"""Render a `Telemetry.to_jsonl` log as a terminal summary and/or a
self-contained HTML report (inline SVG sparklines, no external assets).

Stdlib-only ON PURPOSE: the docs/fast CI tiers render the committed
fixture log (tests/data/telemetry_fixture.jsonl) without numpy or the
repro package installed, so this module must import nothing beyond the
standard library.

Input is the typed-JSONL format documented in
`repro.serving.telemetry.Telemetry.to_jsonl`: one record per line with
``"type"`` in {"event", "workload", "device", "drift"} plus a single
"summary" trailer carrying counters / wall totals / gauges / ring fill.

Run:  python -m benchmarks.telemetry_report LOG.jsonl [--html OUT.html]
      --html F   also write a self-contained HTML report to F
      --top N    workloads/devices shown in tables and charts (default 8)
      --check    exit non-zero if the log is malformed: no summary
                 trailer, unknown record types, or the overflow-immune
                 ``reconfig_events`` counter disagreeing with
                 ``events_reconfig``
"""
from __future__ import annotations

import argparse
import html
import json
import sys

RECORD_TYPES = ("event", "workload", "device", "drift", "summary")


def load(path: str) -> dict:
    """Parse a telemetry JSONL log into {events, workloads, devices,
    drift, summary, unknown} lists (summary: dict or None)."""
    data = {"events": [], "workloads": [], "devices": [], "drift": [],
            "summary": None, "unknown": []}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            t = rec.pop("type", None)
            if t == "event":
                data["events"].append(rec)
            elif t == "workload":
                data["workloads"].append(rec)
            elif t == "device":
                data["devices"].append(rec)
            elif t == "drift":
                data["drift"].append(rec)
            elif t == "summary":
                data["summary"] = rec
            else:
                data["unknown"].append(t)
    return data


def check(data: dict) -> list:
    """Structural sanity problems (empty list = clean log)."""
    problems = []
    if data["unknown"]:
        problems.append(f"unknown record types: {sorted(set(data['unknown']))}")
    s = data["summary"]
    if s is None:
        problems.append("missing summary trailer")
        return problems
    counters = s.get("counters", {})
    n_reconf = counters.get("reconfig_events", 0)
    if counters.get("events_reconfig", 0) != n_reconf:
        problems.append(
            f"reconfig_events={n_reconf} disagrees with "
            f"events_reconfig={counters.get('events_reconfig', 0)}")
    for name, ring in s.get("rings", {}).items():
        if ring["rows"] + ring["dropped"] != ring["total"]:
            problems.append(f"ring {name}: rows+dropped != total ({ring})")
    return problems


# -- aggregation --------------------------------------------------------------

def _series(rows, key_field, t_field, v_field):
    """rows -> {key: [(t, v), ...]} sorted by t."""
    out = {}
    for r in rows:
        out.setdefault(r[key_field], []).append((r[t_field], r[v_field]))
    for v in out.values():
        v.sort()
    return out


def _top_keys(series: dict, n: int) -> list:
    """Keys ranked by peak value, descending."""
    peak = {k: max((v for _, v in pts), default=0.0)
            for k, pts in series.items()}
    return sorted(peak, key=lambda k: (-peak[k], str(k)))[:n]


def _event_counts(events) -> dict:
    out = {}
    for e in events:
        key = (e.get("kind", "?"), e.get("cause", ""))
        out[key] = out.get(key, 0) + 1
    return out


# -- terminal -----------------------------------------------------------------

def terminal_report(data: dict, top: int = 8) -> str:
    lines = []
    s = data["summary"] or {}
    rings = s.get("rings", {})
    lines.append("== telemetry report ==")
    lines.append(
        "rows: " + ", ".join(
            f"{name}={r['rows']}"
            + (f" (+{r['dropped']} dropped)" if r["dropped"] else "")
            for name, r in rings.items()) if rings else "rows: (no summary)")

    counts = _event_counts(data["events"])
    if counts:
        lines.append("-- events (kind/cause) --")
        for (kind, cause), n in sorted(counts.items(),
                                       key=lambda kv: (-kv[1], kv[0])):
            lines.append(f"  {kind:<12} {cause:<10} x{n}")

    p99 = _series(data["workloads"], "workload", "t_s", "p99_ms")
    if p99:
        lines.append(f"-- workloads (top {top} by peak p99) --")
        for w in _top_keys(p99, top):
            vals = [v for _, v in p99[w]]
            lines.append(f"  {w:<10} p99 peak {max(vals):8.2f} ms  "
                         f"last {vals[-1]:8.2f} ms  ({len(vals)} ticks)")

    util = _series(data["devices"], "gpu", "t_s", "util")
    if util:
        lines.append(f"-- devices (top {top} by peak util) --")
        for g in _top_keys(util, top):
            vals = [v for _, v in util[g]]
            lines.append(f"  gpu {g:<6} util peak {max(vals):5.2f}  "
                         f"last {vals[-1]:5.2f}  ({len(vals)} ticks)")
        agg = {}
        for r in data["devices"]:
            agg.setdefault(r["t_s"], []).append(r)
        t_last = max(agg)
        rows = agg[t_last]
        lines.append(
            f"  fleet @ t={t_last:g}s: {len(rows)} devices, "
            f"mean util {sum(r['util'] for r in rows) / len(rows):.2f}, "
            f"mean power_sum "
            f"{sum(r['power_sum'] for r in rows) / len(rows):.1f} W, "
            f"mean delta_sch "
            f"{sum(r['delta_sch'] for r in rows) / len(rows):.3f} ms")

    score = _series(data["drift"], "gpu", "t_s", "score")
    if score:
        lines.append(f"-- drift (top {top} by peak score) --")
        for g in _top_keys(score, top):
            vals = [v for _, v in score[g]]
            lines.append(f"  gpu {g:<6} score peak {max(vals):6.3f}  "
                         f"last {vals[-1]:6.3f}  ({len(vals)} ticks)")

    if s:
        walls = s.get("walls_ms", {})
        if walls:
            lines.append("-- overhead (wall ms) --")
            for k, v in walls.items():
                lines.append(f"  {k:<12} {v:10.2f}")
        counters = s.get("counters", {})
        if counters:
            lines.append("-- counters --")
            for k, v in counters.items():
                lines.append(f"  {k:<18} {v}")
        gauges = s.get("gauges", {})
        if gauges:
            lines.append("-- gauges --")
            for k, v in gauges.items():
                lines.append(f"  {k:<18} {v}")
    return "\n".join(lines)


# -- HTML ---------------------------------------------------------------------

def _sparkline(points, width=640, height=80, color="#2563eb") -> str:
    """Inline-SVG polyline for [(t, v), ...]; self-scaling, no deps."""
    if not points:
        return "<svg/>"
    ts = [t for t, _ in points]
    vs = [v for _, v in points]
    t0, t1 = min(ts), max(ts)
    v0, v1 = min(vs), max(vs)
    dt = (t1 - t0) or 1.0
    dv = (v1 - v0) or 1.0
    pad = 4
    pts = " ".join(
        f"{pad + (t - t0) / dt * (width - 2 * pad):.1f},"
        f"{height - pad - (v - v0) / dv * (height - 2 * pad):.1f}"
        for t, v in points)
    return (f'<svg width="{width}" height="{height}" '
            f'viewBox="0 0 {width} {height}">'
            f'<polyline fill="none" stroke="{color}" stroke-width="1.5" '
            f'points="{pts}"/>'
            f'<text x="{pad}" y="12" font-size="10" fill="#666">'
            f"max {v1:.3g}</text>"
            f'<text x="{pad}" y="{height - 2 * pad}" font-size="10" '
            f'fill="#666">min {v0:.3g}</text></svg>')


def _chart_block(title, series, keys, colors) -> str:
    parts = [f"<h2>{html.escape(title)}</h2>"]
    for i, k in enumerate(keys):
        parts.append(
            f'<div class="chart"><span class="lbl">{html.escape(str(k))}'
            f"</span>{_sparkline(series[k], color=colors[i % len(colors)])}"
            f"</div>")
    return "\n".join(parts)


def render_html(data: dict, top: int = 8) -> str:
    """Self-contained HTML report: summary tables + SVG sparklines."""
    s = data["summary"] or {}
    colors = ("#2563eb", "#dc2626", "#059669", "#d97706",
              "#7c3aed", "#0891b2", "#be185d", "#4d7c0f")
    body = ["<h1>telemetry report</h1>"]

    rings = s.get("rings", {})
    if rings:
        body.append("<table><tr><th>ring</th><th>rows</th><th>total</th>"
                    "<th>dropped</th></tr>")
        for name, r in rings.items():
            body.append(f"<tr><td>{html.escape(name)}</td><td>{r['rows']}"
                        f"</td><td>{r['total']}</td><td>{r['dropped']}"
                        f"</td></tr>")
        body.append("</table>")

    counts = _event_counts(data["events"])
    if counts:
        body.append("<h2>control-plane events</h2>"
                    "<table><tr><th>kind</th><th>cause</th><th>n</th></tr>")
        for (kind, cause), n in sorted(counts.items(),
                                       key=lambda kv: (-kv[1], kv[0])):
            body.append(f"<tr><td>{html.escape(kind)}</td>"
                        f"<td>{html.escape(cause)}</td><td>{n}</td></tr>")
        body.append("</table>")

    p99 = _series(data["workloads"], "workload", "t_s", "p99_ms")
    if p99:
        body.append(_chart_block(f"workload p99 (ms, top {top})", p99,
                                 _top_keys(p99, top), colors))
    util = _series(data["devices"], "gpu", "t_s", "util")
    if util:
        body.append(_chart_block(f"device utilization (top {top})", util,
                                 _top_keys(util, top), colors))
    power = _series(data["devices"], "gpu", "t_s", "power_sum")
    if power:
        body.append(_chart_block(f"device power_sum (W, top {top})", power,
                                 _top_keys(power, top), colors))
    score = _series(data["drift"], "gpu", "t_s", "score")
    if score:
        body.append(_chart_block(f"drift score (top {top})", score,
                                 _top_keys(score, top), colors))

    if data["events"]:
        body.append("<h2>event log (newest last)</h2>"
                    "<table><tr><th>t_s</th><th>kind</th><th>workload</th>"
                    "<th>cause</th><th>rate</th><th>gpu</th></tr>")
        for e in data["events"][-50:]:
            rate = (f"{e.get('rate_from', 0):.1f}&rarr;"
                    f"{e.get('rate_to', 0):.1f}")
            gpu = (f"{e.get('gpu_from', -1)}&rarr;{e.get('gpu_to', -1)}")
            body.append(
                f"<tr><td>{e.get('t_s', 0):.2f}</td>"
                f"<td>{html.escape(e.get('kind', ''))}</td>"
                f"<td>{html.escape(e.get('workload', ''))}</td>"
                f"<td>{html.escape(e.get('cause', ''))}</td>"
                f"<td>{rate}</td><td>{gpu}</td></tr>")
        body.append("</table>")

    for title, key in (("overhead (wall ms)", "walls_ms"),
                       ("counters", "counters"), ("gauges", "gauges")):
        d = s.get(key, {})
        if d:
            body.append(f"<h2>{title}</h2><table>")
            for k, v in d.items():
                body.append(f"<tr><td>{html.escape(k)}</td><td>{v}</td></tr>")
            body.append("</table>")

    return ("<!doctype html><html><head><meta charset='utf-8'>"
            "<title>telemetry report</title><style>"
            "body{font:13px monospace;margin:2em;color:#111}"
            "table{border-collapse:collapse;margin:0.5em 0}"
            "td,th{border:1px solid #ccc;padding:2px 8px;text-align:left}"
            ".chart{display:flex;align-items:center;gap:8px;margin:2px 0}"
            ".lbl{min-width:8em;display:inline-block}"
            "</style></head><body>"
            + "\n".join(body) + "</body></html>")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("log", help="telemetry JSONL log (Telemetry.to_jsonl)")
    ap.add_argument("--html", type=str, default=None,
                    help="write a self-contained HTML report here")
    ap.add_argument("--top", type=int, default=8,
                    help="workloads/devices per table/chart (default 8)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on a malformed log")
    args = ap.parse_args(argv)

    data = load(args.log)
    print(terminal_report(data, top=args.top))
    if args.html:
        with open(args.html, "w") as f:
            f.write(render_html(data, top=args.top))
        print(f"# wrote {args.html}")
    problems = check(data)
    for p in problems:
        print(f"# MALFORMED: {p}")
    return 1 if (args.check and problems) else 0


if __name__ == "__main__":
    sys.exit(main())
