"""Benchmark harness — one module per paper table/figure (+ roofline).

Prints ``bench,key=value,...`` CSV-ish rows and writes
benchmarks/out/results.json.  Run: PYTHONPATH=src python -m benchmarks.run
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import ablation, accuracy, dynamic_sweep, interference, \
        kernels_micro, provisioning, roofline, runtime_behavior, scale_sweep

    modules = [
        ("interference(Figs3-9)", interference),
        ("accuracy(Figs11-13)", accuracy),
        ("provisioning(Table1,Figs14-19)", provisioning),
        ("runtime(Figs15-21)", runtime_behavior),
        ("scale_sweep(Sec5.4,quick)", scale_sweep),
        ("dynamic_sweep(Sec4.2/4.4,quick)", dynamic_sweep),
        ("kernels_micro", kernels_micro),
        ("interference_ablation", ablation),
        ("roofline", roofline),
    ]
    all_rows = []
    for name, mod in modules:
        t0 = time.time()
        rows = mod.run()
        dt = time.time() - t0
        print(f"# {name}: {len(rows)} rows in {dt:.1f}s", flush=True)
        for r in rows:
            bench = r.pop("bench", name)
            body = ",".join(f"{k}={v}" for k, v in r.items())
            print(f"{bench},{body}")
            r["bench"] = bench
        all_rows.extend(rows)
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    out = os.path.join(out_dir, "results.json")
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# wrote {out} ({len(all_rows)} rows)")


if __name__ == '__main__':
    main()
