"""Kernel microbenchmarks (name, us_per_call, derived) — CPU wall-clock of
the pure-jnp model paths vs the naive oracles.  The Pallas kernels
themselves target TPU (interpret mode timing is meaningless), so the
'derived' column reports the kernel's ANALYTIC HBM-traffic advantage —
the quantity the roofline table prices.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fitted_context  # noqa: F401  (path setup)
from repro.kernels import ref
from repro.models.attention import kv_blockwise_attention
from repro.models.rwkv import wkv_chunked
from repro.models.ssm import ssd_chunked


def _time(fn, *args, reps=5):
    fn(*args)                                     # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # attention: chunked online-softmax vs naive quadratic
    B, S, H, KV, hd = 1, 2048, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    chunked = jax.jit(lambda q, k, v: kv_blockwise_attention(
        q, k, v, q_positions=pos, kv_positions=pos, causal=True, window=None,
        kv_chunk=512))
    t_naive = _time(naive, q, k, v)
    t_chunk = _time(chunked, q, k, v)
    # flash kernel analytic traffic: scores never hit HBM
    score_bytes = B * S * S * H * 4
    io_bytes = (3 * B * S * KV * hd + B * S * H * hd) * 4
    rows.append({"bench": "kernel_micro", "name": "attention_naive_2k",
                 "us_per_call": round(t_naive, 1),
                 "derived": f"score_traffic={score_bytes/1e6:.0f}MB"})
    rows.append({"bench": "kernel_micro", "name": "attention_kvblockwise_2k",
                 "us_per_call": round(t_chunk, 1),
                 "derived": f"flash_kernel_traffic={io_bytes/1e6:.0f}MB "
                            f"({score_bytes/io_bytes:.0f}x less than naive)"})

    # rwkv6: chunked factorized vs sequential scan
    S2, H2, hd2 = 1024, 4, 64
    r = 0.5 * jax.random.normal(ks[3], (B, S2, H2, hd2))
    kk = 0.5 * jax.random.normal(ks[4], (B, S2, H2, hd2))
    vv = jax.random.normal(ks[5], (B, S2, H2, hd2))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[6], (B, S2, H2, hd2)) - 1.5), -2.0)
    u = 0.3 * jax.random.normal(ks[7], (H2, hd2))
    t_seq = _time(jax.jit(lambda *a: ref.rwkv6_ref(*a)[0]), r, kk, vv, logw, u)
    t_chk = _time(jax.jit(lambda *a: wkv_chunked(*a, q=32)[0]), r, kk, vv, logw, u)
    rows.append({"bench": "kernel_micro", "name": "rwkv6_sequential_1k",
                 "us_per_call": round(t_seq, 1), "derived": "oracle"})
    rows.append({"bench": "kernel_micro", "name": "rwkv6_chunked_1k",
                 "us_per_call": round(t_chk, 1),
                 "derived": f"speedup={t_seq/t_chk:.1f}x"})

    # ssd: chunked vs sequential
    N = 32
    xdt = jax.random.normal(ks[0], (B, S2, H2, hd2))
    Bm = 0.5 * jax.random.normal(ks[1], (B, S2, N))
    Cm = 0.5 * jax.random.normal(ks[2], (B, S2, N))
    dt = jnp.ones((B, S2, H2)) * 0.1
    dA = -jnp.exp(jax.random.normal(ks[3], (B, S2, H2)) - 1.5)
    D = jnp.ones((H2,))
    BmH = jnp.broadcast_to(Bm[:, :, None, :], (B, S2, H2, N))
    CmH = jnp.broadcast_to(Cm[:, :, None, :], (B, S2, H2, N))
    t_seq2 = _time(jax.jit(lambda *a: ref.ssd_ref(*a)[0]),
                   xdt * dt[..., None], BmH, CmH, dA)
    t_chk2 = _time(jax.jit(lambda *a: ssd_chunked(*a, q=128)[0]),
                   xdt, Bm, Cm, dt, dA, D)
    rows.append({"bench": "kernel_micro", "name": "ssd_sequential_1k",
                 "us_per_call": round(t_seq2, 1), "derived": "oracle"})
    rows.append({"bench": "kernel_micro", "name": "ssd_chunked_1k",
                 "us_per_call": round(t_chk2, 1),
                 "derived": f"speedup={t_seq2/t_chk2:.1f}x"})
    return rows
