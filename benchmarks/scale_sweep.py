"""Sec. 5.4 scalability sweep: the paper claims Algorithm 1 provisions
m = 1000 workloads in 4.61 s (the interference model is called O(m^2)
times).  This benchmark tracks that bound against the vectorized engine:

  * m in {10, 100, 500, 1000} synthetic workloads (jittered App-table
    mixes) provisioned over heterogeneous hardware (TPU v5e + v4) via
    `provision_cheapest`,
  * reported per m: provisioning wall-clock, devices used, chosen
    hardware, plan cost, and the model-predicted SLO-violation count,
  * for small m: the scalar-oracle wall-clock and a plan-identity check,
  * a sampled discrete-event simulation of a few devices (exact per
    device) as a ground-truth spot check.

Run:  PYTHONPATH=src python -m benchmarks.scale_sweep [--quick] [--check]
      --quick    m <= 100 only (CI per-PR smoke; uploads results artifact)
      --check    exit non-zero if the m=1000 wall-clock exceeds TARGET_S

Writes a JSON row dump (default benchmarks/scale_sweep_results.json).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SIZES_FULL = (10, 100, 500, 1000)
SIZES_QUICK = (10, 100)
TARGET_S = 10.0          # CI bound for m=1000 (paper: 4.61 s)
DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "scale_sweep_results.json")


def _context():
    from repro.core.experiments import fitted_context
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles_by_hw = {ctx5.hw.name: ctx5.profiles,
                      ctx4.hw.name: ctx4.profiles}
    return profiles_by_hw, [ctx5.hw, ctx4.hw]


def sweep(sizes, *, seed: int = 0, oracle_max_m: int = 100,
          sim_max_m: int = 500, sim_devices: int = 4,
          sim_duration_s: float = 5.0):
    from repro.core import provisioner as prov
    from repro.serving.simulator import simulate_device_sample
    from repro.serving.workload import models, synthetic_workloads

    profiles_by_hw, hardware = _context()
    mods = models()
    rows = []
    for m in sizes:
        specs = synthetic_workloads(m, seed)
        t0 = time.perf_counter()
        plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware)
        wall = time.perf_counter() - t0
        viol = prov.predicted_violations(plan, profiles_by_hw[hw.name], hw)
        row = {
            "bench": "scale_sweep", "m": m,
            "wall_s": round(wall, 3),
            "n_devices": plan.n_gpus,
            "hardware": hw.name,
            "cost_per_hour": round(plan.cost_per_hour(), 2),
            "predicted_violations": len(viol),
            "target_s": TARGET_S if m == 1000 else None,
        }
        if m <= oracle_max_m:
            t0 = time.perf_counter()
            oracle, hw_o = prov.provision_cheapest(
                specs, profiles_by_hw, hardware, engine="scalar")
            row["scalar_wall_s"] = round(time.perf_counter() - t0, 3)
            row["matches_scalar_oracle"] = (
                hw_o.name == hw.name
                and [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
                     for p in oracle.placements]
                == [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
                    for p in plan.placements])
        if m <= sim_max_m:
            res, gpus = simulate_device_sample(
                plan, mods, hw, max_devices=sim_devices,
                duration_s=sim_duration_s, seed=seed)
            simulated = {w: s for w, s in
                         ((p.workload.name, p.workload)
                          for p in plan.placements if p.gpu in set(gpus))}
            row["sim_devices"] = len(gpus)
            row["sim_workloads"] = len(simulated)
            row["sim_violations"] = len(res.violations(simulated))
        rows.append(row)
        print(",".join(f"{k}={v}" for k, v in row.items() if v is not None),
              flush=True)
    return rows


def run():
    """benchmarks.run integration: the quick tier only."""
    return sweep(SIZES_QUICK)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="m <= 100 only (per-PR CI smoke)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated m values (overrides --quick)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=DEFAULT_OUT)
    ap.add_argument("--check", action="store_true",
                    help="fail if m=1000 exceeds the %.0f s target"
                         % TARGET_S)
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = SIZES_QUICK if args.quick else SIZES_FULL
    if args.check and 1000 not in sizes:
        print("error: --check requires m=1000 in the sweep "
              f"(selected sizes: {sizes})", file=sys.stderr)
        return 2
    rows = sweep(sizes, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {args.out} ({len(rows)} rows)")

    status = 0
    for row in rows:
        if row["m"] == 1000:
            ok = row["wall_s"] < TARGET_S
            print(f"# m=1000 wall-clock {row['wall_s']:.2f}s "
                  f"{'<' if ok else '>='} {TARGET_S:.0f}s target "
                  f"({'PASS' if ok else 'FAIL'}; paper reports 4.61s)")
            if args.check and not ok:
                status = 1
    return status


if __name__ == "__main__":
    sys.exit(main())
