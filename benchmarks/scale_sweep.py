"""Sec. 5.4 scalability sweep: the paper claims Algorithm 1 provisions
m = 1000 workloads in 4.61 s (the interference model is called O(m^2)
times).  This benchmark tracks that bound against the vectorized engine,
and — since the simulator is vectorized too — closes the loop against
ground truth at FULL cluster scale:

  * m in {10, 100, 500, 1000} synthetic workloads (jittered App-table
    mixes) provisioned over heterogeneous hardware (TPU v5e + v4) via
    `provision_cheapest`,
  * reported per m: provisioning wall-clock, devices used, chosen
    hardware, plan cost, and the model-predicted SLO-violation count,
  * for small m: the scalar-oracle wall-clock and a plan-identity check,
  * a FULL-cluster discrete-event simulation (`simulate_full`: every
    device, >= 10 simulated seconds) reporting *simulated* SLO
    violations next to the predicted ones, plus events/sec throughput
    so simulator perf regressions are visible per PR,
  * the predicted-vs-simulated violation GAP for BOTH budget splits:
    the queueing-aware default (`budget="queueing"`, the headline row)
    and the paper-faithful `budget="half"` comparison whose zero-slack
    split is what produced the historical 5-predicted-vs-178-simulated
    gap at m=1000 (`half_*` fields),
  * the replica-group plan (`provision(..., replicate=True)`, `repl_*`
    fields): workloads infeasible even solo at r = 1.0 are split into
    rate-share replicas (`w#0..w#k-1`) instead of clamped, so the
    honest full-device residual becomes servable — replica counts and
    the remaining residual are tracked per m (docs/provisioning.md).

The jitted backend (`--backend jax`, `PlannerConfig(backend="jax")`)
extends the sweep to m = 10,000 (~8k devices): provisioning runs
through `perf_model_jax.alloc_all_jax` and the simulator's latency
tables through the bulk `physics_jax` twin, with numpy staying the
pinned oracle (plans are checked identical at m <= 1000 by the jax
test suite).  Above `CMP_MAX_M` the half-split and replica comparison
plans are skipped — they would triple the simulation cost of the
informational m=10k tier without adding coverage the m=1000 row
doesn't already pin.

Run:  PYTHONPATH=src python -m benchmarks.scale_sweep [--quick] [--check]
      --quick        m <= 100 only (CI per-PR smoke; uploads artifact)
      --backend B    "numpy" (default) or "jax": planner + simulator
                     hot-path backend for every plan in the sweep
      --check        exit non-zero if any swept m in TARGETS exceeds its
                     (provision, full-simulation) wall-clock targets, or
                     if its simulated violations exceed 2x the predicted
                     count
      --sim-floor N  exit non-zero if any full simulation ran below N
                     simulated events per wall-clock second
      --gap-budget N exit non-zero if, for any m, the queueing-aware
                     plan's simulated violations exceed predicted + N
                     (negative disables; CI enforces this per PR)

Writes a JSON row dump (default benchmarks/out/scale_sweep_results.json
— gitignored; CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SIZES_FULL = (10, 100, 500, 1000)
SIZES_QUICK = (10, 100)
TARGET_S = 10.0          # CI bound for m=1000 provisioning (paper: 4.61 s)
SIM_TARGET_S = 60.0      # CI bound for the m=1000 FULL-cluster simulation
# per-m (provision, full-simulation) wall-clock targets --check enforces;
# m=10,000 rides the informational jax-tier job (single-digit minutes)
TARGETS = {1000: (TARGET_S, SIM_TARGET_S), 10000: (240.0, 300.0)}
CMP_MAX_M = 1000         # half-split / replica comparison plans up to here
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "scale_sweep_results.json")


def _context():
    from repro.core.experiments import fitted_context
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles_by_hw = {ctx5.hw.name: ctx5.profiles,
                      ctx4.hw.name: ctx4.profiles}
    return profiles_by_hw, [ctx5.hw, ctx4.hw]


def sweep(sizes, *, seed: int = 0, oracle_max_m: int = 100,
          sim_duration_s: float = 10.0, backend: str = "numpy"):
    from repro.core import provisioner as prov
    from repro.core.types import PlannerConfig
    from repro.serving.simulator import simulate_full
    from repro.serving.workload import models, synthetic_workloads

    cfg = PlannerConfig(backend=backend)
    profiles_by_hw, hardware = _context()
    mods = models()
    rows = []
    for m in sizes:
        specs = synthetic_workloads(m, seed)
        sb = {s.name: s for s in specs}
        t0 = time.perf_counter()
        plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           config=cfg)
        wall = time.perf_counter() - t0
        viol = prov.predicted_violations(plan, profiles_by_hw[hw.name], hw)
        row = {
            "bench": "scale_sweep", "m": m,
            "budget": "queueing", "backend": backend,
            "wall_s": round(wall, 3),
            "n_devices": plan.n_gpus,
            "hardware": hw.name,
            "cost_per_hour": round(plan.cost_per_hour(), 2),
            "predicted_violations": len(viol),
            "target_s": TARGETS[m][0] if m in TARGETS else None,
        }
        if m <= oracle_max_m:
            t0 = time.perf_counter()
            oracle, hw_o = prov.provision_cheapest(
                specs, profiles_by_hw, hardware, engine="scalar")
            row["scalar_wall_s"] = round(time.perf_counter() - t0, 3)
            row["matches_scalar_oracle"] = (
                hw_o.name == hw.name
                and [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
                     for p in oracle.placements]
                == [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
                    for p in plan.placements])
        # full-cluster ground truth: EVERY device, simulated violations
        # reported next to the model-predicted count
        t0 = time.perf_counter()
        res = simulate_full(plan, mods, hw, duration_s=sim_duration_s,
                            seed=seed, backend=backend)
        sim_wall = time.perf_counter() - t0
        row.update({
            "sim_devices": plan.n_gpus,
            "sim_workloads": m,
            "sim_duration_s": sim_duration_s,
            "sim_wall_s": round(sim_wall, 3),
            "sim_violations": len(res.violations(sb)),
            "sim_requests": int(res.stats["n_requests"]),
            "sim_passes": int(res.stats["n_passes"]),
            "sim_events_per_s": round(res.stats["events_per_s"]),
            "sim_wait_mean_ms": round(res.stats["wait_mean_ms"], 3),
            "sim_wait_p99_ms": round(res.stats["wait_p99_ms"], 3),
            "sim_target_s": TARGETS[m][1] if m in TARGETS else None,
        })
        row["gap"] = row["sim_violations"] - row["predicted_violations"]
        if m <= CMP_MAX_M:
            _comparison_plans(row, specs, sb, profiles_by_hw, hardware,
                              mods, cfg, sim_duration_s, seed)
        rows.append(row)
        print(",".join(f"{k}={v}" for k, v in row.items() if v is not None),
              flush=True)
    return rows


def _comparison_plans(row, specs, sb, profiles_by_hw, hardware, mods, cfg,
                      sim_duration_s, seed):
    """Half-split + replica-group comparison rows (m <= CMP_MAX_M)."""
    from repro.core import provisioner as prov
    from repro.core import replication
    from repro.serving.simulator import simulate_full

    # the paper-faithful half split, same workloads: the historical
    # 5-vs-178 gap stays visible next to the queueing-aware numbers
    plan_h, hw_h = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           config=cfg.replace(budget="half"))
    viol_h = prov.predicted_violations(plan_h, profiles_by_hw[hw_h.name],
                                       hw_h, budget="half")
    res_h = simulate_full(plan_h, mods, hw_h, duration_s=sim_duration_s,
                          seed=seed, backend=cfg.backend)
    row.update({
        "half_n_devices": plan_h.n_gpus,
        "half_cost_per_hour": round(plan_h.cost_per_hour(), 2),
        "half_predicted_violations": len(viol_h),
        "half_sim_violations": len(res_h.violations(sb)),
    })
    row["half_gap"] = (row["half_sim_violations"]
                       - row["half_predicted_violations"])
    # replica groups (replicate=True): workloads infeasible even
    # solo at r = 1.0 are split into rate-share replicas instead of
    # clamped — the honest full-device residual becomes servable
    plan_r, hw_r = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           config=cfg.replace(replicate=True))
    viol_r = prov.predicted_violations(plan_r,
                                       profiles_by_hw[hw_r.name], hw_r)
    res_r = simulate_full(plan_r, mods, hw_r,
                          duration_s=sim_duration_s, seed=seed,
                          backend=cfg.backend)
    groups = replication.group_placements(plan_r.placements)
    row.update({
        "repl_n_devices": plan_r.n_gpus,
        "repl_cost_per_hour": round(plan_r.cost_per_hour(), 2),
        "repl_predicted_violations": len(viol_r),
        "repl_sim_violations": len(res_r.violations(sb)),
        "repl_split_workloads": sum(1 for g in groups.values()
                                    if len(g) > 1),
        "repl_n_replicas": sum(len(g) for g in groups.values()
                               if len(g) > 1),
    })
    row["repl_gap"] = (row["repl_sim_violations"]
                       - row["repl_predicted_violations"])
    # replica groups under the paper-faithful half split: the half
    # budget clamps MORE workloads to r = 1.0 than the queueing split,
    # so replication has more residual to recover — this is the pairing
    # that shows whether the 5-vs-178 gap is a budget artifact or a
    # single-instance ceiling artifact
    plan_hr, hw_hr = prov.provision_cheapest(
        specs, profiles_by_hw, hardware,
        config=cfg.replace(budget="half", replicate=True))
    viol_hr = prov.predicted_violations(plan_hr,
                                        profiles_by_hw[hw_hr.name], hw_hr,
                                        budget="half")
    res_hr = simulate_full(plan_hr, mods, hw_hr, duration_s=sim_duration_s,
                           seed=seed, backend=cfg.backend)
    groups_hr = replication.group_placements(plan_hr.placements)
    row.update({
        "half_repl_n_devices": plan_hr.n_gpus,
        "half_repl_cost_per_hour": round(plan_hr.cost_per_hour(), 2),
        "half_repl_predicted_violations": len(viol_hr),
        "half_repl_sim_violations": len(res_hr.violations(sb)),
        "half_repl_split_workloads": sum(1 for g in groups_hr.values()
                                         if len(g) > 1),
        "half_repl_n_replicas": sum(len(g) for g in groups_hr.values()
                                    if len(g) > 1),
    })
    row["half_repl_gap"] = (row["half_repl_sim_violations"]
                            - row["half_repl_predicted_violations"])


def run():
    """benchmarks.run integration: the quick tier only."""
    return sweep(SIZES_QUICK)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="m <= 100 only (per-PR CI smoke)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated m values (overrides --quick)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sim-duration", type=float, default=10.0,
                    help="simulated seconds for the full-cluster run")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="planner + simulator hot-path backend")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT)
    ap.add_argument("--check", action="store_true",
                    help="fail if any swept m in TARGETS exceeds its "
                         "(provision, full-simulation) wall-clock targets "
                         "(m=1000: %.0f s / %.0f s) or if its simulated "
                         "violations exceed 2x the predicted count"
                         % (TARGET_S, SIM_TARGET_S))
    ap.add_argument("--sim-floor", type=float, default=0.0,
                    help="fail if any full simulation ran below this many "
                         "events/sec (0 = off)")
    ap.add_argument("--gap-budget", type=int, default=-1,
                    help="fail if, for any m, the queueing-aware plan's "
                         "simulated violations exceed predicted + this "
                         "budget (negative = off)")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = SIZES_QUICK if args.quick else SIZES_FULL
    if args.check and not any(m in TARGETS for m in sizes):
        print("error: --check requires a target size "
              f"({sorted(TARGETS)}) in the sweep (selected: {sizes})",
              file=sys.stderr)
        return 2
    rows = sweep(sizes, seed=args.seed, sim_duration_s=args.sim_duration,
                 backend=args.backend)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {args.out} ({len(rows)} rows)")

    status = 0
    for row in rows:
        if args.sim_floor and row["sim_events_per_s"] < args.sim_floor:
            print(f"# m={row['m']} simulator throughput "
                  f"{row['sim_events_per_s']:.0f} events/s < "
                  f"{args.sim_floor:.0f} floor (FAIL)")
            status = 1
        if args.gap_budget >= 0:
            gap_ok = (row["sim_violations"]
                      <= row["predicted_violations"] + args.gap_budget)
            half = ("; half split: "
                    f"{row['half_predicted_violations']} predicted / "
                    f"{row['half_sim_violations']} simulated"
                    if "half_sim_violations" in row else "")
            print(f"# m={row['m']} violation gap: "
                  f"predicted={row['predicted_violations']} "
                  f"simulated={row['sim_violations']} "
                  f"(budget +{args.gap_budget}, "
                  f"{'PASS' if gap_ok else 'FAIL'}{half})")
            if not gap_ok:
                status = 1
        if row["m"] in TARGETS:
            m = row["m"]
            target_s, sim_target_s = TARGETS[m]
            ok = row["wall_s"] < target_s
            print(f"# m={m} provisioning {row['wall_s']:.2f}s "
                  f"{'<' if ok else '>='} {target_s:.0f}s target "
                  f"({'PASS' if ok else 'FAIL'}"
                  f"{'; paper reports 4.61s' if m == 1000 else ''})")
            sim_ok = row["sim_wall_s"] < sim_target_s
            half = (f" (half split: {row['half_predicted_violations']}/"
                    f"{row['half_sim_violations']})"
                    if "half_sim_violations" in row else "")
            print(f"# m={m} full-cluster sim ({row['sim_devices']} devices, "
                  f"{row['sim_duration_s']:.0f}s sim) {row['sim_wall_s']:.2f}s "
                  f"{'<' if sim_ok else '>='} {sim_target_s:.0f}s target "
                  f"({'PASS' if sim_ok else 'FAIL'}); "
                  f"violations predicted={row['predicted_violations']} "
                  f"simulated={row['sim_violations']}{half}")
            # acceptance bound: simulated within 2x of predicted (the
            # half split sat at ~36x: 5 predicted vs 178 simulated)
            two_ok = (row["sim_violations"]
                      <= 2 * max(row["predicted_violations"], 1))
            print(f"# m={m} simulated/predicted "
                  f"{row['sim_violations']}/{row['predicted_violations']} "
                  f"within 2x bound ({'PASS' if two_ok else 'FAIL'})")
            if "repl_n_replicas" in row:
                print(f"# m={m} replica groups: "
                      f"{row['repl_split_workloads']} workloads split into "
                      f"{row['repl_n_replicas']} replicas; violations "
                      f"predicted={row['repl_predicted_violations']} "
                      f"simulated={row['repl_sim_violations']} "
                      f"({row['repl_n_devices']} devices, "
                      f"${row['repl_cost_per_hour']}/h)")
            if "half_repl_n_replicas" in row:
                print(f"# m={m} half-budget replica groups: "
                      f"{row['half_repl_split_workloads']} workloads split "
                      f"into {row['half_repl_n_replicas']} replicas; "
                      f"violations "
                      f"predicted={row['half_repl_predicted_violations']} "
                      f"simulated={row['half_repl_sim_violations']} "
                      f"({row['half_repl_n_devices']} devices, "
                      f"${row['half_repl_cost_per_hour']}/h)")
            if args.check and not (ok and sim_ok and two_ok):
                status = 1
    return status


if __name__ == "__main__":
    sys.exit(main())
