"""Availability sweep: SLO violations and recovery time vs device fault
rate, controller-on vs controller-off (the robustness half of the
predictability story — docs/simulator.md, docs/control-plane.md).

The paper provisions for clean hardware; this sweep measures what its
plans are worth when hardware misbehaves.  For each cluster size m the
static queueing-aware plan is simulated under seeded fault schedules
(`repro.serving.faults`) twice per scenario — once uncontrolled (the
plan just eats the outage: backlog piles up, drains after restart) and
once with the closed-loop controller's health layer detecting failures
/ stragglers from live telemetry, quarantining the device, and
migrating victims to healthy homes.  Rows report whole-run per-request
violation rates, the simulator's downtime / lost-request / recovery
accounting (``SimResult.stats``), and the controller's health edit
counts (``migrate`` / ``readmit``).

Scenarios:
  fail-R     Poisson device failures at R per device-minute, fixed MTTR
             (`faults.random_failures`, one row per swept rate).  The
             availability gate: at EVERY positive rate the controlled
             run must beat the uncontrolled one on BOTH the mean
             per-request violation rate and mean recovery time —
             strictly, unless the seeded schedule happened to produce
             zero in-window failures (noted, skipped).
  straggler  a seeded fraction of devices serve every pass at a
             multiplier the performance model never sees
             (`faults.stragglers`).  The gate: the controller detects
             the stragglers from measured-vs-predicted residuals,
             migrates >= 1 victim off them, and every victim's tail
             (last TAIL_WINDOW_S of 1 s monitor windows) is back under
             its SLO.
  clean      no faults — the health layer must be a perfect no-op
             (zero reconfigurations, plan bit-identical), enforced by
             --check.  Guards against health false-positives rotting
             the no-drift guarantee.

Run:  PYTHONPATH=src python -m benchmarks.availability_sweep [--quick]
      --quick        m <= 100 only (CI per-PR smoke; uploads artifact)
      --sizes M,...  explicit cluster sizes
      --rates R,...  failure rates per device-minute (default 0.5,1,2)
      --seed N       fault-schedule / simulator seed
      --backend B    "numpy" (default) or "jax" simulator backend
      --sim-duration secs of simulated serving per run
      --check        exit non-zero if any gate above fails
      --telemetry    attach a `Telemetry` recorder to every controlled
                     run (results are byte-identical by contract —
                     docs/observability.md); writes per-scenario JSONL +
                     HTML artifacts next to --out, rows gain
                     ``telemetry_*`` columns (drift rows are the
                     straggler-detection signal), and --check gates the
                     event-log-vs-n_reconfigs reconciliation
      --out F        JSON row dump (default
                     benchmarks/out/availability_sweep_results.json)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SIZES_FULL = (100, 1000)
SIZES_QUICK = (100,)
RATES = (0.5, 1.0, 2.0)     # device failures per device-minute
MTTR_MS = 4000.0            # fixed repair time: > the ~3 s detection
                            # latency (1 s control period x fail_ticks),
                            # so an undetected outage is never shorter
                            # than a detected-and-migrated one
FAULT_HORIZON_FRAC = 0.6    # failures only in the first 60% of the run:
                            # every restart (+MTTR) lands in-window, so
                            # the uncontrolled recovery time is measured,
                            # not censored by the horizon
STRAGGLER_FRAC = 0.1
STRAGGLER_MULT = 2.5        # comfortably past the fleet-relative
                            # detection bar (health_straggler_factor)
TAIL_WINDOW_S = 3.0         # straggler gate: victim p99 over the last
                            # 3 s of 1 s monitor windows must meet SLO
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "availability_sweep_results.json")


def _mean_violation_rate(res, specs) -> float:
    import numpy as np
    rates = res.violation_rates({s.name: s for s in specs})
    return float(np.mean(list(rates.values())))


def _fault_stats(res) -> dict:
    """Fault accounting keys (absent from faults-off runs: zeros)."""
    return {k: res.stats.get(k, 0) for k in
            ("n_failures", "downtime_ms", "lost_requests",
             "n_recoveries", "recovery_mean_ms")}


def _victim_tail_ok(res, plan, specs, slow_gpus, horizon_s) -> tuple:
    """(ok, worst) — every straggler-victim base workload's monitor
    windows inside the last TAIL_WINDOW_S must sit at/below its SLO."""
    from repro.core import replication
    victims = {replication.base_name(p.workload.name)
               for p in plan.placements if p.gpu in slow_gpus}
    slo = {s.name: s.slo_ms for s in specs}
    ok, worst = True, 0.0
    for row in res.timeline:
        base = replication.base_name(row["workload"])
        if base not in victims or row["t_s"] < horizon_s - TAIL_WINDOW_S:
            continue
        if row["rps_1s"] <= 0.0:
            continue
        margin = row["p99_1s"] / slo[base]
        worst = max(worst, margin)
        if row["p99_1s"] > slo[base] + 1e-9:
            ok = False
    return ok, worst


def sweep(sizes, *, rates=RATES, seed: int = 0,
          sim_duration_s: float = 12.0, backend: str = "numpy",
          telemetry: bool = False, artifact_dir: str = None):
    from repro.core import provisioner as prov
    from repro.core.experiments import fitted_context
    from repro.core.types import PlannerConfig
    from repro.serving import faults
    from repro.serving.controller import Controller
    from repro.serving.simulator import simulate_full
    from repro.serving.telemetry import Telemetry
    from repro.serving.workload import models, synthetic_workloads

    from benchmarks import telemetry_report

    if telemetry:
        artifact_dir = artifact_dir or os.path.dirname(DEFAULT_OUT)
        os.makedirs(artifact_dir, exist_ok=True)

    cfg = PlannerConfig(backend=backend)
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles_by_hw = {ctx5.hw.name: ctx5.profiles,
                      ctx4.hw.name: ctx4.profiles}
    hardware = [ctx5.hw, ctx4.hw]
    mods = models()
    horizon_ms = sim_duration_s * 1000.0

    rows = []
    for m in sizes:
        specs = synthetic_workloads(m, seed)
        plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           config=cfg)
        profiles = profiles_by_hw[hw.name]
        scenarios = [("clean", None)]
        scenarios += [
            (f"fail-{r:g}", faults.random_failures(
                plan.n_gpus, horizon_ms * FAULT_HORIZON_FRAC,
                rate_per_min=r, mttr_ms=MTTR_MS, seed=seed))
            for r in rates]
        scenarios.append(("straggler", faults.stragglers(
            plan.n_gpus, frac=STRAGGLER_FRAC, multiplier=STRAGGLER_MULT,
            seed=seed)))
        for scenario, fs in scenarios:
            kw = dict(duration_s=sim_duration_s, seed=seed, faults=fs,
                      backend=backend, record_timeline=True)
            t0 = time.perf_counter()
            res_u = simulate_full(plan, mods, hw, **kw)
            off_wall = time.perf_counter() - t0
            tel = Telemetry() if telemetry else None
            ctl = Controller(plan, profiles, hw,
                             config=cfg.replace(batch="joint"),
                             telemetry=tel)
            t0 = time.perf_counter()
            res_c = simulate_full(plan, mods, hw, adjust_fn=ctl,
                                  adjust_scope="cluster",
                                  adjust_period_s=1.0, telemetry=tel,
                                  **kw)
            on_wall = time.perf_counter() - t0
            row = {
                "bench": "availability_sweep", "m": m,
                "scenario": scenario, "backend": backend,
                "hardware": hw.name, "n_devices": plan.n_gpus,
                "n_failures": int(res_u.stats.get("n_failures", 0)),
                "off_violation_rate":
                    round(_mean_violation_rate(res_u, specs), 4),
                "on_violation_rate":
                    round(_mean_violation_rate(res_c, specs), 4),
                "off": {k: round(float(v), 2)
                        for k, v in _fault_stats(res_u).items()},
                "on": {k: round(float(v), 2)
                       for k, v in _fault_stats(res_c).items()},
                "n_reconfigs": int(res_c.stats["n_reconfigs"]),
                "n_migrations": sum(1 for e in ctl.edits
                                    if e.action == "migrate"),
                "n_readmits": sum(1 for e in ctl.edits
                                  if e.action == "readmit"),
                "n_edits": len(ctl.edits),
                "plan_identical": ctl.plan is plan,
                "off_sim_wall_s": round(off_wall, 3),
                "on_sim_wall_s": round(on_wall, 3),
                "sim_duration_s": sim_duration_s,
            }
            if scenario == "straggler":
                slow_gpus = set(fs.slow)
                ok, worst = _victim_tail_ok(res_c, plan, specs, slow_gpus,
                                            sim_duration_s)
                row["n_stragglers"] = len(slow_gpus)
                row["victim_tail_ok"] = ok
                row["victim_tail_worst"] = round(worst, 3)
            if tel is not None:
                stem = os.path.join(artifact_dir,
                                    f"telemetry_m{m}_{scenario}")
                tel.to_jsonl(stem + ".jsonl")
                with open(stem + ".html", "w") as f:
                    f.write(telemetry_report.render_html(
                        telemetry_report.load(stem + ".jsonl")))
                row.update({
                    "telemetry_events": tel.events.total,
                    "telemetry_drift_rows": tel.drift.total,
                    "telemetry_reconfig_ok":
                        tel.counters.get("reconfig_events", 0)
                        == int(res_c.stats["n_reconfigs"]),
                    "telemetry_log": stem + ".jsonl",
                })
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    return rows


def run():
    """benchmarks.run integration: the quick tier only."""
    return sweep(SIZES_QUICK)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="m <= 100 only (per-PR CI smoke)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated m values (overrides --quick)")
    ap.add_argument("--rates", type=str, default=None,
                    help="comma-separated failure rates per device-minute "
                         f"(default: {','.join(str(r) for r in RATES)})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="simulator backend (default: numpy)")
    ap.add_argument("--sim-duration", type=float, default=12.0)
    ap.add_argument("--telemetry", action="store_true",
                    help="attach a Telemetry recorder to every "
                         "controlled run; writes per-scenario JSONL + "
                         "HTML artifacts next to --out")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT)
    ap.add_argument("--check", action="store_true",
                    help="fail unless controller-on strictly beats "
                         "controller-off on violations AND recovery at "
                         "every positive fault rate, the straggler gate "
                         "holds, and the clean run is a no-op")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = SIZES_QUICK if args.quick else SIZES_FULL
    rates = (tuple(float(r) for r in args.rates.split(","))
             if args.rates else RATES)
    rows = sweep(sizes, rates=rates, seed=args.seed,
                 sim_duration_s=args.sim_duration, backend=args.backend,
                 telemetry=args.telemetry,
                 artifact_dir=os.path.dirname(os.path.abspath(args.out)))
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {args.out} ({len(rows)} rows)")

    status = 0
    for row in rows:
        tag = f"m={row['m']} {row['scenario']}"
        if "telemetry_events" in row:
            ok_rec = row["telemetry_reconfig_ok"]
            print(f"# {tag}: telemetry {row['telemetry_events']} events, "
                  f"{row['telemetry_drift_rows']} drift rows, event-log "
                  f"reconciliation {'PASS' if ok_rec else 'FAIL'}")
            if args.check and not ok_rec:
                status = 1
        if row["scenario"] == "clean":
            noop = (row["n_reconfigs"] == 0 and row["n_edits"] == 0
                    and row["plan_identical"])
            print(f"# {tag}: health no-op check "
                  f"({'PASS' if noop else 'FAIL'}: "
                  f"{row['n_reconfigs']} reconfigs, {row['n_edits']} "
                  f"edits, plan_identical={row['plan_identical']})")
            if args.check and not noop:
                status = 1
        elif row["scenario"].startswith("fail-"):
            if row["n_failures"] == 0:
                print(f"# {tag}: no in-window failures at this seed — "
                      f"dominance gate skipped")
                continue
            ok = (row["on_violation_rate"] < row["off_violation_rate"]
                  and row["on"]["recovery_mean_ms"]
                  < row["off"]["recovery_mean_ms"])
            print(f"# {tag}: {row['n_failures']} failures; violation "
                  f"rate {row['off_violation_rate']:.4f} -> "
                  f"{row['on_violation_rate']:.4f}, recovery "
                  f"{row['off']['recovery_mean_ms']:.0f}ms -> "
                  f"{row['on']['recovery_mean_ms']:.0f}ms, "
                  f"{row['n_migrations']} migrations "
                  f"({'PASS' if ok else 'FAIL'})")
            if args.check and not ok:
                status = 1
        elif row["scenario"] == "straggler":
            ok = row["n_migrations"] >= 1 and row["victim_tail_ok"]
            print(f"# {tag}: {row['n_stragglers']} stragglers; "
                  f"{row['n_migrations']} migrations, victim tail "
                  f"p99/SLO worst {row['victim_tail_worst']:.2f} "
                  f"({'PASS' if ok else 'FAIL'})")
            if args.check and not ok:
                status = 1
    return status


if __name__ == "__main__":
    sys.exit(main())
