"""Figs. 3-9: interference characterization + solo-model fits."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_context
from repro.core import perf_model as pm
from repro.serving import physics
from repro.serving.workload import models


def fig3_colocation():
    """Normalized latency vs #co-located identical workloads (1-5)."""
    ctx = fitted_context()
    rows = []
    for name, d in models().items():
        solo = physics.device_state([(d, 8, 0.2)], ctx.hw)[0].t_inf
        for n in range(1, 6):
            st = physics.device_state([(d, 8, 0.2)] * n, ctx.hw)[0]
            pred = pm.predict_device(
                [pm.PlacedWorkload(ctx.profiles[name], 8, 0.2)] * n,
                ctx.hw).per_workload[0].t_inf
            rows.append({
                "bench": "fig3_colocation", "model": name, "n": n,
                "observed_ms": round(st.t_inf, 3),
                "normalized": round(st.t_inf / solo, 4),
                "predicted_ms": round(pred, 3),
            })
    return rows


def fig4_batch_interference():
    """Latency of a fixed workload vs a neighbor's batch size (1-32)."""
    ctx = fitted_context()
    me = models()["qwen1.5-4b"]
    neighbor = models()["rwkv6-1.6b"]
    solo = physics.device_state([(me, 16, 0.5)], ctx.hw)[0].t_inf
    rows = []
    for nb in (1, 2, 4, 8, 16, 32):
        st = physics.device_state([(me, 16, 0.5), (neighbor, nb, 0.5)],
                                  ctx.hw)[0]
        pred = pm.predict_device(
            [pm.PlacedWorkload(ctx.profiles["qwen1.5-4b"], 16, 0.5),
             pm.PlacedWorkload(ctx.profiles["rwkv6-1.6b"], nb, 0.5)],
            ctx.hw).per_workload[0].t_inf
        rows.append({
            "bench": "fig4_batch_interference", "neighbor_batch": nb,
            "observed_ms": round(st.t_inf, 3),
            "normalized": round(st.t_inf / solo, 4),
            "predicted_ms": round(pred, 3),
        })
    return rows


def fig5_7_factors():
    """Factor decomposition: dispatch delay, bandwidth contention, power."""
    ctx = fitted_context()
    d = models()["qwen2-vl-7b"]
    rows = []
    for n in range(1, 6):
        st = physics.device_state([(d, 8, 0.2)] * n, ctx.hw)[0]
        rows.append({
            "bench": "fig5_7_factors", "n": n,
            "sched_ms": round(st.t_sched, 4),
            "active_ms": round(st.t_act, 3),
            "device_power_w": round(st.device_power, 1),
            "freq_mhz": round(st.freq, 1),
        })
    return rows


def fig8_9_solo_model():
    """Eq. 11 surface fit quality + p/c linear fits (R^2)."""
    ctx = fitted_context()
    rows = []
    for name, c in ctx.profiles.items():
        obs, fit = [], []
        for b in (1, 2, 4, 8, 16, 32):
            for r in (0.15, 0.3, 0.5, 0.75, 1.0):
                s = ctx.testbed.run_solo(name, b, r)
                obs.append(s.t_act)
                fit.append(c.k_act(b, r))
        obs, fit = np.array(obs), np.array(fit)
        ss_res = float(np.sum((obs - fit) ** 2))
        ss_tot = float(np.sum((obs - obs.mean()) ** 2))
        rows.append({
            "bench": "fig8_9_solo_model", "model": name,
            "k_act_r2": round(1 - ss_res / ss_tot, 5),
            "k_act_mape_pct": round(
                100 * float(np.mean(np.abs(obs - fit) / obs)), 3),
        })
    return rows


def run():
    return (fig3_colocation() + fig4_batch_interference() + fig5_7_factors()
            + fig8_9_solo_model())
