"""Table 1 + Figs. 14/18/19: provisioning plans, cost, SLO violations."""
from __future__ import annotations

from benchmarks.common import fitted_context
from repro.core import baselines as B
from repro.core import provisioner as prov
from repro.core.experiments import all_plans, evaluate_plans
from repro.serving.workload import three_workloads, twelve_workloads


def table1_three_workloads():
    """Sec. 2.3 illustrative example (A/R/V on one device)."""
    ctx = fitted_context()
    plan = prov.provision(three_workloads(), ctx.profiles, ctx.hw)
    rows = [{
        "bench": "table1_example", "strategy": "iGniter",
        "n_devices": plan.n_gpus,
        "plan": plan.summary().replace("\n", " | "),
    }]
    return rows


def fig14_18_strategies():
    ctx = fitted_context()
    plans = all_plans(ctx)
    results = evaluate_plans(plans, ctx)
    rows = []
    for name, r in results.items():
        rows.append({
            "bench": "fig14_strategies", "strategy": name,
            "n_devices": r["n_gpus"],
            "cost_per_hour": round(r["cost_per_hour"], 2),
            "violations": len(r["violations"]),
            "violating": ",".join(r["violations"]),
        })
        for p in sorted(r["plan"].placements,
                        key=lambda p: int(p.workload.name[1:])):
            rows.append({
                "bench": "fig18_allocations", "strategy": name,
                "workload": p.workload.name, "gpu": p.gpu,
                "r_pct": round(100 * p.r, 1), "batch": p.batch,
            })
    ig = results["iGniter"]["cost_per_hour"]
    gl = results["gpu-lets+"]["cost_per_hour"]
    rows.append({"bench": "fig14_strategies", "strategy": "saving_vs_gpulets",
                 "cost_saving_pct": round(100 * (gl - ig) / gl, 1),
                 "paper_claim_pct": 25})
    return rows


def fig19_placement_of_w2():
    """Where does each strategy place W2 and at what allocation?"""
    ctx = fitted_context()
    specs = twelve_workloads()
    import functools
    from repro.serving.simulator import measure_steady
    from repro.serving.workload import models
    mfn = functools.partial(measure_steady, models=models(), hw=ctx.hw)
    strategies = {
        "FFD+": B.provision_ffd(specs, ctx.profiles, ctx.hw),
        "FFD++": B.provision_ffd(specs, ctx.profiles, ctx.hw,
                                 use_alloc_gpus=True),
        "gpu-lets+": B.provision_gpulets(specs, ctx.profiles, ctx.hw),
        "iGniter": prov.provision(specs, ctx.profiles, ctx.hw),
    }
    rows = []
    for name, plan in strategies.items():
        p = next(pl for pl in plan.placements if pl.workload.name == "W2")
        rows.append({"bench": "fig19_placement", "strategy": name,
                     "gpu": p.gpu, "r_pct": round(100 * p.r, 1),
                     "batch": p.batch})
    return rows


def run():
    return table1_three_workloads() + fig14_18_strategies() \
        + fig19_placement_of_w2()
