"""Roofline table (brief §Roofline): per (arch x shape), the three terms
from the compiled dry-run artifacts + dominant bottleneck + MODEL_FLOPS
ratio.  Reads dryrun_results.json if present (produced by
`python -m repro.launch.dryrun --both-meshes --out dryrun_results.json`);
otherwise reports skip."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def run():
    if not os.path.exists(RESULTS):
        return [{"bench": "roofline", "status":
                 "dryrun_results.json missing — run repro.launch.dryrun"}]
    recs = json.load(open(RESULTS))
    rows = []
    for r in recs:
        if r.get("mesh") != "16x16":      # roofline table is single-pod
            continue
        if r["status"] != "ok":
            rows.append({"bench": "roofline", "arch": r["arch"],
                         "shape": r["shape"], "status": r["status"]})
            continue
        rows.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "status": "ok",
            "compute_ms": round(1e3 * r["compute_s"], 2),
            "memory_ms": round(1e3 * r["memory_s"], 2),
            "collective_ms": round(1e3 * r["collective_s"], 2),
            "dominant": r["dominant"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "mem_gib_per_dev": round(
                (r["temp_bytes_per_dev"] + r["arg_bytes_per_dev"]) / 2**30, 2),
        })
    return rows
