import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiments import fitted_context  # noqa: E402,F401
