"""Figs. 11-13: inference performance model prediction accuracy
(resource sweep, batch sweep, 4-way co-location), iGniter vs a pairwise
gpu-lets-style model."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fitted_context
from repro.core import perf_model as pm
from repro.serving.workload import models


def _observed(st, hw):
    return st.t_load + st.t_gpu + st.t_feedback


def fig11_resource_sweep():
    """Prediction error vs allocated resources (held-out r, fixed batch)."""
    ctx = fitted_context()
    rows = []
    for name in ("qwen2-vl-7b", "whisper-large-v3"):
        for r in (0.15, 0.25, 0.45, 0.65, 0.9):
            obs = ctx.testbed.run_colocated(
                [(name, 3, r), ("qwen1.5-4b", 3, min(0.95 - r, 0.4))])[0]
            observed = obs.t_load + (obs.t_sched + obs.t_act) * (
                ctx.hw.max_freq / obs.device_freq) + obs.t_feedback
            pred = pm.predict_device(
                [pm.PlacedWorkload(ctx.profiles[name], 3, r),
                 pm.PlacedWorkload(ctx.profiles["qwen1.5-4b"], 3,
                                   min(0.95 - r, 0.4))],
                ctx.hw).per_workload[0].t_inf
            rows.append({
                "bench": "fig11_resource_sweep", "model": name, "r": r,
                "observed_ms": round(observed, 3),
                "predicted_ms": round(pred, 3),
                "err_pct": round(100 * abs(pred - observed) / observed, 2),
            })
    return rows


def fig12_batch_sweep():
    """Prediction error vs batch size at fixed 50% resources."""
    ctx = fitted_context()
    rows = []
    for name in ("rwkv6-1.6b", "qwen1.5-4b"):
        for b in (1, 2, 4, 8, 16, 32):
            obs = ctx.testbed.run_colocated(
                [(name, b, 0.5), ("qwen2-vl-7b", 4, 0.4)])[0]
            observed = obs.t_load + (obs.t_sched + obs.t_act) * (
                ctx.hw.max_freq / obs.device_freq) + obs.t_feedback
            pred = pm.predict_device(
                [pm.PlacedWorkload(ctx.profiles[name], b, 0.5),
                 pm.PlacedWorkload(ctx.profiles["qwen2-vl-7b"], 4, 0.4)],
                ctx.hw).per_workload[0].t_inf
            rows.append({
                "bench": "fig12_batch_sweep", "model": name, "batch": b,
                "observed_ms": round(observed, 3),
                "predicted_ms": round(pred, 3),
                "err_pct": round(100 * abs(pred - observed) / observed, 2),
            })
    return rows


def fig13_four_way():
    """4-way co-location accuracy (gpu-lets' pairwise model cannot run
    this case; iGniter can — the paper's key qualitative claim)."""
    ctx = fitted_context()
    entries = [("rwkv6-1.6b", 4, 0.25), ("qwen1.5-4b", 4, 0.25),
               ("qwen2-vl-7b", 3, 0.25), ("whisper-large-v3", 2, 0.2)]
    obs = ctx.testbed.run_colocated(entries)
    placed = [pm.PlacedWorkload(ctx.profiles[m], b, r)
              for (m, b, r) in entries]
    pred = pm.predict_device(placed, ctx.hw)
    rows = []
    for (m, b, r), o, p in zip(entries, obs, pred.per_workload):
        observed = o.t_load + (o.t_sched + o.t_act) * (
            ctx.hw.max_freq / o.device_freq) + o.t_feedback
        rows.append({
            "bench": "fig13_four_way", "model": m,
            "observed_ms": round(observed, 3),
            "predicted_ms": round(p.t_inf, 3),
            "err_pct": round(100 * abs(p.t_inf - observed) / observed, 2),
            "gpu_lets_supported": False,
        })
    return rows


def run():
    rows = fig11_resource_sweep() + fig12_batch_sweep() + fig13_four_way()
    errs = [r["err_pct"] for r in rows]
    rows.append({"bench": "accuracy_summary",
                 "avg_err_pct": round(float(np.mean(errs)), 2),
                 "max_err_pct": round(float(np.max(errs)), 2),
                 "paper_range_pct": "0.04-9.29 (avg ~4)"})
    return rows
