"""Dynamic-load sweep: static plan vs closed-loop controller over the
trace suite (the runtime half of the paper, Sec. 4.2/4.4).

For each cluster size m, the static queueing-aware plan is simulated
under every trace scenario twice — once as-is and once with the online
controller (`repro.serving.controller.Controller`) driving the
simulator's cluster-scoped ``adjust_fn`` hook — and the rows report
simulated SLO violations (rate targets corrected by each trace's
time-weighted mean scale), reconfiguration counts, controller wall-clock
overhead (``reconfig_latency_ms``, the paper's Sec. 5.5 number), final
plan cost, and simulator throughput.  Since the controller gained
replica scale-out (``split_workload``/``merge_workload`` reconciliation,
docs/control-plane.md) the rows also report ``n_splits`` / ``n_merges``
edit counts and the final plan's replica footprint
(``split_workloads`` / ``n_replicas``) — the r = 1.0 ceiling that used
to cap every diurnal workload at one device's throughput is gone, which
is what the controlled-violations column measures.

Scenarios:
  no_drift   constant-rate control case — the controller must do NOTHING
             (zero reconfigurations, plan bit-identical); enforced by
             --check.
  diurnal    2x smooth ramp over the horizon (deterministic arrivals) —
             the headline closed-loop case: the static plan degrades,
             the controlled plan must violate strictly less.
  spike      2.5x flash crowd for 2 s mid-run (Poisson arrivals) — a
             reactive controller cannot un-blow a short spike's p99, but
             must never be WORSE and drains the backlog faster (the
             per-request violation-rate column shows the win).
  churn      10% of workloads depart / 10% arrive mid-run — exercises
             remove_workload / add_workload reconciliation.
  overload   demand ramps to ~2x an immovable fleet: the plan is
             provisioned normally, then the controller runs with
             ``max_devices`` frozen at that fleet size while the low
             tier (3 of every 4 workloads, priority 0) ramps to
             OVERLOAD_PEAK_LO and the high tier (priority 1) to
             OVERLOAD_PEAK_HI.  The admission layer must degrade
             gracefully: preempt/brownout/shed the low tier, keep the
             high tier's whole-run p99 inside its SLO.  --check gates
             zero high-tier violations plus bounded low-tier shed-rate
             and brownout depth (both reported in the JSON artifact).

The reconciler's Theorem-1 probes are memoized across edits
(`provisioner.ProbeCache`): repeat (spec, budget) probes — the dominant
cost of a reconciliation burst at large m — are O(1) after their first
miss.  Rows report the cache's ``probe_hits`` / ``probe_misses``, and
--check enforces the m=1000 diurnal edit-overhead bound
(``EDIT_TARGET_MS``) the cache is responsible for.  ``--backend jax``
threads the jitted planner + simulator hot paths through the run
(m=10,000 rides the informational CI tier this way).

Run:  PYTHONPATH=src python -m benchmarks.dynamic_sweep [--quick] [--check]
      --quick        m <= 100 only (CI per-PR smoke; uploads artifact)
      --sizes M,...  explicit cluster sizes
      --scenarios s, explicit scenario subset (default: all four)
      --backend B    "numpy" (default) or "jax" planner/simulator backend
      --check        exit non-zero if any scenario's controlled
                     violations exceed the static plan's, if a no-drift
                     run reconfigures at all (or its plan is not
                     bit-identical), if an overload run violates a
                     high-tier SLO or exceeds the low-tier shed/brownout
                     bounds, if an m=1000 controlled sim exceeds the
                     scale_sweep wall-clock bound, or if the m=1000
                     diurnal controller overhead exceeds EDIT_TARGET_MS
      --sim-floor N  exit non-zero if any sim ran below N events/s

--telemetry re-runs each controlled scenario with a `Telemetry`
recorder attached (`repro.serving.telemetry`, docs/observability.md) to
a FRESH controller — the primary controlled run stays telemetry-off so
its wall clock remains the no-observability baseline.  Per scenario it
writes a JSONL event/timeline log plus a self-contained HTML report
(rendered via `benchmarks.telemetry_report`) next to --out, and the
row gains ``telemetry_*`` columns.  Under --check the telemetry run
must (a) reconcile its overflow-immune ``reconfig_events`` counter
against the sim's ``n_reconfigs`` stat — every placement mutation
appears exactly once in the event log — and (b) at m=1000 keep the
telemetry-on wall within TELEMETRY_OVERHEAD_CAP (10%) of the
telemetry-off controlled run.

Writes a JSON row dump (default benchmarks/out/dynamic_sweep_results.json
— gitignored; CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SIZES_FULL = (100, 1000)
SIZES_QUICK = (100,)
SCENARIOS = ("no_drift", "diurnal", "spike", "churn", "overload")
OVERLOAD_HI_EVERY = 4     # every 4th workload is priority 1 (high tier)
OVERLOAD_PEAK_LO = 3.0    # low-tier diurnal peak ...
OVERLOAD_PEAK_HI = 1.3    # ... high-tier peak: aggregate demand ~2x fleet
                          # (the low tier drives the overload; the high
                          # tier's gentle ramp is what the admission layer
                          # must keep whole)
OVERLOAD_SHED_CAP = 0.6   # --check: low-tier shed-rate must stay below
OVERLOAD_BROWNOUT_FRAC = 1.0  # --check: max brownout depth / low-tier count
OVERLOAD_RESERVE = 1.4    # high-tier capacity reservation factor at
                          # provisioning time (> OVERLOAD_PEAK_HI): near
                          # the r = 1.0 ceiling the planner's queueing
                          # model understates rho -> 1 delay, so the
                          # reservation must push ceiling placements into
                          # configurations with real simulated headroom
SIM_TARGET_S = 60.0      # same bound as scale_sweep's m=1000 full sim
EDIT_TARGET_MS = 10000.0  # m=1000 diurnal controller overhead bound:
                          # ~13 s before PR 6 (ProbeCache + vectorized
                          # probe path), ~7 s after
TELEMETRY_OVERHEAD_CAP = 0.10  # --check: m=1000 telemetry-on wall may
                               # exceed telemetry-off by at most 10%
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "dynamic_sweep_results.json")


def _make_trace(scenario: str, names, horizon_ms: float, seed: int):
    from repro.serving import traces
    if scenario == "no_drift":
        return traces.constant(names, horizon_ms), False
    if scenario == "diurnal":
        return traces.diurnal(names, horizon_ms, peak=2.0), False
    if scenario == "spike":
        return traces.step_spike(names, horizon_ms,
                                 at_ms=0.4 * horizon_ms,
                                 duration_ms=0.2 * horizon_ms,
                                 scale=2.5), True
    if scenario == "churn":
        return traces.random_churn(names, horizon_ms, depart_frac=0.1,
                                   arrive_frac=0.1, seed=seed), False
    if scenario == "overload":
        # Priority-split ramp: aggregate demand peaks at ~2x the capped
        # fleet, but the high tier only ramps to OVERLOAD_PEAK_HI so the
        # admission layer can keep it whole by degrading the low tier.
        hi = [n for i, n in enumerate(names) if i % OVERLOAD_HI_EVERY == 0]
        lo = [n for i, n in enumerate(names) if i % OVERLOAD_HI_EVERY != 0]
        t_lo = traces.diurnal(lo, horizon_ms, peak=OVERLOAD_PEAK_LO)
        t_hi = traces.diurnal(hi, horizon_ms, peak=OVERLOAD_PEAK_HI)
        return traces.Trace(edges=t_lo.edges,
                            scales={**t_lo.scales, **t_hi.scales}), False
    raise ValueError(f"unknown scenario {scenario!r}")


def _overload_specs(specs):
    """Same workloads with every OVERLOAD_HI_EVERY-th marked priority 1
    (matching `_make_trace`'s tier split); the rest stay priority 0."""
    import dataclasses
    return [dataclasses.replace(s, priority=1)
            if i % OVERLOAD_HI_EVERY == 0 else s
            for i, s in enumerate(specs)]


def _overload_plan(o_specs, profiles_by_hw, hardware, cfg):
    """Provision the overload fleet with the high tier's rate inflated
    by OVERLOAD_RESERVE (its capacity reservation — what a priority
    tier buys), then rewrite the placements' spec rates back to the
    true base rates so arrivals and controller targets see real
    demand.  The fleet is then frozen at this size: the low tier's
    ramp must be absorbed by admission control, and the gate checks it
    never steals the high tier's reserved headroom (zero whole-run p99
    violations there).  The fleet is pinned to the FIRST (commodity)
    hardware tier: a roomier accelerator would leave enough slack that
    the cap never binds and the scenario measures nothing."""
    import dataclasses
    from repro.core import provisioner as prov
    prov_specs = [dataclasses.replace(s, rate_rps=s.rate_rps
                                      * OVERLOAD_RESERVE)
                  if s.priority > 0 else s for s in o_specs]
    plan, hw = prov.provision_cheapest(prov_specs, profiles_by_hw,
                                       hardware[:1],
                                       config=cfg.replace(replicate=True))
    placements = [
        dataclasses.replace(p, workload=dataclasses.replace(
            p.workload, rate_rps=p.workload.rate_rps / OVERLOAD_RESERVE))
        if p.workload.priority > 0 else p
        for p in plan.placements]
    return dataclasses.replace(plan, placements=placements), hw


def _scaled_specs(specs, tr, horizon_ms):
    """Specs with each rate replaced by its trace-mean expectation, so
    `SimResult.violations`' 95%-of-target rate check measures against
    what the trace actually offered (one violation definition, reused)."""
    import dataclasses
    return {s.name: dataclasses.replace(
        s, rate_rps=s.rate_rps * tr.mean_scale(s.name, horizon_ms))
        for s in specs}


def _violations(res, specs, tr, horizon_ms):
    return res.violations(_scaled_specs(specs, tr, horizon_ms))


def _mean_violation_rate(res, specs) -> float:
    import numpy as np
    rates = res.violation_rates({s.name: s for s in specs})
    return float(np.mean(list(rates.values())))


def sweep(sizes, scenarios, *, seed: int = 0, sim_duration_s: float = 10.0,
          backend: str = "numpy", telemetry: bool = False,
          artifact_dir: str = None):
    from repro.core import provisioner as prov
    from repro.core.experiments import fitted_context
    from repro.core.types import PlannerConfig
    from repro.serving.controller import Controller, ControllerConfig
    from repro.serving.simulator import simulate_full
    from repro.serving.telemetry import Telemetry
    from repro.serving.workload import models, synthetic_workloads

    from benchmarks import telemetry_report

    if telemetry:
        artifact_dir = artifact_dir or os.path.dirname(DEFAULT_OUT)
        os.makedirs(artifact_dir, exist_ok=True)

    cfg = PlannerConfig(backend=backend)
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles_by_hw = {ctx5.hw.name: ctx5.profiles,
                      ctx4.hw.name: ctx4.profiles}
    hardware = [ctx5.hw, ctx4.hw]
    mods = models()
    horizon_ms = sim_duration_s * 1000.0

    rows = []
    for m in sizes:
        specs = synthetic_workloads(m, seed)
        names = [s.name for s in specs]
        t0 = time.perf_counter()
        plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           config=cfg)
        prov_wall = time.perf_counter() - t0
        profiles = profiles_by_hw[hw.name]
        for scenario in scenarios:
            o_specs, o_plan, o_hw = specs, plan, hw
            o_profiles, o_prov_wall, ctl_cfg = profiles, prov_wall, None
            if scenario == "overload":
                # Re-provision with priority annotations, then FREEZE the
                # fleet at the provisioned size: the controller may not
                # buy its way out of the 2x ramp.
                o_specs = _overload_specs(specs)
                t0 = time.perf_counter()
                o_plan, o_hw = _overload_plan(o_specs, profiles_by_hw,
                                              hardware, cfg)
                o_prov_wall = time.perf_counter() - t0
                o_profiles = profiles_by_hw[o_hw.name]
                # aggressive resize headroom: under overload the
                # controller should ask EARLY for the capacity it must
                # claw back from the low tier (demand never exceeds the
                # high tier's reservation, so a refused edit is safe)
                ctl_cfg = ControllerConfig(max_devices=o_plan.n_gpus,
                                           headroom=0.35)
            tr, poisson = _make_trace(scenario, names, horizon_ms, seed)
            t0 = time.perf_counter()
            res_s = simulate_full(o_plan, mods, o_hw,
                                  duration_s=sim_duration_s,
                                  seed=seed, poisson=poisson, trace=tr,
                                  backend=backend)
            static_wall = time.perf_counter() - t0
            ctl = Controller(o_plan, o_profiles, o_hw,
                             config=cfg.replace(batch="joint"),
                             cfg=ctl_cfg)
            t0 = time.perf_counter()
            res_c = simulate_full(o_plan, mods, o_hw,
                                  duration_s=sim_duration_s,
                                  seed=seed, poisson=poisson, trace=tr,
                                  adjust_fn=ctl, adjust_scope="cluster",
                                  adjust_period_s=1.0, backend=backend)
            ctl_wall = time.perf_counter() - t0
            from repro.core import replication
            groups = replication.group_placements(ctl.plan.placements)
            row = {
                "bench": "dynamic_sweep", "m": m, "scenario": scenario,
                "backend": backend,
                "hardware": o_hw.name, "n_devices": o_plan.n_gpus,
                "provision_wall_s": round(o_prov_wall, 3),
                "static_violations": len(_violations(res_s, o_specs, tr,
                                                     horizon_ms)),
                "controlled_violations": len(_violations(res_c, o_specs, tr,
                                                         horizon_ms)),
                "static_violation_rate":
                    round(_mean_violation_rate(res_s, o_specs), 4),
                "controlled_violation_rate":
                    round(_mean_violation_rate(res_c, o_specs), 4),
                "n_reconfigs": int(res_c.stats["n_reconfigs"]),
                "n_edits": len(ctl.edits),
                "n_splits": sum(1 for e in ctl.edits
                                if e.action == "split"),
                "n_merges": sum(1 for e in ctl.edits
                                if e.action == "merge"),
                "split_workloads": sum(1 for g in groups.values()
                                       if len(g) > 1),
                "n_replicas": sum(len(g) for g in groups.values()
                                  if len(g) > 1),
                "reconfig_latency_ms":
                    round(res_c.stats["reconfig_latency_ms"], 1),
                "probe_hits": ctl.reconciler.probes.hits,
                "probe_misses": ctl.reconciler.probes.misses,
                "plan_identical": ctl.plan is o_plan,
                "static_cost_per_hour": round(o_plan.cost_per_hour(), 2),
                "final_cost_per_hour":
                    round(ctl.plan.cost_per_hour(), 2),
                "mean_cost_per_hour": round(
                    sum(c for _, c in ctl.costs)
                    / max(len(ctl.costs), 1), 2),
                "static_sim_wall_s": round(static_wall, 3),
                "controlled_sim_wall_s": round(ctl_wall, 3),
                "sim_events_per_s": round(res_c.stats["events_per_s"]),
                "sim_duration_s": sim_duration_s,
            }
            if scenario == "overload":
                viol = set(_violations(res_c, o_specs, tr, horizon_ms))
                hi = {s.name for s in o_specs if s.priority > 0}
                st = res_c.stats
                row.update({
                    "max_devices": o_plan.n_gpus,
                    "hi_workloads": len(hi),
                    "lo_workloads": len(o_specs) - len(hi),
                    "hi_violations": len(viol & hi),
                    "lo_violations": len(viol - hi),
                    "shed_requests": int(st.get("shed_requests", 0)),
                    "lo_shed_rate": round(st.get("class0_shed_rate",
                                                 0.0), 4),
                    "hi_shed_rate": round(st.get("class1_shed_rate",
                                                 0.0), 4),
                    "hi_violation_rate":
                        round(st.get("class1_violation_rate", 0.0), 4),
                    "brownout_depth_max":
                        int(st.get("brownout_depth_max", 0)),
                    "brownout_ticks": int(st.get("brownout_ticks", 0)),
                    "admission_preemptions":
                        int(st.get("admission_preemptions", 0)),
                    "admission_shed_workloads":
                        int(st.get("admission_shed_workloads", 0)),
                    "admission_readmits":
                        int(st.get("admission_readmits", 0)),
                })
            if scenario in ("no_drift", "spike"):
                # Third run: same plan/trace with the predictive tier on
                # (forecast-armed Sec. 4.2 shadows, docs/control-plane.md).
                # The spike gate wants the forecast-on whole-run violation
                # rate strictly below the reactive controller's — the
                # reactive loop can only drain a 2 s flash crowd's backlog
                # after the fact, while the forecaster pre-sizes and arms
                # standby r before the step lands.  no_drift must stay a
                # no-op: constant-rate Poisson noise never fires the
                # forecaster (zero forecast/shadow_arm events, plan
                # bit-identical).
                import dataclasses
                fc_cfg = (dataclasses.replace(ctl_cfg, forecast=True)
                          if ctl_cfg is not None
                          else ControllerConfig(forecast=True))
                ctl_f = Controller(o_plan, o_profiles, o_hw,
                                   config=cfg.replace(batch="joint"),
                                   cfg=fc_cfg)
                t0 = time.perf_counter()
                res_f = simulate_full(o_plan, mods, o_hw,
                                      duration_s=sim_duration_s,
                                      seed=seed, poisson=poisson, trace=tr,
                                      adjust_fn=ctl_f,
                                      adjust_scope="cluster",
                                      adjust_period_s=1.0, backend=backend)
                fc_wall = time.perf_counter() - t0
                row.update({
                    "forecast_violations": len(_violations(res_f, o_specs,
                                                           tr, horizon_ms)),
                    "forecast_violation_rate":
                        round(_mean_violation_rate(res_f, o_specs), 4),
                    "forecast_n_reconfigs": int(res_f.stats["n_reconfigs"]),
                    "n_forecast_events": sum(1 for e in ctl_f.edits
                                             if e.action == "forecast"),
                    "n_shadow_arms": sum(1 for e in ctl_f.edits
                                         if e.action == "shadow_arm"),
                    "forecast_plan_identical": ctl_f.plan is o_plan,
                    "forecast_sim_wall_s": round(fc_wall, 3),
                })
            if telemetry:
                # Fresh controller + recorder: the primary controlled
                # run above stays telemetry-off, so ctl_wall is the
                # baseline the overhead gate compares against.
                tel = Telemetry()
                ctl_t = Controller(o_plan, o_profiles, o_hw,
                                   config=cfg.replace(batch="joint"),
                                   cfg=ctl_cfg, telemetry=tel)
                t0 = time.perf_counter()
                res_t = simulate_full(o_plan, mods, o_hw,
                                      duration_s=sim_duration_s,
                                      seed=seed, poisson=poisson, trace=tr,
                                      adjust_fn=ctl_t,
                                      adjust_scope="cluster",
                                      adjust_period_s=1.0, backend=backend,
                                      telemetry=tel)
                tel_wall = time.perf_counter() - t0
                stem = os.path.join(artifact_dir,
                                    f"telemetry_m{m}_{scenario}")
                tel.to_jsonl(stem + ".jsonl")
                with open(stem + ".html", "w") as f:
                    f.write(telemetry_report.render_html(
                        telemetry_report.load(stem + ".jsonl")))
                row.update({
                    "telemetry_wall_s": round(tel_wall, 3),
                    "telemetry_overhead": round(
                        (tel_wall - ctl_wall) / max(ctl_wall, 1e-9), 4),
                    "telemetry_events": tel.events.total,
                    "telemetry_reconfig_ok":
                        tel.counters.get("reconfig_events", 0)
                        == int(res_t.stats["n_reconfigs"]),
                    "telemetry_log": stem + ".jsonl",
                })
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    return rows


def run():
    """benchmarks.run integration: the quick tier only."""
    return sweep(SIZES_QUICK, SCENARIOS)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="m <= 100 only (per-PR CI smoke)")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated m values (overrides --quick)")
    ap.add_argument("--scenarios", type=str, default=None,
                    help="comma-separated scenario subset "
                         f"(default: {','.join(SCENARIOS)})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="planner/simulator backend (default: numpy)")
    ap.add_argument("--sim-duration", type=float, default=10.0)
    ap.add_argument("--out", type=str, default=DEFAULT_OUT)
    ap.add_argument("--telemetry", action="store_true",
                    help="re-run each controlled scenario with a "
                         "Telemetry recorder attached; writes per-"
                         "scenario JSONL + HTML artifacts next to --out "
                         "and (with --check) gates the event-log "
                         "reconciliation and the m=1000 overhead cap")
    ap.add_argument("--check", action="store_true",
                    help="fail on controlled > static violations, on any "
                         "no-drift reconfiguration, or on an m=1000 "
                         f"controlled sim over {SIM_TARGET_S:.0f} s")
    ap.add_argument("--sim-floor", type=float, default=0.0,
                    help="fail if any sim ran below this many events/s "
                         "(0 = off)")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = SIZES_QUICK if args.quick else SIZES_FULL
    scenarios = (tuple(args.scenarios.split(",")) if args.scenarios
                 else SCENARIOS)
    rows = sweep(sizes, scenarios, seed=args.seed,
                 sim_duration_s=args.sim_duration, backend=args.backend,
                 telemetry=args.telemetry,
                 artifact_dir=os.path.dirname(os.path.abspath(args.out)))
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {args.out} ({len(rows)} rows)")

    status = 0
    for row in rows:
        tag = f"m={row['m']} {row['scenario']}"
        ok = row["controlled_violations"] <= row["static_violations"]
        print(f"# {tag}: static={row['static_violations']} "
              f"controlled={row['controlled_violations']} "
              f"(rates {row['static_violation_rate']:.3f} -> "
              f"{row['controlled_violation_rate']:.3f}; "
              f"{row['n_reconfigs']} reconfigs, "
              f"{row['n_splits']} splits/{row['n_merges']} merges -> "
              f"{row['n_replicas']} replicas, "
              f"{row['reconfig_latency_ms']:.0f} ms overhead; "
              f"{'PASS' if ok else 'FAIL'})")
        if args.check and not ok:
            status = 1
        if row["scenario"] == "no_drift":
            noop = row["n_reconfigs"] == 0 and row["plan_identical"]
            print(f"# {tag}: no-op check "
                  f"({'PASS' if noop else 'FAIL'}: "
                  f"{row['n_reconfigs']} reconfigs, plan_identical="
                  f"{row['plan_identical']})")
            if args.check and not noop:
                status = 1
        if row["scenario"] == "overload":
            bo_cap = OVERLOAD_BROWNOUT_FRAC * row["lo_workloads"]
            ok_hi = row["hi_violations"] == 0
            ok_shed = row["lo_shed_rate"] <= OVERLOAD_SHED_CAP
            ok_bo = row["brownout_depth_max"] <= bo_cap
            print(f"# {tag}: overload gates hi_violations="
                  f"{row['hi_violations']} (want 0), lo_shed_rate="
                  f"{row['lo_shed_rate']:.3f} (cap {OVERLOAD_SHED_CAP}), "
                  f"brownout_depth_max={row['brownout_depth_max']} "
                  f"(cap {bo_cap:.0f}); {row['shed_requests']} shed, "
                  f"{row['admission_preemptions']} preemptions, "
                  f"{row['admission_readmits']} readmits "
                  f"({'PASS' if ok_hi and ok_shed and ok_bo else 'FAIL'})")
            if args.check and not (ok_hi and ok_shed and ok_bo):
                status = 1
        if "forecast_violations" in row:
            if row["scenario"] == "spike":
                ok_f = (row["forecast_violation_rate"]
                        < row["controlled_violation_rate"]
                        and row["forecast_violations"]
                        <= row["controlled_violations"])
                print(f"# {tag}: forecast gate rate "
                      f"{row['forecast_violation_rate']:.3f} "
                      f"{'<' if ok_f else '!<'} reactive "
                      f"{row['controlled_violation_rate']:.3f} "
                      f"(violations {row['controlled_violations']} -> "
                      f"{row['forecast_violations']}; "
                      f"{row['n_forecast_events']} forecast edits, "
                      f"{row['n_shadow_arms']} shadow arms; "
                      f"{'PASS' if ok_f else 'FAIL'})")
            else:  # no_drift: the forecaster must not fire on Poisson noise
                ok_f = (row["forecast_n_reconfigs"] == 0
                        and row["forecast_plan_identical"]
                        and row["n_forecast_events"] == 0
                        and row["n_shadow_arms"] == 0)
                print(f"# {tag}: forecast no-op check "
                      f"({'PASS' if ok_f else 'FAIL'}: "
                      f"{row['forecast_n_reconfigs']} reconfigs, "
                      f"{row['n_forecast_events']} forecast edits, "
                      f"plan_identical={row['forecast_plan_identical']})")
            if args.check and not ok_f:
                status = 1
        if "telemetry_events" in row:
            ok_rec = row["telemetry_reconfig_ok"]
            print(f"# {tag}: telemetry {row['telemetry_events']} events, "
                  f"wall {row['telemetry_wall_s']:.2f}s "
                  f"({row['telemetry_overhead']:+.1%} vs off), event-log "
                  f"reconciliation {'PASS' if ok_rec else 'FAIL'}")
            if args.check and not ok_rec:
                status = 1
            if row["m"] == 1000:
                ok_ovh = row["telemetry_overhead"] <= TELEMETRY_OVERHEAD_CAP
                print(f"# {tag}: telemetry overhead "
                      f"{row['telemetry_overhead']:.1%} "
                      f"{'<=' if ok_ovh else '>'} "
                      f"{TELEMETRY_OVERHEAD_CAP:.0%} cap "
                      f"({'PASS' if ok_ovh else 'FAIL'})")
                if args.check and not ok_ovh:
                    status = 1
        if row["m"] == 1000:
            fast = row["controlled_sim_wall_s"] < SIM_TARGET_S
            print(f"# {tag}: controlled full sim "
                  f"{row['controlled_sim_wall_s']:.2f}s "
                  f"{'<' if fast else '>='} {SIM_TARGET_S:.0f}s "
                  f"({'PASS' if fast else 'FAIL'})")
            if args.check and not fast:
                status = 1
            if row["scenario"] == "diurnal":
                cheap = row["reconfig_latency_ms"] < EDIT_TARGET_MS
                print(f"# {tag}: controller edit overhead "
                      f"{row['reconfig_latency_ms']:.0f}ms "
                      f"{'<' if cheap else '>='} {EDIT_TARGET_MS:.0f}ms "
                      f"(probe cache {row['probe_hits']} hits / "
                      f"{row['probe_misses']} misses; "
                      f"{'PASS' if cheap else 'FAIL'})")
                if args.check and not cheap:
                    status = 1
        if args.sim_floor and row["sim_events_per_s"] < args.sim_floor:
            print(f"# {tag}: throughput {row['sim_events_per_s']:.0f} "
                  f"events/s < {args.sim_floor:.0f} floor (FAIL)")
            status = 1
    return status


if __name__ == "__main__":
    sys.exit(main())
