"""Figs. 15-17 (GSLICE oscillation + shadow failover) and Fig. 20-21
(heterogeneous selection, provisioner overhead)."""
from __future__ import annotations

import resource
import time

import numpy as np

from benchmarks.common import fitted_context
from repro.core import provisioner as prov
from repro.core.experiments import all_plans, fitted_context as fc
from repro.core.types import V4, V5E, WorkloadSpec
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads


def fig15_17_shadow_failover():
    """Inject a prediction error, record the P99 timeline around the
    shadow switch (paper Fig. 17: recovery within ~1.5 s)."""
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=12.0, shadow=True,
                        record_timeline=True)
    rows = []
    switch_t = None
    for t in res.timeline:
        if t["workload"] != "W1":
            continue
        if t["shadow"] and switch_t is None:
            switch_t = t["t_s"]
        rows.append({
            "bench": "fig17_shadow_timeline", "t_s": round(t["t_s"], 1),
            "p99_1s_ms": round(t["p99_1s"], 1),
            "r_pct": round(100 * t["r"], 1), "shadow": t["shadow"],
        })
    rows.append({
        "bench": "fig17_shadow_timeline", "summary": True,
        "shadow_switch_t_s": switch_t,
        "final_p99_ms": round(res.per_workload["W1"]["p99_ms"], 1),
        "slo_ms": specs_by_name()["W1"].slo_ms,
    })
    return rows[:10] + rows[-1:]


def fig20_heterogeneous():
    """Run Alg. 1 per TPU type and pick the cheaper plan (paper: V100 vs
    T4; here v5e vs the bigger v4-analogue)."""
    rows = []
    specs = twelve_workloads()
    best = None
    for hw_name in ("tpu-v5e", "tpu-v4"):
        ctx = fc(hw_name)
        plan = prov.provision(specs, ctx.profiles, ctx.hw)
        cost = plan.cost_per_hour()
        rows.append({
            "bench": "fig20_heterogeneous", "hardware": hw_name,
            "n_devices": plan.n_gpus, "cost_per_hour": round(cost, 2),
        })
        if best is None or cost < best[1]:
            best = (hw_name, cost)
    rows.append({"bench": "fig20_heterogeneous", "selected": best[0],
                 "cost_per_hour": round(best[1], 2)})
    return rows


def fig21_overhead():
    """Alg. 1 computation time and memory vs #workloads (paper: 4.61 s and
    55 MB at m=1000; complexity O(m^2) time / O(m) space)."""
    ctx = fitted_context()
    rng = np.random.default_rng(0)
    mods = list(ctx.profiles)
    rows = []
    for m in (10, 50, 100, 200, 400):
        specs = [WorkloadSpec(f"W{i}", mods[i % len(mods)],
                              float(rng.uniform(150, 400)),
                              float(rng.uniform(5, 30)))
                 for i in range(m)]
        t0 = time.time()
        plan = prov.provision(specs, ctx.profiles, ctx.hw)
        dt = time.time() - t0
        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
        rows.append({
            "bench": "fig21_overhead", "m_workloads": m,
            "time_s": round(dt, 3), "rss_mb": round(rss, 1),
            "n_devices": plan.n_gpus,
        })
    return rows


def run():
    return fig15_17_shadow_failover() + fig20_heterogeneous() + fig21_overhead()
