"""Beyond-paper ablation: which interference channel matters?

Provision the 12-workload study with the iGniter model but with ONE
interference term zeroed out (scheduler Eq. 6 / cache Eq. 8 / power
Eq. 9), then validate against the full-physics simulator.  Violations
that appear attribute SLO risk to the ablated channel.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import fitted_context
from repro.core import provisioner as prov
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads


def _ablate(ctx, which: str):
    hw = ctx.hw
    profiles = dict(ctx.profiles)
    if which == "scheduler":
        hw = dataclasses.replace(hw, alpha_sch=0.0, beta_sch=0.0)
    elif which == "cache":
        profiles = {k: dataclasses.replace(c, alpha_cache=0.0)
                    for k, c in profiles.items()}
    elif which == "power":
        # pretend nothing draws power -> the model never predicts throttling
        profiles = {k: dataclasses.replace(c, alpha_power=0.0, beta_power=0.0)
                    for k, c in profiles.items()}
    return hw, profiles


def run():
    ctx = fitted_context()
    sb = specs_by_name()
    rows = []
    for which in ("none", "scheduler", "cache", "power", "all"):
        if which == "all":
            hw, profiles = ctx.hw, ctx.profiles
            hw, p2 = _ablate(ctx, "scheduler")
            _, p3 = _ablate(ctx, "cache")
            profiles = {k: dataclasses.replace(
                p2[k], alpha_cache=0.0, alpha_power=0.0, beta_power=0.0)
                for k in p2}
        else:
            hw, profiles = _ablate(ctx, which)
        try:
            plan = prov.provision(twelve_workloads(), profiles, hw)
        except prov.InfeasibleError as e:
            rows.append({"bench": "interference_ablation", "ablated": which,
                         "status": f"infeasible: {e}"})
            continue
        res = simulate_plan(plan, models(), ctx.hw, duration_s=20.0,
                            shadow=False, seed=1)
        viols = res.violations(sb)
        rows.append({
            "bench": "interference_ablation", "ablated": which,
            "n_devices": plan.n_gpus,
            "violations": len(viols), "violating": ",".join(viols),
        })
    return rows
