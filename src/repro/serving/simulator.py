"""Discrete-event GPU-cluster serving simulator (ground truth).

Three roles:

1. **ProfilingTestbed** (`SimTestbed`): what Nsight Systems/Compute +
   nvidia-smi provide on hardware — solo and co-located steady-state runs
   returning per-phase latencies, power, bandwidth utilization.  The
   iGniter coefficients are fit against these.

2. **Serving simulation** (`simulate_plan`): event-driven request/batch/
   serve loop per workload with constant-rate (or Poisson) arrivals,
   greedy dynamic batching up to the configured batch size, spatial
   co-location physics from `repro.serving.physics`, per-request latency
   records (P99), the GSLICE-style reactive controller hook, and the
   iGniter shadow-instance failover (Sec. 4.2).  Two engines:

   * ``engine="vec"`` (default): devices are independent, and between
     monitor/adjust epochs a device's co-location state is static, so
     each instance's pass latency over effective batch nb in [1, b] is
     precomputed in ONE `physics.device_state_batch` call and the event
     loop runs per device as a pass recurrence over pre-generated
     arrival arrays — no global million-entry heap, no per-event
     physics call.  Noise is applied as sampled multipliers on the
     cached base values.  Tables are invalidated on shadow activation
     and after every `adjust_fn` call, so GSLICE/shadow scenarios stay
     exact.
   * ``engine="scalar"``: the original global-heap event loop, kept as
     the oracle — same seed => byte-identical per-request latency
     streams and SimResult metrics (`tests/test_sim_equivalence.py`).

   Both engines draw from per-instance RNG streams
   (``default_rng([seed, i, k])``: k=0 arrivals, k=1 active-time noise,
   k=2 dispatch noise) so no draw depends on cross-device event
   interleaving — that is what makes the per-device loop exact.

   The ``adjust_fn`` hook has a UNIFIED contract across engines (see
   `AdjustFn`): ``adjust_scope="device"`` (default) calls it once per
   device with that device's instances, ``adjust_scope="cluster"`` once
   per period with ALL instances — under either scope and either engine
   the callback sees the same synced state (pending ``queue``,
   ``recent_arrivals`` for the last adjust interval, ``busy_until``,
   ``completed``) and may mutate ``r`` / ``batch`` / ``shadow_r`` /
   ``gpu`` (migration).  Reconfigurations are tracked in
   ``SimResult.stats`` as ``n_reconfigs`` (instances whose placement
   changed at an adjust tick; engine-identical) and
   ``reconfig_latency_ms`` (wall-clock spent inside the callback — the
   controller-overhead number the paper reports in Sec. 5.5).

   Dynamic load: pass a ``repro.serving.traces.Trace`` as ``trace`` to
   replace each workload's constant rate with a piecewise-constant
   schedule (diurnal ramps, flash-crowd spikes, churn).  Arrivals are
   pre-generated in `_setup` from the shared per-instance RNG streams,
   so traced scenarios stay byte-identical across engines too.

   Replica groups (docs/simulator.md): a workload served by replicas
   ``w#0..w#k-1`` draws ONE pooled arrival stream at the summed share
   rate, split rate-proportionally by `_split_stream` (deterministic
   weighted round-robin; Poisson thinning) so each slice is a faithful
   share of the workload's traffic and the pooled stream is exactly
   partitioned.  At adjust ticks `_resync_replicas` re-splits the
   FUTURE tail whenever the controller splits/merges a group or
   appends a fresh replica instance (cluster scope only) — past
   arrivals keep their assignment.  `SimResult.per_workload`,
   `request_latencies` and `violations` merge replicas back to BASE
   names (pooled percentiles, summed rates); `SimResult.per_replica`
   keeps the unmerged view.  A plan with no replicas takes the exact
   pre-replication code paths, byte for byte.

3. **Full-cluster validation** (`simulate_full`): every device of an
   m=1000-scale plan simulated at ground truth with events/sec
   throughput reported in `SimResult.stats` — tracked per PR by
   `benchmarks/scale_sweep.py` next to the model-predicted violations.
"""
from __future__ import annotations

import heapq
import math
import time as _time
from bisect import bisect_right
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import replication
from repro.core.coefficients import ProfileSample
from repro.core.types import HardwareSpec, ProvisioningPlan, WorkloadSpec
from repro.profiling.metrics import ServedModelDesc
from repro.serving import faults as faults_mod
from repro.serving import physics
from repro.serving import telemetry as telemetry_mod
from repro.serving import traces as traces_mod

MONITOR_WINDOW_MS = 1000.0       # P99 monitor lookback (1 s, paper Sec. 4.2)


# ---------------------------------------------------------------------------
# Profiling testbed
# ---------------------------------------------------------------------------

class SimTestbed:
    """ProfilingTestbed over the ground-truth physics (deterministic:
    profiling averages away noise on real hardware too)."""

    def __init__(self, models: Dict[str, ServedModelDesc], hw: HardwareSpec,
                 noisy: bool = False, seed: int = 0):
        self.models = models
        self.hw = hw
        self.rng = np.random.default_rng(seed) if noisy else None

    def _sample(self, desc: ServedModelDesc, b: int, st: physics.TrueState
                ) -> ProfileSample:
        return ProfileSample(
            model=desc.name, batch=b, r=0.0,
            t_load=st.t_load, t_sched=st.t_sched, t_act=st.t_act,
            t_feedback=st.t_feedback, power=st.power,
            cache_util=st.cache_util, n_kernels=desc.n_kernels,
            d_load=desc.d_load_mb * b, d_feedback=desc.d_feedback_mb * b,
            device_freq=st.freq, device_power=st.device_power)

    def run_solo(self, model: str, batch: int, r: float) -> ProfileSample:
        desc = self.models[model]
        st = physics.device_state([(desc, batch, r)], self.hw, self.rng)[0]
        s = self._sample(desc, batch, st)
        return ProfileSample(**{**s.__dict__, "r": r})

    def run_colocated(self, entries: Sequence[Tuple[str, int, float]]
                      ) -> List[ProfileSample]:
        ds = [(self.models[m], b, r) for (m, b, r) in entries]
        sts = physics.device_state(ds, self.hw, self.rng)
        out = []
        for (m, b, r), st in zip(entries, sts):
            s = self._sample(self.models[m], b, st)
            out.append(ProfileSample(**{**s.__dict__, "r": r}))
        return out


# ---------------------------------------------------------------------------
# Discrete-event serving simulation
# ---------------------------------------------------------------------------

@dataclass
class ServedInstance:
    """One serving process (Triton-process analogue) on a device."""
    spec: WorkloadSpec
    desc: ServedModelDesc
    r: float
    batch: int
    gpu: int
    shadow_r: float = 0.0        # extra resources granted when shadow active
    shadow_active: bool = False
    queue: List[float] = field(default_factory=list)   # arrival times
    busy_until: float = 0.0
    latencies: List[float] = field(default_factory=list)
    waits: List[float] = field(default_factory=list)   # serve start - arrival
    completed: int = 0
    # overload admission (docs/control-plane.md): while ``shed`` the
    # front door rejects this instance's arrivals — queued backlog is
    # rejected at the shed tick, new arrivals are counted into
    # ``shed_count`` instead of being served.  Toggled ONLY at adjust
    # boundaries (the controller's tick), which is what keeps shed
    # accounting byte-identical across both engines.  ``slo0`` pins the
    # SLO the instance was CREATED with, so per-class violation
    # accounting stays honest under brownout (loosened plan SLOs).
    shed: bool = False
    shed_count: int = 0
    slo0: float = 0.0
    # arrivals in the last adjust interval, synced before adjust_fn calls
    # (identical across engines: both slice the pre-generated streams)
    recent_arrivals: np.ndarray = field(
        default_factory=lambda: np.empty(0))

    @property
    def r_eff(self) -> float:
        return self.r + (self.shadow_r if self.shadow_active else 0.0)


@dataclass
class SimResult:
    # keyed by BASE workload name: a replica group's requests are merged
    # back into one pooled per-workload record (docs/simulator.md);
    # per_replica keeps the unmerged per-instance view
    per_workload: Dict[str, Dict[str, float]]
    timeline: List[Dict] = field(default_factory=list)
    request_latencies: Dict[str, np.ndarray] = field(default_factory=dict)
    request_waits: Dict[str, np.ndarray] = field(default_factory=dict)
    per_replica: Dict[str, Dict[str, float]] = field(default_factory=dict)
    stats: Dict[str, float] = field(default_factory=dict)

    def _latency_ms(self, name: str, metric) -> float:
        """One latency figure for `metric`: "p99", "avg", or a quantile
        in (0, 1) evaluated over the per-request latency stream."""
        if isinstance(metric, float):
            lats = self.request_latencies.get(name)
            if lats is None or lats.size == 0:
                return math.inf
            return float(np.percentile(lats, 100.0 * metric))
        return self.per_workload[name][f"{metric}_ms"]

    def violations(self, specs: Dict[str, WorkloadSpec], *,
                   metric="p99", check_rate: bool = True) -> List[str]:
        """Workloads violating their SLO at `metric` latency accounting
        ("p99" default, "avg" for mean-latency accounting, or a float
        quantile) and/or missing 95% of the target arrival rate."""
        out = []
        for name, m in self.per_workload.items():
            s = specs[name]
            if (self._latency_ms(name, metric) > s.slo_ms + 1e-9
                    or (check_rate and m["rps"] < 0.95 * s.rate_rps)):
                out.append(name)
        return out

    def violation_rates(self, specs: Dict[str, WorkloadSpec]
                        ) -> Dict[str, float]:
        """Per-workload fraction of individual requests over the SLO."""
        out = {}
        for name, lats in self.request_latencies.items():
            s = specs[name]
            out[name] = (float(np.mean(lats > s.slo_ms))
                         if lats.size else 1.0)
        return out


AdjustFn = Callable[[float, List[ServedInstance]], None]
# Called every `adjust_period` sim-seconds with (now, instances).  The
# grouping is engine-INDEPENDENT and set by ``adjust_scope``:
#   * "device" (default): once per device with that device's instances,
#     sorted by device id — an instance-local/GSLICE-style callback;
#   * "cluster": once per period with ALL instances — what a global
#     controller (repro.serving.controller) needs.
# Under both scopes the callback may mutate r / batch / shadow_r and
# (under any scope) gpu — migrations regroup devices and invalidate the
# vec engine's latency tables for touched devices only.  queue,
# latencies, busy_until, completed and recent_arrivals are synced
# read-only views; mutating them has no effect in the vec engine.


# ---------------------------------------------------------------------------
# Shared helpers (both engines): arrivals, noise, setup, read-out.  These
# being shared is what pins scalar and vec to identical RNG streams.
# ---------------------------------------------------------------------------

def _gen_arrivals(rate_rps: float, horizon_ms: float, poisson: bool,
                  rng: np.random.Generator) -> np.ndarray:
    """All arrival times in [0, horizon) for one instance, pre-generated
    with vectorized RNG.  The stream depends only on (seed, instance)."""
    period = 1000.0 / rate_rps
    t0 = float(rng.uniform(0, period))
    if t0 >= horizon_ms:
        return np.empty(0)
    if not poisson:
        n = int(math.ceil((horizon_ms - t0) / period))
        ts = t0 + period * np.arange(n + 1)
        return ts[ts < horizon_ms]
    chunks = [np.array([t0])]
    last = t0
    est = max(16, int((horizon_ms - t0) / period * 1.2))
    while last < horizon_ms:
        gaps = rng.exponential(period, size=est)
        ts = last + np.cumsum(gaps)
        chunks.append(ts)
        last = float(ts[-1])
        est = max(16, est // 4)
    arr = np.concatenate(chunks)
    return arr[arr < horizon_ms]


class _NoiseStream:
    """Chunk-buffered lognormal multipliers.  Both engines consume the
    same stream through the same chunking, so values match bitwise."""
    __slots__ = ("rng", "sigma", "buf", "k")
    CHUNK = 512

    def __init__(self, rng: np.random.Generator, sigma: float):
        self.rng = rng
        self.sigma = sigma
        self.buf: List[float] = []
        self.k = 0

    def next(self) -> float:
        if self.k >= len(self.buf):
            self.buf = self.rng.lognormal(0.0, self.sigma, self.CHUNK).tolist()
            self.k = 0
        v = self.buf[self.k]
        self.k += 1
        return v


def _noisy_t_inf(t_load: float, t_sch: float, t_act: float, t_fb: float,
                 slow: float, na: float, ns: float) -> float:
    """One serving pass latency from noise-free base values + sampled
    multipliers (na on active time, ns on dispatch)."""
    return t_load + (t_sch * ns + t_act * na) / slow + t_fb


# ---------------------------------------------------------------------------
# Replica groups: arrival-stream splitting (docs/simulator.md).  A base
# workload's requests form ONE pooled stream; replicas `w#0..w#k-1`
# each receive a rate-share slice of it.  Splitting is deterministic
# given (pooled stream, shares, split version) and lives in helpers
# shared by both engines — that is what keeps replicated and runtime-
# split runs byte-identical across the scalar oracle and the vec engine.
# ---------------------------------------------------------------------------

def _split_stream(arr: np.ndarray, fracs: Sequence[float], poisson: bool,
                  rng: np.random.Generator) -> List[np.ndarray]:
    """Partition pooled arrivals among k replicas by rate fraction.

    Deterministic arrivals: weighted round-robin — each replica j with
    fraction f_j owns virtual slots at (m+1)/f_j, and the merged sorted
    slot order (ties to the lower replica index) assigns arrivals
    rate-proportionally with maximal interleaving.  Poisson arrivals:
    i.i.d. thinning — one uniform draw per arrival picks the replica,
    so each slice is itself Poisson at its share rate.  Zero-share
    replicas receive nothing; an all-zero share vector leaves the whole
    stream on replica 0 (a parked group still drains its arrivals).
    """
    k = len(fracs)
    if k == 1:
        return [arr]
    n = arr.size
    if n == 0:
        return [np.empty(0) for _ in range(k)]
    fr = np.asarray(fracs, dtype=np.float64)
    total = float(fr.sum())
    if total <= 0.0:
        return [arr] + [np.empty(0) for _ in range(k - 1)]
    fr = fr / total
    if poisson:
        cum = np.cumsum(fr)
        cum[-1] = max(cum[-1], 1.0)
        u = rng.uniform(0.0, 1.0, size=n)
        assign = np.searchsorted(cum, u, side="right")
    else:
        slots = []
        ids = []
        for j, f in enumerate(fr):
            if f <= 0.0:
                continue
            nj = int(math.ceil(n * f)) + k + 1
            slots.append(np.arange(1.0, nj + 1.0) / f)
            ids.append(np.full(nj, j, dtype=np.int64))
        t = np.concatenate(slots)
        r = np.concatenate(ids)
        order = np.lexsort((r, t))[:n]
        assign = r[order]
    return [arr[assign == j] for j in range(k)]


def _replica_members(instances: List[ServedInstance]
                     ) -> Dict[str, List[int]]:
    """Instance indices grouped by base workload name, in replica order
    (replica index, then instance order — stable across engines)."""
    groups: Dict[str, List[int]] = {}
    for i, inst in enumerate(instances):
        groups.setdefault(replication.base_name(inst.spec.name),
                          []).append(i)
    for base, idxs in groups.items():
        idxs.sort(key=lambda i: (replication.replica_index(
            instances[i].spec.name) or 0, i))
    return groups


class _ReplicaRouter:
    """Book-keeping for pooled base streams and their current split.

    ``base``/``anchor`` hold, per base workload, the pooled arrival
    array and the instance index whose RNG stream generated it (replica
    0 at setup); ``sig`` caches the last applied membership signature —
    member indices plus NORMALIZED shares, so equal-proportion resizes
    never force a pointless re-split; ``version`` counts re-splits to
    key the thinning RNG (``default_rng([seed, anchor, 3, version])``).
    """
    __slots__ = ("seed", "poisson", "base", "anchor", "version", "sig")

    def __init__(self, seed: int, poisson: bool):
        self.seed = seed
        self.poisson = poisson
        self.base: Dict[str, np.ndarray] = {}
        self.anchor: Dict[str, int] = {}
        self.version: Dict[str, int] = {}
        self.sig: Dict[str, tuple] = {}

    @staticmethod
    def signature(members: Sequence[Tuple[int, float]]) -> tuple:
        total = sum(sh for _, sh in members)
        if total <= 0.0:
            total = 1.0
        return tuple((i, round(sh / total, 9)) for i, sh in members)

    def assign_rng(self, base: str) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, self.anchor[base], 3, self.version[base]])


def _resync_replicas(router: _ReplicaRouter,
                     instances: List[ServedInstance],
                     arrivals: List[np.ndarray],
                     now_ms: float) -> List[int]:
    """Re-split changed replica groups' FUTURE arrivals (> now) after an
    adjust tick: splits, merges, renames and appended instances all show
    up as a membership/share-signature change.  Arrivals at or before
    ``now`` keep their existing assignment (they were already queued or
    served).  Returns the instance indices whose arrays changed —
    shared by both engines, so the re-split is exact by construction.
    """
    changed: List[int] = []
    for base, idxs in sorted(_replica_members(instances).items()):
        members = [(i, instances[i].spec.rate_rps) for i in idxs]
        sig = router.signature(members)
        if sig == router.sig.get(base):
            continue
        router.sig[base] = sig
        if base not in router.base:
            continue       # no pooled stream (workload unknown at setup)
        barr = router.base[base]
        tail = barr[int(np.searchsorted(barr, now_ms, side="right")):]
        router.version[base] += 1
        parts = _split_stream(tail, [sh for _, sh in members],
                              router.poisson, router.assign_rng(base))
        for i, part in zip(idxs, parts):
            old = arrivals[i]
            past = old[:int(np.searchsorted(old, now_ms, side="right"))]
            arrivals[i] = np.concatenate([past, part]) \
                if past.size else part
            changed.append(i)
    return changed


def _setup(plan: ProvisioningPlan, models: Dict[str, ServedModelDesc],
           shadow: bool, shadow_extra: float, horizon_ms: float,
           poisson: bool, seed: int,
           trace: Optional["traces_mod.Trace"] = None):
    """Instances, device grouping, per-instance arrival arrays, noise
    streams and the replica router — identical for both engines.  With
    a `trace`, workloads it names (by BASE name) draw their arrivals
    from the piecewise-constant schedule instead of the static rate.

    Replica groups (`w#0..w#k-1`) get ONE pooled stream at the summed
    share rate, generated from replica 0's RNG stream and split by
    `_split_stream`; an unreplicated workload keeps the exact
    pre-replication path (same RNG key, same array), which is what
    makes k=1 plans byte-identical to pre-replication output.
    """
    instances: List[ServedInstance] = []
    for p in plan.placements:
        instances.append(ServedInstance(
            spec=p.workload, desc=models[p.workload.model], r=p.r,
            batch=max(1, p.batch), gpu=p.gpu, slo0=p.workload.slo_ms))
    by_gpu: Dict[int, List[int]] = {}
    for i, inst in enumerate(instances):
        by_gpu.setdefault(inst.gpu, []).append(i)

    if shadow:
        for inst in instances:
            used = sum(instances[k].r for k in by_gpu[inst.gpu])
            inst.shadow_r = min(shadow_extra, max(0.0, 1.0 - used))

    router = _ReplicaRouter(seed, poisson)
    arrivals: List[Optional[np.ndarray]] = [None] * len(instances)
    for base, idxs in _replica_members(instances).items():
        anchor = idxs[0]
        rate = float(sum(instances[i].spec.rate_rps for i in idxs))
        rng = np.random.default_rng([seed, anchor, 0])
        if trace is not None and base in trace.scales:
            edges, scales = trace.segments(base, horizon_ms)
            pooled = traces_mod.gen_arrivals(rate, edges, scales,
                                             horizon_ms, poisson, rng)
        else:
            pooled = _gen_arrivals(rate, horizon_ms, poisson, rng)
        router.base[base] = pooled
        router.anchor[base] = anchor
        router.version[base] = 0
        members = [(i, instances[i].spec.rate_rps) for i in idxs]
        router.sig[base] = router.signature(members)
        if len(idxs) == 1:
            arrivals[anchor] = pooled
        else:
            parts = _split_stream(pooled, [sh for _, sh in members],
                                  poisson, router.assign_rng(base))
            for i, part in zip(idxs, parts):
                arrivals[i] = part
    noise_a = [_NoiseStream(np.random.default_rng([seed, i, 1]),
                            physics.NOISE_SIGMA)
               for i in range(len(instances))]
    noise_s = [_NoiseStream(np.random.default_rng([seed, i, 2]),
                            2 * physics.NOISE_SIGMA)
               for i in range(len(instances))]
    return instances, by_gpu, arrivals, noise_a, noise_s, router


def _epoch_times(horizon_ms: float, monitor_period_s: float,
                 adjust_fn: Optional[AdjustFn], adjust_period_s: float
                 ) -> Tuple[List[float], List[float]]:
    mon = [float(t) for t in np.arange(monitor_period_s * 1000.0, horizon_ms,
                                       monitor_period_s * 1000.0)]
    adj = []
    if adjust_fn is not None:
        adj = [float(t) for t in np.arange(adjust_period_s * 1000.0,
                                           horizon_ms,
                                           adjust_period_s * 1000.0)]
    return mon, adj


def _stats(n_requests: int, n_passes: int, peak_window: int,
           wall0: float, n_reconfigs: int = 0,
           reconfig_ms: float = 0.0) -> Dict[str, float]:
    wall = _time.perf_counter() - wall0
    return {"n_requests": n_requests, "n_passes": n_passes,
            "n_events": n_requests + n_passes, "wall_s": wall,
            "events_per_s": (n_requests + n_passes) / max(wall, 1e-9),
            "peak_window": peak_window,
            # controller overhead accounting (paper Sec. 5.5 analogue):
            # n_reconfigs counts instances whose placement (gpu / r /
            # batch / shadow) changed at an adjust tick — engine-
            # identical; reconfig_latency_ms is adjust_fn wall-clock.
            "n_reconfigs": n_reconfigs,
            "reconfig_latency_ms": reconfig_ms}


def _snap_placement(inst: ServedInstance):
    return (inst.gpu, inst.r, inst.batch, inst.shadow_r,
            inst.shadow_active)


def _call_adjust(adjust_fn: AdjustFn, now_s: float,
                 insts: List[ServedInstance]
                 ) -> Tuple[List[Tuple[ServedInstance, int]],
                            List[ServedInstance], float]:
    """Invoke the callback; return ([(changed_inst, old_gpu)],
    [appended new instances], wall_ms).  A "reconfiguration" is any
    change to an instance's placement tuple (gpu, r, batch, shadow_r,
    shadow_active); a scale-out callback may APPEND fresh
    `ServedInstance`s (replica scale-out) to the list it was handed."""
    n0 = len(insts)
    snaps = [_snap_placement(i) for i in insts]
    t0 = _time.perf_counter()
    adjust_fn(now_s, insts)
    wall_ms = (_time.perf_counter() - t0) * 1000.0
    changed = [(inst, s[0]) for inst, s in zip(insts[:n0], snaps)
               if _snap_placement(inst) != s]
    return changed, list(insts[n0:]), wall_ms


def _dispatch_adjust(adjust_fn: AdjustFn, now_s: float,
                     instances: List[ServedInstance],
                     by_gpu: Dict[int, List[int]], adjust_scope: str
                     ) -> Tuple[List[Tuple[ServedInstance, int]],
                                List[ServedInstance], float]:
    """Scope-aware adjust_fn dispatch, shared by BOTH engines so the
    call grouping/ordering that the byte-identical contract depends on
    lives in exactly one place.  Returns (changed instances with their
    pre-call gpu, appended instances, total wall ms).  Instance
    creation is a cluster-scope capability: under the per-device scope
    the callback only sees throwaway sub-lists, so an append there is
    rejected loudly instead of being dropped."""
    if adjust_scope == "cluster":
        calls = [instances]
    else:
        calls = [[instances[k] for k in by_gpu[g]] for g in sorted(by_gpu)]
    changed_all: List[Tuple[ServedInstance, int]] = []
    new_all: List[ServedInstance] = []
    wall_ms = 0.0
    for insts_c in calls:
        changed, new, dt = _call_adjust(adjust_fn, now_s, insts_c)
        if new and adjust_scope != "cluster":
            raise RuntimeError(
                "adjust_fn appended instances under adjust_scope="
                "'device'; replica scale-out requires "
                "adjust_scope='cluster'")
        changed_all.extend(changed)
        new_all.extend(new)
        wall_ms += dt
    return changed_all, new_all, wall_ms


def _emit_reconfigs(telemetry, now_ms: float,
                    changed: List[Tuple[ServedInstance, int]],
                    new: List[ServedInstance], wall_ms: float) -> None:
    """One typed ``reconfig`` event per placement mutation the adjust
    tick actually applied — the same (changed, new) sets `n_reconfigs`
    counts, shared by both engines, so the event log reconciles
    EXACTLY against ``SimResult.stats["n_reconfigs"]`` (the overflow-
    immune ``reconfig_events`` counter survives ring eviction).
    ``wall_ms`` is the tick's adjust_fn wall (host-side; excluded from
    the engine-identity contract)."""
    t_s = now_ms / 1000.0
    for inst, old_g in changed:
        telemetry.record_event(telemetry_mod.ControlEvent(
            t_s=t_s, kind="reconfig", workload=inst.spec.name,
            cause="adjust", post=((inst.gpu, inst.batch, inst.r),),
            gpu_from=old_g, gpu_to=inst.gpu, wall_ms=wall_ms))
    for inst in new:
        telemetry.record_event(telemetry_mod.ControlEvent(
            t_s=t_s, kind="reconfig", workload=inst.spec.name,
            cause="scale_out", post=((inst.gpu, inst.batch, inst.r),),
            gpu_from=-1, gpu_to=inst.gpu, wall_ms=wall_ms))


def _sync_recent_arrivals(instances: List[ServedInstance],
                          arrivals: List[np.ndarray], now: float,
                          window_ms: float) -> None:
    """Expose each instance's arrivals in (now - window, now] — the raw
    material for the controller's rate/burstiness estimators."""
    lo = now - window_ms
    for i, inst in enumerate(instances):
        a = arrivals[i]
        j0 = int(np.searchsorted(a, lo, side="right"))
        j1 = int(np.searchsorted(a, now, side="right"))
        inst.recent_arrivals = a[j0:j1]


def _regroup(instances: List[ServedInstance]) -> Dict[int, List[int]]:
    by_gpu: Dict[int, List[int]] = {}
    for i, inst in enumerate(instances):
        by_gpu.setdefault(inst.gpu, []).append(i)
    return by_gpu


def _attach_canary(adjust_fn: Optional[AdjustFn], fstate) -> None:
    """Hand a health-probe canary to a controller-style callback.

    The canary answers "run one reference pass on idle device ``gpu``
    at ``now_ms`` — what is measured/predicted?": the device's active
    straggler multiplier (noise averages away exactly as in profiling),
    ``inf`` while the device is down, 1.0 when clean.  Computed from the
    fault schedule BOTH engines share, so probe-readmission decisions
    are deterministic and engine-identical.  Callbacks without an
    ``attach_canary`` method are untouched (hook is opt-in)."""
    if adjust_fn is None:
        return
    attach = getattr(adjust_fn, "attach_canary", None)
    if not callable(attach):
        return

    def canary(gpu: int, now_ms: float) -> float:
        if fstate is None:
            return 1.0
        fl = fstate.dev.get(gpu)
        if fl is None:
            return 1.0
        starts, ends, mult = fl
        if starts:
            kf = bisect_right(starts, now_ms) - 1
            if kf >= 0 and now_ms < ends[kf]:
                return math.inf
        return mult

    attach(canary)


def _merge_overload_stats(adjust_fn: Optional[AdjustFn],
                          stats: Dict[str, float]) -> None:
    """Fold a controller-style callback's admission-layer report
    (brownout depth, shed/preemption counts) into ``stats``.  Callbacks
    without ``overload_stats``, and controllers whose admission layer
    took ZERO actions, contribute nothing — the cap-slack run's stats
    stay byte-identical to the pre-overload build."""
    if adjust_fn is None:
        return
    rep = getattr(adjust_fn, "overload_stats", None)
    if not callable(rep):
        return
    extra = rep()
    if extra:
        stats.update(extra)


class _FaultState:
    """Runtime fault bookkeeping shared by BOTH engines (docstring
    semantics in `repro.serving.faults`).

    The schedule is pure data, so every decision here depends only on
    (schedule, arrival arrays, instance->device assignment at the
    boundary) — never on served-request state.  That is what keeps
    fault runs byte-identical across engines: the scalar heap may serve
    a chained pass at exactly a boundary time before the fault event
    (done has lower priority), the vec engine defers it to the next
    epoch, and neither order can change any outcome below.

    * **Fail boundary**: replicas of a >=2-member group resident on the
      failed device have their rate share zeroed (the pre-fail share is
      saved) and `_resync_replicas` re-splits the pooled stream's
      future tail, so surviving replicas absorb the dead one's traffic.
      Solo workloads keep their stream and accumulate backlog.
    * **Restart boundary**: saved shares are restored (unless the
      controller re-owned the spec in between — its plan rates win) and
      the tail re-splits back.  Recovery accounting marks, per instance
      resident on the device at restart, how many of its arrivals
      predate the restart: the outage's recovery time is how long past
      the restart the last of those requests completes (0 when the
      controller migrated everyone away first).
    """

    def __init__(self, fs: "faults_mod.FaultSchedule"):
        self.fs = fs
        # gpu -> (fail starts, restart ends, straggler multiplier);
        # plain lists for bisect in the hot pass loops
        self.dev: Dict[int, Tuple[List[float], List[float], float]] = {}
        for g in set(fs.down) | set(fs.slow):
            iv = fs.down.get(g)
            starts = [float(x) for x in iv[:, 0]] if iv is not None else []
            ends = [float(x) for x in iv[:, 1]] if iv is not None else []
            self.dev[g] = (starts, ends, fs.multiplier(g))
        self.saved: Dict[int, float] = {}      # inst idx -> pre-fail share
        # (restart_ms, [(inst idx, #arrivals <= restart)]) per outage
        self.outages: List[Tuple[float, List[Tuple[int, int]]]] = []

    def on_fail(self, g: int, now: float, instances, by_gpu, router,
                arrivals) -> List[int]:
        """Zero the shares of replicas on g; returns re-split indices."""
        groups = _replica_members(instances)
        changed = False
        for i in by_gpu.get(g, []):
            inst = instances[i]
            base = replication.base_name(inst.spec.name)
            if len(groups.get(base, ())) < 2:
                continue
            if inst.spec.rate_rps > 0.0:
                self.saved[i] = inst.spec.rate_rps
                inst.spec = replace(inst.spec, rate_rps=0.0)
                changed = True
        return _resync_replicas(router, instances, arrivals, now) \
            if changed else []

    def on_restart(self, g: int, now: float, instances, by_gpu, router,
                   arrivals) -> List[int]:
        """Record recovery marks, restore saved shares; re-split."""
        members = by_gpu.get(g, [])
        self.outages.append((now, [
            (i, int(np.searchsorted(arrivals[i], now, side="right")))
            for i in members]))
        restored = False
        for i in members:
            saved = self.saved.pop(i, None)
            if saved is not None and instances[i].spec.rate_rps == 0.0:
                instances[i].spec = replace(instances[i].spec,
                                            rate_rps=saved)
                restored = True
        return _resync_replicas(router, instances, arrivals, now) \
            if restored else []

    def fault_stats(self, dones: List[List[float]], horizon_ms: float,
                    n_requests: int, n_served: int) -> Dict[str, float]:
        """Downtime / lost-request / recovery accounting for
        `SimResult.stats` — computed from arrival counts and completion
        stamps both engines agree on bitwise."""
        rec = []
        for (r, marks) in self.outages:
            worst = 0.0
            for (i, n) in marks:
                if n <= 0:
                    continue           # nothing pending at the restart
                dn = dones[i]
                late = dn[n - 1] - r if n <= len(dn) else horizon_ms - r
                if late > worst:
                    worst = late
            rec.append(max(0.0, worst))
        return {
            "n_failures": self.fs.n_failures(horizon_ms),
            "downtime_ms": self.fs.downtime_ms(horizon_ms),
            "lost_requests": n_requests - n_served,
            "n_recoveries": len(rec),
            "recovery_mean_ms": float(np.mean(rec)) if rec else 0.0,
        }


def _finalize(instances: List[ServedInstance], duration_s: float,
              timeline: List[Dict], stats: Dict[str, float]) -> SimResult:
    per = {}
    req = {}
    wts = {}
    per_rep = {}
    groups = _replica_members(instances)
    for base, idxs in groups.items():
        members = [instances[i] for i in idxs]
        # replica-merged per-workload accounting: one pooled request
        # stream per BASE workload (singleton groups reproduce the
        # pre-replication records bit-for-bit)
        lat_parts = [np.asarray(m.latencies) for m in members]
        wait_parts = [np.asarray(m.waits) for m in members]
        pooled_lat = np.concatenate(lat_parts) if len(members) > 1 \
            else lat_parts[0]
        pooled_wait = np.concatenate(wait_parts) if len(members) > 1 \
            else wait_parts[0]
        lats = pooled_lat if pooled_lat.size else np.array([np.inf])
        waits = pooled_wait if pooled_wait.size else np.array([np.inf])
        per[base] = {
            "p99_ms": float(np.percentile(lats, 99)),
            "p50_ms": float(np.percentile(lats, 50)),
            "avg_ms": float(np.mean(lats)),
            "wait_avg_ms": float(np.mean(waits)),
            "wait_p99_ms": float(np.percentile(waits, 99)),
            "rps": sum(m.completed for m in members) / duration_s,
            "r_final": sum(m.r_eff for m in members),
            "batch_final": members[0].batch,
            "shadow_used": any(m.shadow_active for m in members),
            "n_replicas": len(members),
        }
        req[base] = pooled_lat
        wts[base] = pooled_wait
        if len(members) > 1 or replication.is_replica(
                members[0].spec.name):
            for m in members:
                m_lats = np.asarray(m.latencies)
                per_rep[m.spec.name] = {
                    "p99_ms": float(np.percentile(m_lats, 99))
                    if m_lats.size else math.inf,
                    "rps": m.completed / duration_s,
                    "rate_share_rps": m.spec.rate_rps,
                    "r_final": m.r_eff,
                    "batch_final": m.batch,
                    "gpu": m.gpu,
                }
    # cluster-wide end-to-end latency + queueing-delay aggregates: the
    # measured counterpart of the provisioner's t_queue budget term
    all_lats = np.concatenate([v for v in req.values() if v.size]) \
        if any(v.size for v in req.values()) else np.array([np.inf])
    all_waits = np.concatenate([v for v in wts.values() if v.size]) \
        if any(v.size for v in wts.values()) else np.array([np.inf])
    stats = dict(stats)
    stats.update({
        "e2e_p50_ms": float(np.percentile(all_lats, 50)),
        "e2e_p99_ms": float(np.percentile(all_lats, 99)),
        "wait_mean_ms": float(np.mean(all_waits)),
        "wait_p99_ms": float(np.percentile(all_waits, 99)),
    })
    # Overload accounting — GATED: every key below is absent unless a
    # request was actually shed or the controller reported admission
    # activity, which is what keeps cap-slack runs byte-identical to
    # pre-overload output.  Violation rates are measured against each
    # instance's CREATION-time SLO (``slo0``), so a brownout (loosened
    # working SLO) can never hide a violation from the per-class stats.
    total_shed = sum(inst.shed_count for inst in instances)
    if total_shed > 0 or stats.get("overload_active"):
        stats["shed_requests"] = float(total_shed)
        by_class: Dict[int, List[str]] = {}
        for base, idxs in groups.items():
            members = [instances[i] for i in idxs]
            per[base]["shed_requests"] = float(
                sum(m.shed_count for m in members))
            by_class.setdefault(int(members[0].spec.priority),
                                []).append(base)
        for pr, bases in sorted(by_class.items()):
            viol = served = shed = 0
            for b in bases:
                idxs = groups[b]
                slo0 = instances[idxs[0]].slo0
                viol += int(np.sum(req[b] > slo0))
                served += int(req[b].size)
                shed += sum(instances[i].shed_count for i in idxs)
            stats[f"class{pr}_workloads"] = float(len(bases))
            stats[f"class{pr}_violation_rate"] = \
                viol / served if served else 0.0
            stats[f"class{pr}_shed_rate"] = \
                shed / (served + shed) if (served + shed) else 0.0
    return SimResult(per_workload=per, timeline=timeline,
                     request_latencies=req, request_waits=wts,
                     per_replica=per_rep, stats=stats)


# ---------------------------------------------------------------------------
# Scalar oracle engine: one global event heap, one physics call per pass.
# ---------------------------------------------------------------------------

def _simulate_scalar(plan, models, hw, *, duration_s, seed, poisson, shadow,
                     shadow_extra, monitor_period_s, adjust_fn,
                     adjust_period_s, record_timeline, adjust_scope,
                     trace, faults, telemetry=None) -> SimResult:
    wall0 = _time.perf_counter()
    horizon = duration_s * 1000.0                      # ms
    instances, by_gpu, arrivals, noise_a, noise_s, router = _setup(
        plan, models, shadow, shadow_extra, horizon, poisson, seed, trace)
    fstate = _FaultState(faults) \
        if faults is not None and (faults.down or faults.slow) else None
    _attach_canary(adjust_fn, fstate)
    shed_prev = [False] * len(instances)

    # (t, prio, seq, kind, idx, ver): the kind priority pins the same-
    # time ordering the setup-time push order used to imply (arrival <
    # monitor < adjust < done < fault), so arrivals re-pushed MID-RUN by
    # a replica re-split keep the arrival-before-boundary contract the
    # vec engine's run_passes assumes
    events: List[Tuple[float, int, int, str, int, int]] = []
    seq = 0
    _PRIO = {"arrival": 0, "monitor": 1, "adjust": 2, "done": 3,
             "fault": 4}

    def push(t, kind, idx, ver=0):
        nonlocal seq
        heapq.heappush(events, (t, _PRIO[kind], seq, kind, idx, ver))
        seq += 1

    for i, arr in enumerate(arrivals):
        for t in arr.tolist():
            push(t, "arrival", i)
    # per-instance arrival-stream version: a replica re-split bumps it
    # and re-pushes the new tail, orphaning the stale queued events
    arr_ver = [0] * len(instances)
    mon, adj = _epoch_times(horizon, monitor_period_s, adjust_fn,
                            adjust_period_s)
    for t in mon:
        push(t, "monitor", -1)
    for t in adj:
        push(t, "adjust", -1)
    # fault boundaries: idx carries the DEVICE id, ver 0=fail 1=restart.
    # Restart events past the horizon still fire (the heap drains all
    # arrivals), mirroring the vec engine's final infinite epoch.
    if fstate is not None:
        for (tb, g, up) in fstate.fs.boundaries():
            push(tb, "fault", g, 1 if up else 0)
    # per-instance completion stamps, recovery accounting only (the vec
    # engine keeps these always as its monitor-window index)
    fault_dones: Optional[List[List[float]]] = \
        [[] for _ in instances] if fstate is not None else None

    timeline: List[Dict] = []
    # last-window latencies, pruned each monitor tick (bounded deque, NOT
    # an ever-growing list): (done_time, latency, wait) per request
    recent: List[deque] = [deque() for _ in instances]
    n_passes = 0
    peak_window = 0
    n_reconfigs = 0
    adjust_wall_ms = 0.0
    adj_window_ms = adjust_period_s * 1000.0

    def pass_latency(inst: ServedInstance, nb: int) -> physics.TrueState:
        peers = [instances[k] for k in by_gpu[inst.gpu]
                 if instances[k] is not inst]
        entries = [(inst.desc, nb, inst.r_eff)] + \
            [(p.desc, p.batch, p.r_eff) for p in peers]
        return physics.device_state(entries, hw)[0]

    def try_serve(i: int, now: float):
        nonlocal n_passes
        inst = instances[i]
        if not inst.queue or inst.busy_until > now:
            return
        fmult = 1.0
        if fstate is not None:
            fl = fstate.dev.get(inst.gpu)
            if fl is not None:
                fstarts, fends, fmult = fl
                if fstarts:
                    kf = bisect_right(fstarts, now) - 1
                    if kf >= 0 and now < fends[kf]:
                        return     # device down: backlog waits for the
                                   # restart wake (or is lost forever)
        nb = min(inst.batch, len(inst.queue))
        taken, inst.queue = inst.queue[:nb], inst.queue[nb:]
        st = pass_latency(inst, nb)
        slow = st.freq / hw.max_freq
        na = noise_a[i].next()
        ns = noise_s[i].next()
        t_inf = _noisy_t_inf(st.t_load, st.t_sched, st.t_act, st.t_feedback,
                             slow, na, ns)
        if fmult != 1.0:
            t_inf *= fmult         # straggler: the model never knows
        done = now + t_inf
        inst.busy_until = done
        for arr in taken:
            lat = done - arr
            inst.latencies.append(lat)
            inst.waits.append(now - arr)
            recent[i].append((done, lat, now - arr))
        if fault_dones is not None:
            fault_dones[i].extend([done] * nb)
        inst.completed += nb
        n_passes += 1
        push(done, "done", i)

    while events:
        now, _, _, kind, idx, ver = heapq.heappop(events)
        if kind == "arrival":
            if ver != arr_ver[idx]:
                continue               # stale stream (re-split tail)
            if instances[idx].shed:
                # admission layer rejects at the front door: counted,
                # never queued, never served (docs/control-plane.md)
                instances[idx].shed_count += 1
                continue
            instances[idx].queue.append(now)
            try_serve(idx, now)
        elif kind == "done":
            try_serve(idx, now)
        elif kind == "monitor":
            cutoff = now - MONITOR_WINDOW_MS
            tl_rows = [] if telemetry is not None else None
            for i, inst in enumerate(instances):
                dq = recent[i]
                while dq and dq[0][0] <= cutoff:
                    dq.popleft()
                # the monitor sees COMPLETED requests only: a pass still
                # in flight has its (done, lat) records stamped in the
                # future, and with passes longer than the lookback the
                # window is legitimately empty between completions
                window = [l for (d, l, _) in dq if d <= now]
                peak_window = max(peak_window, len(window))
                if tl_rows is not None:
                    # done stamps are nondecreasing per instance, so the
                    # window is exactly the first len(window) entries
                    k = len(window)
                    stamps_w: List[float] = []
                    waits_w: List[float] = []
                    for (d, _, wt) in dq:
                        if len(stamps_w) >= k:
                            break
                        stamps_w.append(d)
                        waits_w.append(wt)
                    tl_rows.append((i, window, waits_w, stamps_w,
                                    len(inst.queue)))
                if record_timeline:
                    timeline.append({
                        "t_s": now / 1000.0, "workload": inst.spec.name,
                        "p99_1s": float(np.percentile(window, 99)) if window else 0.0,
                        "avg_1s": float(np.mean(window)) if window else 0.0,
                        "r": inst.r_eff, "batch": inst.batch,
                        "rps_1s": len(window) / 1.0,
                        "shadow": inst.shadow_active,
                    })
                # Sec. 4.2 activation: simulator-armed (shadow=True) OR
                # controller-armed (inst.shadow_r set by the predictive
                # tier) — per-instance, so a run with nothing armed
                # evaluates exactly as before
                if ((shadow or inst.shadow_r > 0.0) and window
                        and not inst.shadow_active):
                    if float(np.percentile(window, 99)) > inst.spec.slo_ms:
                        # switch to the pre-launched shadow process (Sec. 4.2)
                        inst.shadow_active = True
            if tl_rows is not None:
                telemetry.sample_tick(now, instances, by_gpu, hw, tl_rows)
        elif kind == "adjust" and adjust_fn is not None:
            _sync_recent_arrivals(instances, arrivals, now, adj_window_ms)
            n_before = len(instances)
            changed, new, wall_ms = _dispatch_adjust(
                adjust_fn, now / 1000.0, instances, by_gpu, adjust_scope)
            n_reconfigs += len(changed) + len(new)
            adjust_wall_ms += wall_ms
            if telemetry is not None:
                _emit_reconfigs(telemetry, now, changed, new, wall_ms)
                telemetry.add_wall("sim_adjust", wall_ms)
            for j in range(n_before, len(instances)):
                # appended replica: fresh per-instance RNG streams keyed
                # by its (new, never-reused) global index — the vec
                # engine derives the identical keys
                noise_a.append(_NoiseStream(
                    np.random.default_rng([seed, j, 1]),
                    physics.NOISE_SIGMA))
                noise_s.append(_NoiseStream(
                    np.random.default_rng([seed, j, 2]),
                    2 * physics.NOISE_SIGMA))
                arrivals.append(np.empty(0))
                recent.append(deque())
                arr_ver.append(0)
                shed_prev.append(False)
                if fault_dones is not None:
                    fault_dones.append([])
            for i, inst in enumerate(instances):
                if inst.shed and not shed_prev[i]:
                    # shedding starts at this tick: the queued backlog
                    # is rejected too (not yet admitted to a pass); the
                    # in-flight pass, if any, completes
                    inst.shed_count += len(inst.queue)
                    inst.queue.clear()
                shed_prev[i] = inst.shed
            for i in _resync_replicas(router, instances, arrivals, now):
                arr_ver[i] += 1
                a = arrivals[i]
                for t in a[np.searchsorted(a, now, side="right"):].tolist():
                    push(t, "arrival", i, arr_ver[i])
            if new or any(old_g != inst.gpu for inst, old_g in changed):
                by_gpu = _regroup(instances)
            if fstate is not None and changed:
                # migration of a fault-blocked backlog: without faults,
                # a non-empty queue implies busy_until >= now, so this
                # clamp is a no-op in clean runs.  With it, the backlog
                # serves on the NEW device at the tick (the wake event),
                # exactly when the vec recurrence resumes it — never at
                # a pre-migration arrival stamp.
                pos = {id(inst): k for k, inst in enumerate(instances)}
                for inst, old_g in changed:
                    if old_g != inst.gpu and inst.busy_until < now:
                        inst.busy_until = now
                        push(now, "done", pos[id(inst)])
        elif kind == "fault":
            g = idx
            if ver == 1:
                resynced = fstate.on_restart(g, now, instances, by_gpu,
                                             router, arrivals)
            else:
                resynced = fstate.on_fail(g, now, instances, by_gpu,
                                          router, arrivals)
            for i in resynced:
                arr_ver[i] += 1
                a = arrivals[i]
                for t in a[np.searchsorted(a, now, side="right"):].tolist():
                    push(t, "arrival", i, arr_ver[i])
            if ver == 1:
                for i in by_gpu.get(g, []):
                    try_serve(i, now)      # restart wake: drain backlog

    if telemetry is not None:
        # per-pass scalar physics calls: the oracle's "dispatch" unit
        # (engine-specific by design, like the vec table-build counts)
        telemetry.count("dispatch_scalar", n_passes)
    stats = _stats(sum(len(a) for a in arrivals), n_passes, peak_window,
                   wall0, n_reconfigs, adjust_wall_ms)
    if fstate is not None:
        stats.update(fstate.fault_stats(
            fault_dones, horizon, sum(len(a) for a in arrivals),
            sum(inst.completed for inst in instances)))
    _merge_overload_stats(adjust_fn, stats)
    return _finalize(instances, duration_s, timeline, stats)


# ---------------------------------------------------------------------------
# Vectorized engine: per-device pass recurrence over cached latency tables.
# ---------------------------------------------------------------------------

class _LatTable:
    """Per-instance pass-latency base values over effective batch
    nb in [1, b], from ONE `device_state_batch` call.  Valid while the
    device's co-location state (peer batch/r_eff, own r_eff/batch cap)
    is unchanged — i.e. between shadow activations / adjust_fn calls."""
    __slots__ = ("t_load", "t_sch", "t_act", "t_fb", "slow")

    def __init__(self, inst: ServedInstance, peers: List[ServedInstance],
                 hw: HardwareSpec):
        descs = [inst.desc] + [p.desc for p in peers]
        bmax = max(1, inst.batch)
        n = len(descs)
        b = np.empty((bmax, n))
        r = np.empty((bmax, n))
        b[:, 0] = np.arange(1, bmax + 1)
        r[:, 0] = inst.r_eff
        for j, p in enumerate(peers):
            b[:, j + 1] = p.batch
            r[:, j + 1] = p.r_eff
        st = physics.device_state_batch(descs, b, r, hw)
        self.t_load = st.t_load[:, 0].tolist()
        self.t_sch = st.t_sched[:, 0].tolist()
        self.t_act = st.t_act[:, 0].tolist()
        self.t_fb = st.t_feedback[:, 0].tolist()
        self.slow = (st.freq / hw.max_freq).tolist()

    @classmethod
    def from_values(cls, t_load, t_sch, t_act, t_fb, slow) -> "_LatTable":
        """Table from precomputed columns (`_build_tables_bulk`)."""
        self = cls.__new__(cls)
        self.t_load, self.t_sch, self.t_act, self.t_fb, self.slow = (
            t_load, t_sch, t_act, t_fb, slow)
        return self


_BULK_CHUNK = 1 << 19    # max rows*n per bulk physics call (~50 MB live)


def _build_tables_bulk(instances: List[ServedInstance],
                       groups: Dict[int, List[int]], hw: HardwareSpec,
                       backend: str = "numpy") -> Dict[int, "_LatTable"]:
    """Latency tables for every instance of ``groups`` in a handful of
    `physics.device_state_arrays` calls instead of one per instance.

    Jobs are bucketed by co-location width n (self + peers): within a
    bucket every row reduces over a last axis of exactly n entries —
    the same grouping the per-device `_LatTable` build sees — so the
    numpy backend is bitwise-identical to it, device by device.  Chunks
    bound transient memory at ~`_BULK_CHUNK` elements per array; rows
    are independent, so chunking cannot change results.  With
    ``backend="jax"`` each chunk is evaluated by the jitted twin
    (`physics_jax.table_values`, <= 1e-6 relative vs numpy), with the
    row count padded to a power of two to bound recompilation.
    """
    tables: Dict[int, _LatTable] = {}
    buckets: Dict[int, List[Tuple[int, List[int]]]] = {}
    for g, idxs in groups.items():
        for i in idxs:
            cols = [i] + [k for k in idxs if k != i]
            buckets.setdefault(len(cols), []).append((i, cols))
    for n, jobs in sorted(buckets.items()):
        start = 0
        while start < len(jobs):
            end, rows = start, 0
            while end < len(jobs):
                bmax = max(1, instances[jobs[end][0]].batch)
                if rows and (rows + bmax) * n > _BULK_CHUNK:
                    break
                rows += bmax
                end += 1
            _build_tables_chunk(instances, jobs[start:end], n, rows, hw,
                                backend, tables)
            start = end
    return tables


def _build_tables_chunk(instances: List[ServedInstance],
                        jobs: List[Tuple[int, List[int]]], n: int,
                        rows: int, hw: HardwareSpec, backend: str,
                        tables: Dict[int, "_LatTable"]) -> None:
    R = rows
    if backend == "jax":       # stable jit shapes: pad rows to 2^k
        R = 1 << (rows - 1).bit_length() if rows > 1 else 1
    b = np.empty((R, n))
    r = np.empty((R, n))
    consts = [np.empty((R, n)) for _ in range(6)]
    d_load, d_fb, flops_i, w_bytes, a_bytes, n_kern = consts
    blocks: List[Tuple[int, int, int]] = []
    row = 0
    for (i, cols) in jobs:
        inst = instances[i]
        bmax = max(1, inst.batch)
        sl = slice(row, row + bmax)
        b[sl, 0] = np.arange(1, bmax + 1)
        r[sl, 0] = inst.r_eff
        for j, k in enumerate(cols[1:]):
            b[sl, j + 1] = instances[k].batch
            r[sl, j + 1] = instances[k].r_eff
        for j, k in enumerate(cols):
            dsc = instances[k].desc
            d_load[sl, j] = dsc.d_load_mb
            d_fb[sl, j] = dsc.d_feedback_mb
            flops_i[sl, j] = dsc.flops_per_item
            w_bytes[sl, j] = dsc.weight_bytes
            a_bytes[sl, j] = dsc.act_bytes_per_item
            n_kern[sl, j] = float(dsc.n_kernels)
        blocks.append((i, row, bmax))
        row += bmax
    if R > rows:               # benign values in the padding rows
        for a in (b, r, *consts):
            a[rows:] = a[0]
    if backend == "jax":
        from repro.serving import physics_jax
        t_load, t_sch, t_act, t_fb, freq = physics_jax.table_values(
            d_load, d_fb, flops_i, w_bytes, a_bytes, n_kern, b, r, n, hw)
    else:
        st = physics.device_state_arrays(d_load, d_fb, flops_i, w_bytes,
                                         a_bytes, n_kern, b, r, n, hw)
        t_load, t_sch, t_act, t_fb, freq = (st.t_load, st.t_sched,
                                            st.t_act, st.t_feedback,
                                            st.freq)
    slow = freq / hw.max_freq
    for (i, row0, bmax) in blocks:
        sl = slice(row0, row0 + bmax)
        tables[i] = _LatTable.from_values(
            t_load[sl, 0].tolist(), t_sch[sl, 0].tolist(),
            t_act[sl, 0].tolist(), t_fb[sl, 0].tolist(),
            slow[sl].tolist())


def _simulate_vec(plan, models, hw, *, duration_s, seed, poisson, shadow,
                  shadow_extra, monitor_period_s, adjust_fn,
                  adjust_period_s, record_timeline, adjust_scope,
                  trace, faults, telemetry=None,
                  backend="numpy") -> SimResult:
    wall0 = _time.perf_counter()
    horizon = duration_s * 1000.0
    instances, by_gpu, arrivals, noise_a, noise_s, router = _setup(
        plan, models, shadow, shadow_extra, horizon, poisson, seed, trace)
    n_inst = len(instances)
    fstate = _FaultState(faults) \
        if faults is not None and (faults.down or faults.slow) else None
    _attach_canary(adjust_fn, fstate)
    shed_prev = [False] * n_inst

    mon, adj = _epoch_times(horizon, monitor_period_s, adjust_fn,
                            adjust_period_s)
    mon_set, adj_set = set(mon), set(adj)
    # fault boundaries become epochs of their own: run_passes advances
    # everyone to the boundary, then the share zero/restore + re-split
    # runs — the same (t, gpu, is_up) order the scalar heap processes
    # its prio-4 fault events in
    fault_at: Dict[float, List[Tuple[int, bool]]] = {}
    if fstate is not None:
        for (tb, g, up) in fstate.fs.boundaries():
            fault_at.setdefault(tb, []).append((g, up))
    epochs = [(t, t in mon_set, t in adj_set)
              for t in sorted(mon_set | adj_set | set(fault_at))]
    epochs.append((math.inf, False, False))            # final drain

    arr_np = arrivals
    arr_l = [a.tolist() for a in arrivals]
    jptr = [0] * n_inst            # next unserved arrival index
    busy = [0.0] * n_inst
    completed = [0] * n_inst
    done_flat: List[List[float]] = [[] for _ in range(n_inst)]
    wptr = [0] * n_inst            # monitor-window start in done_flat
    n_passes = 0
    peak_window = 0
    n_reconfigs = 0
    adjust_wall_ms = 0.0
    adj_window_ms = adjust_period_s * 1000.0
    rows: List[Tuple[float, int, Dict]] = []           # timeline, sortable

    # Per-instance latency tables, built per device and invalidated only
    # for devices whose co-location state changed (shadow activation,
    # adjust_fn mutation, migration).  The loop is EPOCH-major (all
    # instances advance to each boundary before monitor/adjust fire) so
    # a cluster-scoped adjust_fn sees a consistent cluster snapshot;
    # per-instance RNG streams make this reordering exact vs the
    # device-major formulation.
    tables: Dict[int, _LatTable] = {}
    dispatch_key = "dispatch_jax" if backend == "jax" else "dispatch_numpy"

    def rebuild_gpu(g: int) -> None:
        tables.update(_build_tables_bulk(instances, {g: by_gpu[g]}, hw,
                                         backend=backend))
        if telemetry is not None:
            telemetry.count(dispatch_key)

    tables.update(_build_tables_bulk(instances, by_gpu, hw,
                                     backend=backend))
    if telemetry is not None:
        # table-build dispatches: the vec engine's physics-call unit
        # (engine/backend-specific by design; the identity contract
        # covers events + timelines, not dispatch counters)
        telemetry.count(dispatch_key)

    def run_passes(i: int, T: float) -> None:
        """Advance instance i's pass recurrence up to epoch boundary T.

        Replicates the oracle's event ordering: an arrival exactly at T
        is processed before the boundary (arrival events sort before
        monitor/adjust), a chained serve exactly at T after it.
        """
        nonlocal n_passes
        arr = arr_l[i]
        n_arr = len(arr)
        jj = jptr[i]
        if jj >= n_arr:
            return
        inst_i = instances[i]
        if inst_i.shed:
            # front-door rejection (mirrors the oracle's per-event drop;
            # arrivals exactly at T sort before the boundary there)
            j1 = bisect_right(arr, T, jj)
            if j1 > jj:
                inst_i.shed_count += j1 - jj
                jptr[i] = j1
                completed[i] = j1 - inst_i.shed_count
            return
        bu = busy[i]
        bcap = instances[i].batch
        tab = tables[i]
        t_load_t, t_sch_t, t_act_t, t_fb_t, slow_t = (
            tab.t_load, tab.t_sch, tab.t_act, tab.t_fb, tab.slow)
        na_s, ns_s = noise_a[i], noise_s[i]
        lats = instances[i].latencies
        wts = instances[i].waits
        dones = done_flat[i]
        anp = arr_np[i]
        # device fault view, fixed for this segment: the instance's gpu
        # only changes at adjust boundaries, which end every segment
        fstarts = fends = None
        fmult = 1.0
        if fstate is not None:
            fl = fstate.dev.get(instances[i].gpu)
            if fl is not None:
                fstarts, fends, fmult = fl
                if not fstarts:
                    fstarts = None
        while jj < n_arr:
            a = arr[jj]
            if bu > a:                 # chained serve at pass completion
                start = bu
                chained = True
            else:                      # idle: next arrival triggers
                start = a
                chained = False
            if fstarts is not None:
                kf = bisect_right(fstarts, start) - 1
                if kf >= 0 and start < fends[kf]:
                    # device down at the would-be pass start: the pass
                    # begins at the restart (inf for a permanent
                    # failure), the same instant the scalar engine's
                    # restart wake drains the backlog
                    start = fends[kf]
                    chained = True
            if chained:
                if start >= T:
                    break
            else:
                if start > T:
                    break
            nb = bisect_right(arr, start, jj) - jj
            if nb > bcap:
                nb = bcap
            k = nb - 1
            na = na_s.next()
            ns = ns_s.next()
            t_inf = _noisy_t_inf(t_load_t[k], t_sch_t[k], t_act_t[k],
                                 t_fb_t[k], slow_t[k], na, ns)
            if fmult != 1.0:
                t_inf *= fmult         # straggler: the model never knows
            done = start + t_inf
            lats.extend((done - anp[jj:jj + nb]).tolist())
            wts.extend((start - anp[jj:jj + nb]).tolist())
            dones.extend([done] * nb)
            jj += nb
            bu = done
            n_passes += 1
        jptr[i] = jj
        busy[i] = bu
        completed[i] = jj - inst_i.shed_count   # all served so far

    for (T, is_mon, is_adj) in epochs:
        for i in range(n_inst):
            run_passes(i, T)
        dirty: set = set()             # device ids needing table rebuilds
        if is_mon:
            cutoff = T - MONITOR_WINDOW_MS
            tl_rows = [] if telemetry is not None else None
            for i in range(n_inst):
                inst = instances[i]
                dn = done_flat[i]
                w = wptr[i]
                while w < len(dn) and dn[w] <= cutoff:
                    w += 1
                wptr[i] = w
                # completed-by-T only (mirrors the scalar monitor):
                # done stamps are nondecreasing per instance, and a
                # pass may complete past T (or past the horizon)
                end = bisect_right(dn, T, w)
                peak_window = max(peak_window, end - w)
                if (tl_rows is None and not record_timeline
                        and not shadow and inst.shadow_r <= 0.0):
                    continue           # window list only needed below
                window = inst.latencies[w:end]
                if tl_rows is not None:
                    # queue depth at T: arrivals admitted but not yet
                    # consumed by a pass — identical to the oracle's
                    # len(inst.queue) at the tick
                    tl_rows.append((i, window, inst.waits[w:end],
                                    dn[w:end],
                                    bisect_right(arr_l[i], T, jptr[i])
                                    - jptr[i]))
                if record_timeline:
                    rows.append((T, i, {
                        "t_s": T / 1000.0, "workload": inst.spec.name,
                        "p99_1s": float(np.percentile(window, 99)) if window else 0.0,
                        "avg_1s": float(np.mean(window)) if window else 0.0,
                        "r": inst.r_eff, "batch": inst.batch,
                        "rps_1s": len(window) / 1.0,
                        "shadow": inst.shadow_active,
                    }))
                # activation for simulator- OR controller-armed shadows
                # (mirrors the scalar monitor, incl. the table rebuild)
                if ((shadow or inst.shadow_r > 0.0) and window
                        and not inst.shadow_active):
                    if float(np.percentile(window, 99)) > inst.spec.slo_ms:
                        inst.shadow_active = True
                        dirty.add(inst.gpu)
            if tl_rows is not None:
                telemetry.sample_tick(T, instances, by_gpu, hw, tl_rows)
        if is_adj and adjust_fn is not None:
            for i in range(n_inst):
                inst = instances[i]
                inst.busy_until = busy[i]
                inst.completed = completed[i]
                al = arr_l[i]
                inst.queue = al[jptr[i]:bisect_right(al, T, jptr[i])]
            _sync_recent_arrivals(instances, arr_np, T, adj_window_ms)
            n_before = n_inst
            changed, new, wall_ms = _dispatch_adjust(
                adjust_fn, T / 1000.0, instances, by_gpu, adjust_scope)
            n_reconfigs += len(changed) + len(new)
            adjust_wall_ms += wall_ms
            if telemetry is not None:
                _emit_reconfigs(telemetry, T, changed, new, wall_ms)
                telemetry.add_wall("sim_adjust", wall_ms)
            for j in range(n_before, len(instances)):
                # appended replica: same RNG keys as the scalar oracle
                noise_a.append(_NoiseStream(
                    np.random.default_rng([seed, j, 1]),
                    physics.NOISE_SIGMA))
                noise_s.append(_NoiseStream(
                    np.random.default_rng([seed, j, 2]),
                    2 * physics.NOISE_SIGMA))
                arr_np.append(np.empty(0))
                arr_l.append([])
                jptr.append(0)
                busy.append(0.0)
                completed.append(0)
                done_flat.append([])
                wptr.append(0)
                shed_prev.append(False)
                dirty.add(instances[j].gpu)
            for i, inst in enumerate(instances):
                if inst.shed and not shed_prev[i]:
                    # shedding starts at this tick: reject the queued
                    # backlog (same set the oracle clears), keep the
                    # in-flight pass
                    j1 = bisect_right(arr_l[i], T, jptr[i])
                    if j1 > jptr[i]:
                        inst.shed_count += j1 - jptr[i]
                        jptr[i] = j1
                    completed[i] = jptr[i] - inst.shed_count
                    inst.completed = completed[i]
                    inst.queue = []
                shed_prev[i] = inst.shed
            n_inst = len(instances)
            for i in _resync_replicas(router, instances, arr_np, T):
                arr_l[i] = arr_np[i].tolist()
            moved = bool(new)
            for inst, old_g in changed:
                dirty.add(old_g)
                dirty.add(inst.gpu)
                moved = moved or old_g != inst.gpu
            if moved:
                by_gpu = _regroup(instances)
            if fstate is not None and changed:
                # migration of a fault-blocked backlog: see the scalar
                # twin — a no-op in clean runs, and with faults it pins
                # the first post-migration pass to the tick time
                pos = {id(inst): k for k, inst in enumerate(instances)}
                for inst, old_g in changed:
                    if old_g != inst.gpu:
                        k = pos[id(inst)]
                        if busy[k] < T:
                            busy[k] = T
        for g in sorted(dirty):
            if g in by_gpu:
                rebuild_gpu(g)
        if fstate is not None and T in fault_at:
            for (g, up) in fault_at[T]:
                if up:
                    resynced = fstate.on_restart(g, T, instances, by_gpu,
                                                 router, arr_np)
                else:
                    resynced = fstate.on_fail(g, T, instances, by_gpu,
                                              router, arr_np)
                for i in resynced:
                    arr_l[i] = arr_np[i].tolist()

    for i, inst in enumerate(instances):
        inst.completed = completed[i]
        inst.busy_until = busy[i]
        inst.queue = []
    rows.sort(key=lambda x: (x[0], x[1]))
    timeline = [row for (_, _, row) in rows]

    stats = _stats(sum(len(a) for a in arrivals), n_passes, peak_window,
                   wall0, n_reconfigs, adjust_wall_ms)
    if fstate is not None:
        stats.update(fstate.fault_stats(
            done_flat, horizon, sum(len(a) for a in arrivals),
            sum(completed)))
    _merge_overload_stats(adjust_fn, stats)
    return _finalize(instances, duration_s, timeline, stats)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def simulate_plan(plan: ProvisioningPlan,
                  models: Dict[str, ServedModelDesc],
                  hw: HardwareSpec, *,
                  duration_s: float = 30.0,
                  seed: int = 0,
                  poisson: bool = False,
                  shadow: bool = False,
                  shadow_extra: float = 0.10,
                  monitor_period_s: float = 0.5,
                  adjust_fn: Optional[AdjustFn] = None,
                  adjust_period_s: float = 1.0,
                  adjust_scope: str = "device",
                  record_timeline: bool = False,
                  trace: Optional["traces_mod.Trace"] = None,
                  faults: Optional["faults_mod.FaultSchedule"] = None,
                  telemetry: Optional["telemetry_mod.Telemetry"] = None,
                  engine: str = "vec",
                  backend: str = "numpy") -> SimResult:
    """Run the serving cluster for `duration_s` simulated seconds.

    ``engine="vec"`` (default) runs the table-cached epoch-major loop;
    ``engine="scalar"`` the reference global-heap loop.  Same seed =>
    byte-identical per-request latency streams across engines.

    ``backend="jax"`` (vec engine only) evaluates the bulk latency-table
    builds through the jitted physics twin (`physics_jax`): same event
    recurrence, table values within 1e-6 relative of the numpy oracle —
    use it for the m=10,000 sweeps, keep ``"numpy"`` for bitwise
    engine-identity checks.

    `adjust_fn` contract — IDENTICAL across engines (see `AdjustFn`):
    ``adjust_scope="device"`` (default) calls it once per device with
    that device's instances; ``adjust_scope="cluster"`` once per period
    with ALL instances (what `repro.serving.controller.Controller`
    needs).  The callback may mutate r / batch / shadow_r / gpu;
    queue / latencies / busy_until / completed / recent_arrivals are
    synced read-only views in both engines.

    ``trace`` replaces the constant arrival rates with a
    `repro.serving.traces.Trace` schedule (diurnal / spike / churn);
    arrivals stay pre-generated from the shared per-instance RNG
    streams, so traced runs remain engine-identical.

    ``faults`` injects a `repro.serving.faults.FaultSchedule` — device
    down intervals (in-flight passes finish, backlog queues, replica
    groups absorb the dead replica's share, a ``restart`` of ``inf``
    loses the backlog) and persistent straggler multipliers the
    performance model never sees.  Fault runs stay byte-identical
    across engines; ``SimResult.stats`` gains ``n_failures`` /
    ``downtime_ms`` / ``lost_requests`` / ``n_recoveries`` /
    ``recovery_mean_ms``.  ``faults=None`` leaves every code path —
    and every output byte — exactly as before.

    ``telemetry`` attaches a `repro.serving.telemetry.Telemetry`
    recorder: per-monitor-tick workload/device metric timelines and one
    typed ``reconfig`` event per placement mutation at adjust ticks
    (see `docs/observability.md`).  ``telemetry=None`` (default) is
    byte-identical to not having the feature at all, and for a fixed
    seed both engines record identical event/timeline content (host
    wall-time fields excepted).
    """
    if adjust_scope not in ("device", "cluster"):
        raise ValueError(f"unknown adjust_scope {adjust_scope!r}")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    kwargs = dict(duration_s=duration_s, seed=seed, poisson=poisson,
                  shadow=shadow, shadow_extra=shadow_extra,
                  monitor_period_s=monitor_period_s, adjust_fn=adjust_fn,
                  adjust_period_s=adjust_period_s,
                  record_timeline=record_timeline,
                  adjust_scope=adjust_scope, trace=trace, faults=faults,
                  telemetry=telemetry)
    if engine == "vec":
        return _simulate_vec(plan, models, hw, backend=backend, **kwargs)
    if engine != "scalar":
        raise ValueError(f"unknown engine {engine!r}")
    if backend != "numpy":
        raise ValueError("backend='jax' requires engine='vec' (the scalar "
                         "oracle is numpy by definition)")
    return _simulate_scalar(plan, models, hw, **kwargs)


def simulate_full(plan: ProvisioningPlan,
                  models: Dict[str, ServedModelDesc],
                  hw: HardwareSpec, *,
                  duration_s: float = 10.0,
                  seed: int = 0,
                  **kwargs) -> SimResult:
    """Full-cluster ground-truth simulation: EVERY device of the plan
    (m=1000 => ~461 devices), vectorized engine.  `SimResult.stats`
    carries n_requests / n_passes / events_per_s for the scale sweep —
    this is the closed loop that turns `predicted_violations` into a
    comparison against simulated ground truth."""
    return simulate_plan(plan, models, hw, duration_s=duration_s, seed=seed,
                         **kwargs)


def subplan(plan: ProvisioningPlan, device_ids: Sequence[int]
            ) -> ProvisioningPlan:
    """Restrict a plan to a subset of devices.

    Devices are independent in the simulator (co-location physics only
    couples workloads on the SAME device), so simulating a subset is a
    faithful sample of the full cluster for the workloads it hosts —
    and with per-instance RNG streams keyed by the instance's position
    in the (sub)plan, what made spot-checking tractable before
    `simulate_full` existed.
    """
    keep = set(int(g) for g in device_ids)
    out = ProvisioningPlan(hardware=plan.hardware)
    out.placements = [p for p in plan.placements if p.gpu in keep]
    out.n_gpus = len({p.gpu for p in out.placements})
    return out


def simulate_device_sample(plan: ProvisioningPlan,
                           models: Dict[str, ServedModelDesc],
                           hw: HardwareSpec, *,
                           max_devices: int = 8,
                           duration_s: float = 10.0,
                           seed: int = 0,
                           **kwargs) -> Tuple[SimResult, List[int]]:
    """Simulate a uniform sample of devices from a large plan and return
    (result, sampled device ids).  Superseded by `simulate_full` for CI
    validation (the vec engine makes the full cluster affordable); kept
    for quick spot checks and as API surface for notebooks."""
    rng = np.random.default_rng(seed)
    gpus = sorted({p.gpu for p in plan.placements})
    if len(gpus) > max_devices:
        gpus = sorted(rng.choice(gpus, size=max_devices, replace=False))
    sub = subplan(plan, gpus)
    res = simulate_plan(sub, models, hw, duration_s=duration_s, seed=seed,
                        **kwargs)
    return res, [int(g) for g in gpus]


def measure_steady(entries, models, hw):
    """GSLICE's measurement callback: steady-state avg latency + achievable
    throughput for each entry co-located on one device."""
    ds = [(models[e[0].model], e[2], e[3]) for e in entries]
    sts = physics.device_state(ds, hw)
    out = []
    for e, st in zip(entries, sts):
        b = e[2]
        thr = 1000.0 * b / (st.t_gpu + st.t_feedback)
        out.append((st.t_inf, thr))
    return out
