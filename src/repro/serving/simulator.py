"""Discrete-event GPU-cluster serving simulator (ground truth).

Two roles:

1. **ProfilingTestbed** (`SimTestbed`): what Nsight Systems/Compute +
   nvidia-smi provide on hardware — solo and co-located steady-state runs
   returning per-phase latencies, power, bandwidth utilization.  The
   iGniter coefficients are fit against these.

2. **Serving simulation** (`simulate_plan`): event-driven request/batch/
   serve loop per workload with constant-rate (or Poisson) arrivals,
   greedy dynamic batching up to the configured batch size, spatial
   co-location physics from `repro.serving.physics`, per-request latency
   records (P99), the GSLICE-style reactive controller hook, and the
   iGniter shadow-instance failover (Sec. 4.2).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.coefficients import ProfileSample
from repro.core.types import HardwareSpec, ProvisioningPlan, WorkloadSpec
from repro.profiling.metrics import ServedModelDesc
from repro.serving import physics


# ---------------------------------------------------------------------------
# Profiling testbed
# ---------------------------------------------------------------------------

class SimTestbed:
    """ProfilingTestbed over the ground-truth physics (deterministic:
    profiling averages away noise on real hardware too)."""

    def __init__(self, models: Dict[str, ServedModelDesc], hw: HardwareSpec,
                 noisy: bool = False, seed: int = 0):
        self.models = models
        self.hw = hw
        self.rng = np.random.default_rng(seed) if noisy else None

    def _sample(self, desc: ServedModelDesc, b: int, st: physics.TrueState
                ) -> ProfileSample:
        return ProfileSample(
            model=desc.name, batch=b, r=0.0,
            t_load=st.t_load, t_sched=st.t_sched, t_act=st.t_act,
            t_feedback=st.t_feedback, power=st.power,
            cache_util=st.cache_util, n_kernels=desc.n_kernels,
            d_load=desc.d_load_mb * b, d_feedback=desc.d_feedback_mb * b,
            device_freq=st.freq, device_power=st.device_power)

    def run_solo(self, model: str, batch: int, r: float) -> ProfileSample:
        desc = self.models[model]
        st = physics.device_state([(desc, batch, r)], self.hw, self.rng)[0]
        s = self._sample(desc, batch, st)
        return ProfileSample(**{**s.__dict__, "r": r})

    def run_colocated(self, entries: Sequence[Tuple[str, int, float]]
                      ) -> List[ProfileSample]:
        ds = [(self.models[m], b, r) for (m, b, r) in entries]
        sts = physics.device_state(ds, self.hw, self.rng)
        out = []
        for (m, b, r), st in zip(entries, sts):
            s = self._sample(self.models[m], b, st)
            out.append(ProfileSample(**{**s.__dict__, "r": r}))
        return out


# ---------------------------------------------------------------------------
# Discrete-event serving simulation
# ---------------------------------------------------------------------------

@dataclass
class ServedInstance:
    """One serving process (Triton-process analogue) on a device."""
    spec: WorkloadSpec
    desc: ServedModelDesc
    r: float
    batch: int
    gpu: int
    shadow_r: float = 0.0        # extra resources granted when shadow active
    shadow_active: bool = False
    queue: List[float] = field(default_factory=list)   # arrival times
    busy_until: float = 0.0
    latencies: List[float] = field(default_factory=list)
    completed: int = 0

    @property
    def r_eff(self) -> float:
        return self.r + (self.shadow_r if self.shadow_active else 0.0)


@dataclass
class SimResult:
    per_workload: Dict[str, Dict[str, float]]
    timeline: List[Dict] = field(default_factory=list)

    def violations(self, specs: Dict[str, WorkloadSpec]) -> List[str]:
        out = []
        for name, m in self.per_workload.items():
            s = specs[name]
            if m["p99_ms"] > s.slo_ms + 1e-9 or m["rps"] < 0.95 * s.rate_rps:
                out.append(name)
        return out


AdjustFn = Callable[[float, List[ServedInstance]], None]
# called every `adjust_period` sim-seconds with (now, instances)


def simulate_plan(plan: ProvisioningPlan,
                  models: Dict[str, ServedModelDesc],
                  hw: HardwareSpec, *,
                  duration_s: float = 30.0,
                  seed: int = 0,
                  poisson: bool = False,
                  shadow: bool = False,
                  shadow_extra: float = 0.10,
                  monitor_period_s: float = 0.5,
                  adjust_fn: Optional[AdjustFn] = None,
                  adjust_period_s: float = 1.0,
                  record_timeline: bool = False) -> SimResult:
    """Run the serving cluster for `duration_s` simulated seconds."""
    rng = np.random.default_rng(seed)
    instances: List[ServedInstance] = []
    for p in plan.placements:
        instances.append(ServedInstance(
            spec=p.workload, desc=models[p.workload.model], r=p.r,
            batch=max(1, p.batch), gpu=p.gpu))
    by_gpu: Dict[int, List[ServedInstance]] = {}
    for inst in instances:
        by_gpu.setdefault(inst.gpu, []).append(inst)

    if shadow:
        for inst in instances:
            used = sum(i.r for i in by_gpu[inst.gpu])
            inst.shadow_r = min(shadow_extra, max(0.0, 1.0 - used))

    horizon = duration_s * 1000.0                      # ms
    events: List[Tuple[float, int, str, int]] = []     # (t, seq, kind, idx)
    seq = 0

    def push(t, kind, idx):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, idx))
        seq += 1

    # request arrivals
    for i, inst in enumerate(instances):
        period = 1000.0 / inst.spec.rate_rps
        t = float(rng.uniform(0, period))
        while t < horizon:
            push(t, "arrival", i)
            t += float(rng.exponential(period)) if poisson else period

    for t in np.arange(monitor_period_s * 1000.0, horizon,
                       monitor_period_s * 1000.0):
        push(float(t), "monitor", -1)
    if adjust_fn is not None:
        for t in np.arange(adjust_period_s * 1000.0, horizon,
                           adjust_period_s * 1000.0):
            push(float(t), "adjust", -1)

    timeline: List[Dict] = []
    recent: Dict[int, List[Tuple[float, float]]] = {i: [] for i in range(len(instances))}

    def pass_latency(inst: ServedInstance, nb: int) -> physics.TrueState:
        peers = [(i.desc, i.batch, i.r_eff) for i in by_gpu[inst.gpu]
                 if i is not inst]
        entries = [(inst.desc, nb, inst.r_eff)] + peers
        return physics.device_state(entries, hw, rng)[0]

    def try_serve(i: int, now: float):
        inst = instances[i]
        if not inst.queue or inst.busy_until > now + 1e-12:
            return
        nb = min(inst.batch, len(inst.queue))
        taken, inst.queue = inst.queue[:nb], inst.queue[nb:]
        st = pass_latency(inst, nb)
        done = now + st.t_inf
        inst.busy_until = done
        for arr in taken:
            lat = done - arr
            inst.latencies.append(lat)
            recent[i].append((done, lat))
        inst.completed += nb
        push(done, "done", i)

    while events:
        now, _, kind, idx = heapq.heappop(events)
        if kind == "arrival":
            instances[idx].queue.append(now)
            try_serve(idx, now)
        elif kind == "done":
            try_serve(idx, now)
        elif kind == "monitor":
            for i, inst in enumerate(instances):
                window = [l for (t, l) in recent[i] if t > now - 1000.0]
                if record_timeline:
                    st = pass_latency(inst, inst.batch)
                    timeline.append({
                        "t_s": now / 1000.0, "workload": inst.spec.name,
                        "p99_1s": float(np.percentile(window, 99)) if window else 0.0,
                        "avg_1s": float(np.mean(window)) if window else 0.0,
                        "r": inst.r_eff, "batch": inst.batch,
                        "rps_1s": len(window) / 1.0,
                        "shadow": inst.shadow_active,
                    })
                if shadow and window and not inst.shadow_active:
                    if float(np.percentile(window, 99)) > inst.spec.slo_ms:
                        # switch to the pre-launched shadow process (Sec. 4.2)
                        inst.shadow_active = True
        elif kind == "adjust" and adjust_fn is not None:
            adjust_fn(now / 1000.0, instances)

    per = {}
    for inst in instances:
        lats = np.array(inst.latencies) if inst.latencies else np.array([np.inf])
        per[inst.spec.name] = {
            "p99_ms": float(np.percentile(lats, 99)),
            "p50_ms": float(np.percentile(lats, 50)),
            "avg_ms": float(np.mean(lats)),
            "rps": inst.completed / duration_s,
            "r_final": inst.r_eff,
            "batch_final": inst.batch,
            "shadow_used": inst.shadow_active,
        }
    return SimResult(per_workload=per, timeline=timeline)


def subplan(plan: ProvisioningPlan, device_ids: Sequence[int]
            ) -> ProvisioningPlan:
    """Restrict a plan to a subset of devices.

    Devices are independent in the simulator (co-location physics only
    couples workloads on the SAME device), so simulating a subset is a
    faithful sample of the full cluster for the workloads it hosts (up
    to the shared RNG stream) — that is what makes spot-checking an
    m=1000 plan tractable.
    """
    keep = set(int(g) for g in device_ids)
    out = ProvisioningPlan(hardware=plan.hardware)
    out.placements = [p for p in plan.placements if p.gpu in keep]
    out.n_gpus = len({p.gpu for p in out.placements})
    return out


def simulate_device_sample(plan: ProvisioningPlan,
                           models: Dict[str, ServedModelDesc],
                           hw: HardwareSpec, *,
                           max_devices: int = 8,
                           duration_s: float = 10.0,
                           seed: int = 0,
                           **kwargs) -> Tuple[SimResult, List[int]]:
    """Large-cluster scenario: simulate a uniform sample of devices from a
    (possibly m=1000-scale) plan and return (result, sampled device ids).

    A full discrete-event run of 1000 workloads x tens of seconds is
    millions of events; a sampled run bounds the cost while remaining a
    faithful per-device sample (see `subplan`).
    """
    rng = np.random.default_rng(seed)
    gpus = sorted({p.gpu for p in plan.placements})
    if len(gpus) > max_devices:
        gpus = sorted(rng.choice(gpus, size=max_devices, replace=False))
    sub = subplan(plan, gpus)
    res = simulate_plan(sub, models, hw, duration_s=duration_s, seed=seed,
                        **kwargs)
    return res, [int(g) for g in gpus]


def measure_steady(entries, models, hw):
    """GSLICE's measurement callback: steady-state avg latency + achievable
    throughput for each entry co-located on one device."""
    ds = [(models[e[0].model], e[2], e[3]) for e in entries]
    sts = physics.device_state(ds, hw)
    out = []
    for e, st in zip(entries, sts):
        b = e[2]
        thr = 1000.0 * b / (st.t_gpu + st.t_feedback)
        out.append((st.t_inf, thr))
    return out
