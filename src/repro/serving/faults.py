"""Deterministic fault schedules for the serving simulator.

The paper's predictability story (Secs. 4.2/4.4) assumes clean
hardware: every device behaves exactly as the fitted coefficients
predict, forever.  Production fleets do not — devices fail and restart,
and some silently *straggle* (run slower than any fitted model says
they should, see "Understanding GPU Resource Interference One Level
Deeper" in PAPERS.md).  This module supplies the fault side of that
gap as data, in the same style as `repro.serving.traces`: a frozen,
validated schedule object generated up front from a seed and handed to
`simulate_plan(..., faults=...)`, so faulty runs stay byte-identical
across both simulator engines by construction.

Semantics (implemented by the simulator, docs/simulator.md):

  * **Down intervals** ``down[gpu] = [[fail, restart), ...]`` (ms):
    while a device is down no instance on it can START a serving pass
    — in-flight passes complete, arrivals keep queueing as backlog,
    and replicas of the same base workload absorb the dead replica's
    rate share through the runtime re-split.  A ``restart`` of
    ``math.inf`` models a permanent failure (its backlog is never
    served and is reported as ``lost_requests``).
  * **Straggler multipliers** ``slow[gpu]`` (> 1 inflates): every pass
    served on the device takes ``multiplier`` times the modeled
    latency.  The performance model — and therefore the provisioner
    and the controller's plan edits — never sees the multiplier; the
    controller can only DETECT it from measured-vs-predicted residuals
    (the health layer in `repro.serving.controller`).

Schedules are plain per-device data so they compose: `merge` unions
independently generated failure and straggler schedules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultSchedule:
    """Per-device fault plan: down intervals and straggler multipliers.

    ``down`` maps device id -> (K, 2) array of ``[fail, restart)``
    half-open intervals in ms, sorted and non-overlapping (``restart``
    may be ``inf`` for a permanent failure); ``slow`` maps device id ->
    a positive latency multiplier applied to every pass served there
    (stragglers use > 1).  Devices absent from both dicts are clean.
    """
    down: Dict[int, np.ndarray] = field(default_factory=dict)
    slow: Dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        clean_down: Dict[int, np.ndarray] = {}
        for gpu, iv in self.down.items():
            a = np.asarray(iv, dtype=np.float64).reshape(-1, 2)
            a = a[np.argsort(a[:, 0], kind="stable")]
            if a.size and (np.any(a[:, 0] < 0.0)
                           or np.any(a[:, 1] <= a[:, 0])):
                raise ValueError(
                    f"down[{gpu}]: intervals need 0 <= fail < restart")
            if a.shape[0] > 1 and np.any(a[1:, 0] < a[:-1, 1]):
                raise ValueError(f"down[{gpu}]: intervals overlap")
            if a.size:
                clean_down[int(gpu)] = a
        object.__setattr__(self, "down", clean_down)
        clean_slow: Dict[int, float] = {}
        for gpu, m in self.slow.items():
            m = float(m)
            if not m > 0.0:
                raise ValueError(f"slow[{gpu}]: multiplier must be > 0, "
                                 f"got {m}")
            if m != 1.0:
                clean_slow[int(gpu)] = m
        object.__setattr__(self, "slow", clean_slow)

    # -- lookups ------------------------------------------------------------

    def multiplier(self, gpu: int) -> float:
        return self.slow.get(gpu, 1.0)

    def is_down(self, gpu: int, t_ms: float) -> bool:
        iv = self.down.get(gpu)
        if iv is None:
            return False
        k = int(np.searchsorted(iv[:, 0], t_ms, side="right")) - 1
        return k >= 0 and t_ms < iv[k, 1]

    def next_up(self, gpu: int, t_ms: float) -> float:
        """``t_ms`` when the device is up at ``t_ms``, else the restart
        time of the covering down interval (may be ``inf``)."""
        iv = self.down.get(gpu)
        if iv is None:
            return t_ms
        k = int(np.searchsorted(iv[:, 0], t_ms, side="right")) - 1
        if k >= 0 and t_ms < iv[k, 1]:
            return float(iv[k, 1])
        return t_ms

    def boundaries(self) -> List[Tuple[float, int, bool]]:
        """All finite fail/restart boundaries as ``(t_ms, gpu, is_up)``,
        sorted by (t, gpu, is_up) — the deterministic processing order
        both simulator engines share."""
        out: List[Tuple[float, int, bool]] = []
        for gpu, iv in sorted(self.down.items()):
            for f, r in iv:
                out.append((float(f), gpu, False))
                if math.isfinite(r):
                    out.append((float(r), gpu, True))
        out.sort(key=lambda b: (b[0], b[1], b[2]))
        return out

    def downtime_ms(self, horizon_ms: float,
                    gpus: Optional[Sequence[int]] = None) -> float:
        """Total scheduled downtime clipped to ``[0, horizon_ms)``,
        summed over ``gpus`` (default: every scheduled device)."""
        keys = self.down.keys() if gpus is None \
            else [g for g in gpus if g in self.down]
        total = 0.0
        for g in keys:
            iv = self.down[g]
            total += float(np.sum(np.clip(np.minimum(iv[:, 1], horizon_ms)
                                          - iv[:, 0], 0.0, None)))
        return total

    def n_failures(self, horizon_ms: float) -> int:
        """Fail events strictly before the horizon, over all devices."""
        return int(sum(int(np.sum(iv[:, 0] < horizon_ms))
                       for iv in self.down.values()))


# ---------------------------------------------------------------------------
# Schedule generators (seeded, like traces.py)
# ---------------------------------------------------------------------------

def random_failures(n_gpus: int, horizon_ms: float, *,
                    rate_per_min: float, mttr_ms: float,
                    seed: int = 0) -> FaultSchedule:
    """Poisson device failures: each device fails with exponential
    inter-failure gaps at ``rate_per_min`` failures per device-minute
    and stays down for ``mttr_ms``.  Device g's sub-stream is keyed
    ``default_rng([seed, g])``, so a device's fault history does not
    depend on the fleet size.  Failures at or past ``horizon_ms`` are
    dropped (their backlog effects could never be observed)."""
    if rate_per_min < 0.0 or mttr_ms <= 0.0:
        raise ValueError("need rate_per_min >= 0 and mttr_ms > 0")
    down: Dict[int, np.ndarray] = {}
    if rate_per_min == 0.0:
        return FaultSchedule(down=down)
    gap_ms = 60_000.0 / rate_per_min
    for g in range(n_gpus):
        rng = np.random.default_rng([seed, g])
        t = float(rng.exponential(gap_ms))
        ivs: List[List[float]] = []
        while t < horizon_ms:
            ivs.append([t, t + mttr_ms])
            t = t + mttr_ms + float(rng.exponential(gap_ms))
        if ivs:
            down[g] = np.asarray(ivs)
    return FaultSchedule(down=down)


def stragglers(n_gpus: int, *, frac: float, multiplier: float = 1.5,
               seed: int = 0) -> FaultSchedule:
    """A seeded ``frac`` of devices straggle at ``multiplier`` times the
    modeled pass latency for the whole run (persistent stragglers)."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"frac must be in [0, 1], got {frac}")
    rng = np.random.default_rng(seed)
    k = int(round(frac * n_gpus))
    picks = rng.permutation(n_gpus)[:k]
    return FaultSchedule(slow={int(g): float(multiplier) for g in picks})


def merge(*schedules: FaultSchedule) -> FaultSchedule:
    """Union independently generated schedules (e.g. failures +
    stragglers).  Down intervals are concatenated per device (overlaps
    raise via validation); a device's multiplier may be set by at most
    one schedule."""
    down: Dict[int, list] = {}
    slow: Dict[int, float] = {}
    for fs in schedules:
        for g, iv in fs.down.items():
            down.setdefault(g, []).extend(iv.tolist())
        for g, m in fs.slow.items():
            if g in slow and slow[g] != m:
                raise ValueError(f"conflicting multipliers for device {g}")
            slow[g] = m
    return FaultSchedule(down={g: np.asarray(v) for g, v in down.items()},
                         slow=slow)
