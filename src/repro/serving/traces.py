"""Dynamic arrival-rate traces for the serving simulator.

The static pipeline fixes every workload's arrival rate at t=0 and holds
it for the whole horizon; the paper's runtime half (Sec. 4.2/4.4) reacts
to rate changes instead.  This module supplies the *load* side of that
loop: per-workload piecewise-constant rate multipliers over the horizon
(`Trace`) plus the canonical shapes the dynamic benchmarks exercise —

  * ``diurnal``     a smooth 1x -> peak -> 1x ramp (one "day" per horizon
                    by default), discretized to piecewise-constant steps,
  * ``step_spike``  an abrupt flash-crowd multiplier over a window,
  * ``churn``       workload departures (rate -> 0 at a cut time) and
                    arrivals (rate 0 until an onset time), the
                    add/remove half of the control plane's job.

Traces are what the control plane's estimators chase: the diurnal ramp
drives sustained drift past the reconciler's hysteresis band, the
step spike probes the debounce (a short flash crowd must not trigger a
permanent reallocation), and churn exercises departure/re-arrival — the
shared vocabulary (band, debounce, burstiness floor) is defined in
docs/control-plane.md.

Arrival streams are pre-generated per instance by `simulator._setup`
from per-instance RNG streams shared by BOTH engines, so any trace stays
byte-identical across the scalar oracle and the vectorized engine by
construction.  Trace keys are BASE workload names: a replica group
(``w#0..w#k-1``, docs/simulator.md) draws ONE pooled stream for ``w``
at the summed share rate, which the simulator then splits
rate-proportionally.  `gen_arrivals` implements the two arrival
processes:

  * deterministic ("constant-rate" analogue): arrivals at the inverse of
    the cumulative rate integral, i.e. evenly spaced *in expected count*
    with a uniform phase — reduces to evenly spaced arrivals on a flat
    trace;
  * Poisson: thinning of a homogeneous Poisson process at the peak rate
    (acceptance probability scale(t)/scale_max), the standard exact
    sampler for non-homogeneous Poisson processes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Trace:
    """Per-workload piecewise-constant rate multipliers.

    ``edges`` are the K+1 segment boundaries in ms (strictly increasing,
    starting at 0); ``scales[name][k]`` multiplies the workload's
    provisioned ``rate_rps`` over ``[edges[k], edges[k+1])``.  Workloads
    absent from ``scales`` keep their static rate; past ``edges[-1]``
    the final segment's scale extends indefinitely.
    """
    edges: np.ndarray
    scales: Dict[str, np.ndarray]

    def __post_init__(self):
        e = np.asarray(self.edges, dtype=np.float64)
        if e.ndim != 1 or e.size < 2 or e[0] != 0.0 \
                or np.any(np.diff(e) <= 0.0):
            raise ValueError("edges must be 1-D, start at 0 and be "
                             "strictly increasing")
        object.__setattr__(self, "edges", e)
        clean = {}
        for name, s in self.scales.items():
            s = np.asarray(s, dtype=np.float64)
            if s.shape != (e.size - 1,):
                raise ValueError(f"scales[{name!r}] must have "
                                 f"{e.size - 1} segments, got {s.shape}")
            if np.any(s < 0.0):
                raise ValueError(f"scales[{name!r}] has negative rates")
            clean[name] = s
        object.__setattr__(self, "scales", clean)

    # -- lookups ------------------------------------------------------------

    def segments(self, name: str, horizon_ms: float
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """(edges, scales) covering exactly [0, horizon_ms): clipped when
        the trace is longer, final-scale-extended when shorter."""
        s = self.scales[name]
        e = self.edges
        if e[-1] < horizon_ms:
            e = np.concatenate([e, [horizon_ms]])
            s = np.concatenate([s, [s[-1]]])
        k = int(np.searchsorted(e, horizon_ms, side="left"))
        e = np.concatenate([e[:k], [horizon_ms]])
        return e, s[:e.size - 1]

    def scale_at(self, name: str, t_ms: float) -> float:
        if name not in self.scales:
            return 1.0
        k = int(np.searchsorted(self.edges, t_ms, side="right")) - 1
        k = min(max(k, 0), self.scales[name].size - 1)
        return float(self.scales[name][k])

    def mean_scale(self, name: str, horizon_ms: float) -> float:
        """Time-weighted mean multiplier over [0, horizon_ms) — the
        expected-throughput correction for SLO rate checks."""
        if name not in self.scales:
            return 1.0
        e, s = self.segments(name, horizon_ms)
        return float((s * np.diff(e)).sum() / horizon_ms)

    def max_scale(self, name: str, horizon_ms: float) -> float:
        if name not in self.scales:
            return 1.0
        _, s = self.segments(name, horizon_ms)
        return float(s.max()) if s.size else 1.0


# ---------------------------------------------------------------------------
# Trace generators
# ---------------------------------------------------------------------------

def constant(names: Sequence[str], horizon_ms: float, *,
             scale: float = 1.0) -> Trace:
    """Flat multiplier (scale=1.0 is the no-drift control case)."""
    edges = np.array([0.0, float(horizon_ms)])
    return Trace(edges=edges,
                 scales={n: np.array([scale]) for n in names})


def diurnal(names: Sequence[str], horizon_ms: float, *,
            peak: float = 2.0, period_ms: Optional[float] = None,
            resolution_ms: float = 250.0, phase: float = 0.0) -> Trace:
    """Smooth 1x -> ``peak`` -> 1x ramp, one period per horizon by
    default: scale(t) = 1 + (peak-1) * (1 - cos(2 pi (t/P + phase))) / 2,
    discretized to midpoint-sampled piecewise-constant segments."""
    horizon_ms = float(horizon_ms)
    period = float(period_ms) if period_ms is not None else horizon_ms
    n_seg = max(2, int(math.ceil(horizon_ms / resolution_ms)))
    edges = np.linspace(0.0, horizon_ms, n_seg + 1)
    mid = 0.5 * (edges[:-1] + edges[1:])
    s = 1.0 + (peak - 1.0) * 0.5 * (1.0 - np.cos(
        2.0 * math.pi * (mid / period + phase)))
    return Trace(edges=edges, scales={n: s.copy() for n in names})


def step_spike(names: Sequence[str], horizon_ms: float, *,
               at_ms: float, duration_ms: float,
               scale: float = 2.0, base: float = 1.0) -> Trace:
    """Flash crowd: ``base`` -> ``scale`` over [at, at+duration) -> base."""
    horizon_ms = float(horizon_ms)
    hi = min(float(at_ms) + float(duration_ms), horizon_ms)
    edges = [0.0]
    segs = []
    if at_ms > 0.0:
        edges.append(float(at_ms))
        segs.append(base)
    if hi > at_ms:
        edges.append(hi)
        segs.append(scale)
    if hi < horizon_ms:
        edges.append(horizon_ms)
        segs.append(base)
    e = np.array(edges)
    s = np.array(segs)
    return Trace(edges=e, scales={n: s.copy() for n in names})


def churn(names: Sequence[str], horizon_ms: float, *,
          departures: Optional[Mapping[str, float]] = None,
          arrivals: Optional[Mapping[str, float]] = None,
          base: float = 1.0) -> Trace:
    """Workload churn: ``departures[name]`` cuts the rate to 0 at that
    time; ``arrivals[name]`` holds the rate at 0 UNTIL that time (the
    workload "arrives" mid-trace).  Everything else stays at ``base``."""
    departures = dict(departures or {})
    arrivals = dict(arrivals or {})
    horizon_ms = float(horizon_ms)
    cuts = sorted({0.0, horizon_ms}
                  | {min(float(t), horizon_ms) for t in departures.values()}
                  | {min(float(t), horizon_ms) for t in arrivals.values()})
    edges = np.array(cuts)
    mid = 0.5 * (edges[:-1] + edges[1:])
    scales = {}
    for n in names:
        s = np.full(mid.size, base)
        if n in departures:
            s[mid >= departures[n]] = 0.0
        if n in arrivals:
            s[mid < arrivals[n]] = 0.0
        scales[n] = s
    return Trace(edges=edges, scales=scales)


def random_churn(names: Sequence[str], horizon_ms: float, *,
                 depart_frac: float = 0.1, arrive_frac: float = 0.1,
                 seed: int = 0) -> Trace:
    """Seeded convenience wrapper for the benchmark suite: a random
    ``depart_frac`` of workloads depart and a disjoint ``arrive_frac``
    arrive, each at a uniform time in the middle half of the horizon."""
    rng = np.random.default_rng(seed)
    names = list(names)
    k_dep = int(round(depart_frac * len(names)))
    k_arr = int(round(arrive_frac * len(names)))
    picks = rng.permutation(len(names))[:k_dep + k_arr]
    t = rng.uniform(0.25 * horizon_ms, 0.75 * horizon_ms,
                    size=k_dep + k_arr)
    departures = {names[int(i)]: float(tt)
                  for i, tt in zip(picks[:k_dep], t[:k_dep])}
    arrivals = {names[int(i)]: float(tt)
                for i, tt in zip(picks[k_dep:], t[k_dep:])}
    return churn(names, horizon_ms, departures=departures,
                 arrivals=arrivals)


# ---------------------------------------------------------------------------
# Arrival generation over a trace (consumed by simulator._setup)
# ---------------------------------------------------------------------------

def gen_arrivals(rate_rps: float, edges: np.ndarray, scales: np.ndarray,
                 horizon_ms: float, poisson: bool,
                 rng: np.random.Generator) -> np.ndarray:
    """All arrival times in [0, horizon) for one instance under a
    piecewise-constant rate ``rate_rps * scales[k]`` over
    ``[edges[k], edges[k+1])``.  The stream depends only on the RNG
    stream handed in — byte-identical across simulator engines.
    """
    if rate_rps <= 0.0 or scales.size == 0 or float(scales.max()) <= 0.0:
        return np.empty(0)
    widths = np.diff(edges)
    rate_ms = rate_rps * scales / 1000.0
    if not poisson:
        # inverse of the cumulative rate integral at integer counts
        cum = np.concatenate([[0.0], np.cumsum(rate_ms * widths)])
        total = cum[-1]
        u = max(float(rng.uniform(0.0, 1.0)), 1e-12)    # phase in (0, 1]
        if total <= u:
            return np.empty(0)
        targets = u + np.arange(int(math.floor(total - u)) + 1)
        targets = targets[targets < total]
        # k with cum[k] < target <= cum[k+1]; minimality of searchsorted
        # guarantees rate_ms[k] > 0 there (flat segments are skipped)
        k = np.searchsorted(cum[1:], targets, side="left")
        return edges[k] + (targets - cum[k]) / rate_ms[k]
    # Poisson: thin a homogeneous process at the peak rate
    smax = float(scales.max())
    rmax_ms = rate_rps * smax / 1000.0
    period = 1.0 / rmax_ms
    chunks = []
    last = 0.0
    est = max(16, int(horizon_ms / period * 1.2))
    while last < horizon_ms:
        gaps = rng.exponential(period, size=est)
        ts = last + np.cumsum(gaps)
        chunks.append(ts)
        last = float(ts[-1])
        est = max(16, est // 4)
    cand = np.concatenate(chunks)
    cand = cand[cand < horizon_ms]
    seg = np.clip(np.searchsorted(edges, cand, side="right") - 1,
                  0, scales.size - 1)
    accept = rng.uniform(0.0, 1.0, size=cand.size) * smax < scales[seg]
    return cand[accept]
