"""JAX-backed serving engine (Triton-process analogue).

One engine = one served model with an adaptive batcher: requests queue
up; each serving pass takes up to the iGniter-configured batch b_appr
(Eq. 17) and runs prefill + a short decode.  The engine measures real
wall-clock latencies (used by the quickstart example and integration
tests on CPU at reduced scale); production-scale placement runs in the
simulator, which models the co-location physics this engine cannot see
on a single host.

Also implements the shadow-instance failover of Sec. 4.2: a standby
engine configured with extra resources (here: a larger decode budget /
smaller batch) activated when the monitor sees P99 above the SLO.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.zoo import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    arrival_s: float
    extras: Optional[Dict] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray
    latency_ms: float


class ServingEngine:
    def __init__(self, cfg: ArchConfig, *, batch_size: int, prompt_len: int,
                 decode_tokens: int = 4, seed: int = 0):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.queue: Deque[Request] = deque()
        self.latencies: List[float] = []
        self._build()

    def _build(self):
        cfg, B, S = self.cfg, self.batch_size, self.prompt_len
        max_len = S + self.decode_tokens + 8

        def serve_pass(params, tokens, extras):
            batch = {"tokens": tokens}
            if extras:
                batch.update(extras)
            cache = self.model.init_cache(B, max_len, dtype=jnp.float32)
            logits, cache = self.model.prefill(params, batch, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs = [tok]
            for _ in range(self.decode_tokens - 1):
                lg, cache = self.model.decode_step(params, tok, cache)
                tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                outs.append(tok)
            return jnp.concatenate(outs, axis=1)

        self._serve = jax.jit(serve_pass)
        # warm up compile so measured latencies are steady-state
        dummy = jnp.zeros((B, S), jnp.int32)
        extras = self._dummy_extras()
        self._serve(self.params, dummy, extras)

    def _dummy_extras(self):
        cfg, B, S = self.cfg, self.batch_size, self.prompt_len
        extras = {}
        if cfg.frontend == "audio":
            extras["frames"] = jnp.zeros((B, cfg.encoder_seq_len, cfg.d_model),
                                         jnp.float32)
        if cfg.frontend == "vision":
            fd = cfg.frontend_dim or cfg.d_model
            extras["patches"] = jnp.zeros(
                (B, min(cfg.vision_patches, S), fd), jnp.float32)
        return extras

    def submit(self, req: Request):
        self.queue.append(req)

    def pump(self) -> List[Completion]:
        """Serve one batch if any requests are queued."""
        if not self.queue:
            return []
        take = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        B, S = self.batch_size, self.prompt_len
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(take):
            t = r.tokens[:S]
            toks[i, :len(t)] = t
        out = np.asarray(
            self._serve(self.params, jnp.asarray(toks), self._dummy_extras()))
        done = time.time()
        comps = []
        for i, r in enumerate(take):
            lat = (done - r.arrival_s) * 1000.0
            self.latencies.append(lat)
            comps.append(Completion(rid=r.rid, tokens=out[i], latency_ms=lat))
        return comps

    def p99_ms(self, window: int = 200) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(self.latencies[-window:], 99))
