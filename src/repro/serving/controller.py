"""Closed-loop control plane: online re-provisioning under dynamic load.

The static pipeline (Alg. 1/2 + the queueing-aware budget split)
provisions once at t=0; the paper's runtime half (Sec. 4.2: the
inference workload placer is "periodically executed", Sec. 4.4: the GPU
resource scaler reacts to load changes) has three moving parts, built
here on the simulator's unified ``adjust_fn`` hook
(``adjust_scope="cluster"``).  docs/control-plane.md is the narrative
companion; the terminology here (band, debounce, burstiness floor,
split/merge) matches it.

  1. **Estimators** (`ArrivalEstimator`): per-workload EWMA arrival
     rate, trend, and burstiness (squared coefficient of variation of
     inter-arrival gaps) fed from each instance's ``recent_arrivals``
     monitor window.  Replicas of one workload feed a single estimator
     with their merged (sorted) windows — the slices partition the
     pooled stream, so the merge IS the workload's arrival process.
     CV^2 ~ 0 on deterministic traces, ~ 1 on Poisson, >> 1 on spikes —
     exactly the `BudgetModel.burstiness` scale, so the budget split
     adapts to the measured arrival process.

  2. **Reconciler** (`Reconciler`): drift detection behind a
     **hysteresis band** — reconfigure only when the estimate leaves
     max(band, noise_sigmas * sigma) of the plan rate, with an
     asymmetric **debounce** (fast up: under-capacity compounds into
     backlog; slow down: releasing capacity on noise is the expensive
     error) so Poisson noise never triggers.  On sustained drift it
     re-solves the queueing budget with the online burstiness estimate
     (floored at the provisioned value — the **burstiness floor**:
     adaptation only tightens), re-optimizes the batch size jointly
     with the split (``batch="joint"``), and issues incremental plan
     edits: `provisioner.resize_workload` (same-device Alg. 2 re-run),
     `remove_workload` (departures), `add_workload` (re-arrivals and
     fresh devices), and — the replica layer — **split** (scale-out: a
     workload infeasible even solo at r = 1.0 becomes
     `required_replicas` rate-share replicas ``w#0..w#k-1``) and
     **merge** (scale-in on the slow path; survivor shares renormalize
     to the full rate).  Each edit is O(devices touched) through
     `VecCluster`'s cached invariants, with the scalar engines as the
     pinned oracle.

  3. **Controller** (`Controller`): the ``adjust_fn`` adapter.  Each
     control period it feeds the estimators, runs the reconciler, and
     applies the resulting plan deltas to the live instances — r /
     batch / gpu mutations, plus the replica lifecycle: renaming ``w``
     to ``w#0`` on the first split, APPENDING fresh `ServedInstance`s
     for scale-out (the simulator routes them a slice of the pooled
     arrival stream), and parking merged-away replicas at zero rate
     share.  A drift-free run performs ZERO reconfigurations and
     leaves the plan bit-identical — the no-op guarantee CI pins.

Determinism: everything the controller observes (``recent_arrivals``
slices of the pre-generated arrival streams) is byte-identical across
simulator engines, so a controlled run — including its splits and the
re-split arrival routing — is engine-identical too, modulo the
wall-clock ``reconfig_latency_ms`` stat.  A `Controller` is STATEFUL —
construct a fresh one per simulation run.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import perf_model as pm
from repro.core import perf_model_vec as pmv
from repro.core import provisioner as prov
from repro.core import replication
from repro.core.queueing import BudgetLike, QUEUEING, resolve
from repro.core.types import (HardwareSpec, Placement, PlannerConfig,
                              ProvisioningPlan, WorkloadCoefficients,
                              WorkloadSpec, planner_config)
from repro.serving import telemetry as telemetry_mod
from repro.serving.simulator import ServedInstance


# ---------------------------------------------------------------------------
# Online estimators
# ---------------------------------------------------------------------------

@dataclass
class ControllerConfig:
    """Knobs for the estimator / hysteresis / reconciliation loop."""
    alpha: float = 0.4           # EWMA weight for the arrival rate
    burst_alpha: float = 0.3     # EWMA weight for inter-arrival moments
    band_up: float = 0.15        # reconfigure when rate > (1+band_up)x plan
    band_down: float = 0.30      # ... or rate < (1-band_down)x plan
    noise_sigmas: float = 4.0    # widen bands to this many sigmas of the
                                 # smoothed Poisson counting noise, so a
                                 # noise-only run never breaches (the band
                                 # is max(band, k*sigma/mean))
    burst_band: float = 1.5      # ... or cv2 above budget burstiness by this
    debounce_up: int = 1         # ticks before reacting to up-drift (fast:
                                 # under-capacity compounds into backlog)
    debounce_down: int = 3       # ticks before releasing capacity (slow:
                                 # shrinking on noise is the expensive error)
    debounce_burst: int = 3      # ticks before a burstiness-only re-budget
                                 # (cv2 estimates are the noisiest signal)
    headroom: float = 0.15       # provision up-drift to rate*(1+headroom)
    drain_cap: float = 1.0       # backlog-drain demand cap, x estimated rate
    depart_frac: float = 0.02    # est rate below this x plan rate: departed
    depart_missed: float = 8.0   # expected arrivals missed in a zero-
                                 # arrival stretch before declaring departure
    min_gap_obs: int = 4         # gaps needed before trusting a cv2 update
    # -- health layer (failure / straggler detection + quarantine) --
    health: bool = True          # False disables the health layer entirely
    health_fail_ticks: int = 2   # consecutive no-completion-with-backlog
                                 # ticks before a device is declared failed
                                 # (>= 2: the first stalled tick may
                                 # straddle the actual failure instant)
    health_straggler_factor: float = 1.7
                                 # a device's median measured/predicted
                                 # pass-latency residual above this many
                                 # times the fleet median residual at the
                                 # same effective batch = straggling.
                                 # Clean devices carry up to ~1.5x fleet-
                                 # relative fitted-model bias (m=1000,
                                 # batch-normalized); a straggler needs
                                 # multiplier x its bias to clear 1.7 —
                                 # >= ~2.2x is reliably caught, milder
                                 # stragglers hide inside model error
    health_straggler_abs: float = 2.1
                                 # absolute backstop: a raw median
                                 # residual above this flags the device
                                 # even when fleet-relative scoring
                                 # cannot (stragglers pile into the
                                 # full-batch buckets their deep queues
                                 # create and normalize each other
                                 # away).  Clean fitted-model bias tops
                                 # out ~1.8x at m=1000; a 2.5x
                                 # multiplier lands >= ~2.4x
    health_straggler_ticks: int = 2
                                 # consecutive straggling ticks before
                                 # quarantine (residuals are noisier than
                                 # completions, but lognormal noise cannot
                                 # sustain a 30% median residual)
    health_drain_util: float = 0.6
                                 # eviction drain headroom: a victim
                                 # group whose worst member's fitted
                                 # utilization (x the residual guard)
                                 # exceeds this is re-placed as enough
                                 # equal-share replicas to put every
                                 # member under it — a victim at its
                                 # throughput ceiling has ~zero drain
                                 # rate and holds the backlog it
                                 # accumulated during detection latency
                                 # forever
    health_residual_guard: float = 1.3
                                 # fitted->true utilization guard used
                                 # in that split decision: the fitted
                                 # model under-predicts true service
                                 # time by up to ~1.3-1.8x, so a
                                 # fitted utilization near 1 can be a
                                 # TRUE utilization past 1
    health_readmit_s: float = 30.0
                                 # quarantine probation length: at expiry
                                 # the device is PROBED (an active canary
                                 # measures its CURRENT residual) and
                                 # readmitted only when it probes clean —
                                 # a still-straggling device fails the
                                 # probe and its probation restarts, so a
                                 # permanent straggler stays quarantined
                                 # forever.  Without a canary attached the
                                 # legacy timer readmission applies.
    k_max: int = prov.K_MAX      # replica ceiling for scale-out (a drifted
                                 # workload infeasible even solo at r=1.0
                                 # is split into <= k_max rate-share
                                 # replicas; 1 disables replication)
    # -- overload / admission layer (device cap + priority classes) --
    max_devices: Optional[int] = None
                                 # fleet cap for the reconciler's plan
                                 # edits: None = the historical uncapped
                                 # behavior; an int routes any edit that
                                 # would open device max_devices + 1
                                 # through the admission layer
                                 # (preemption -> brownout -> queue-or-
                                 # shed, docs/control-plane.md Overload)
    brownout_mult: float = 1.5   # working-SLO multiplier tried before a
                                 # cap-refused grant is queued or shed: a
                                 # looser SLO shrinks the resource demand.
                                 # Targets keep the TRUE SLO (recovery is
                                 # retried on every later breach) and
                                 # per-class violation stats measure
                                 # against the creation-time ``slo0``, so
                                 # a brownout cannot hide violations
    readmit_backoff_s: float = 5.0
                                 # shed-workload readmission retry gap:
                                 # each failed re-admission attempt backs
                                 # off this long before probing the cap
                                 # again
    planner: Optional[PlannerConfig] = None
                                 # planner knobs (backend/engine/budget/
                                 # batch/k_max) for the reconciler's plan
                                 # edits; None = PlannerConfig(batch=
                                 # "joint"), the controller's historical
                                 # default.  A Reconciler/Controller
                                 # ``config=`` argument overrides this,
                                 # which overrides the legacy ``k_max``
                                 # field above.
    # -- predictive tier (forecast-armed Sec. 4.2 shadows) --
    forecast: bool = False       # master switch for the proactive tier:
                                 # False (default) keeps every code path
                                 # byte-identical to the reactive build
    forecast_horizon: float = 3.0
                                 # control periods of trend extrapolation:
                                 # forecast = rate + max(0, trend) x this
                                 # (plus the seasonal lookup when a
                                 # period is detected)
    forecast_band: float = 0.30  # minimum relative rise of the forecast
                                 # over the plan rate before the
                                 # predictive tier may act
    forecast_sigmas: float = 10.0
                                 # widen that band to this many sigmas of
                                 # the smoothed counting noise — larger
                                 # than the reactive noise_sigmas because
                                 # the horizon extrapolation amplifies
                                 # trend noise ~3x.  This band alone
                                 # keeps constant-rate Poisson input
                                 # forecast-silent at any seed: measured
                                 # worst single-tick margin is ~0.84 of
                                 # the band (worst consecutive PAIR ~0.64)
                                 # over 180k noise-only ticks
                                 # (tests/test_forecast.py)
    forecast_debounce: int = 1   # consecutive forecast breaches before
                                 # acting.  1 by design: the predictive
                                 # tier must act at the FIRST tick a flash
                                 # crowd is visible or the reactive pass
                                 # wins the race (it fires at
                                 # debounce_up=1 and raises the target the
                                 # forecast is compared against) — noise
                                 # immunity comes from the 10-sigma band,
                                 # not the debounce.  Raise it to trade
                                 # spike lead time for extra insurance.
    forecast_hold: int = 5       # breach-free ticks before armed shadows
                                 # are released (never while one is
                                 # ACTIVE — yanking r_eff mid-drain would
                                 # re-blow the tail it just absorbed)
    forecast_history: int = 64   # per-workload rate-history windows kept
                                 # for the autocorrelation period scan
                                 # (bounded: the deque IS the memory cap)
    forecast_min_period: int = 4 # smallest candidate period (windows) —
                                 # below this the EWMA trend already
                                 # tracks the swing
    forecast_autocorr: float = 0.5
                                 # autocorrelation peak needed to declare
                                 # a period (white noise at lag k is
                                 # ~N(0, 1/n) — far below this)
    forecast_snr: float = 4.0    # series variance must exceed this many
                                 # times the Poisson counting-noise
                                 # variance before the period scan runs
                                 # at all: flat + noise never qualifies
    shadow_extra: float = 0.10   # Sec. 4.2 shadow reservation size per
                                 # armed instance (capped by the free
                                 # capacity of its device), matching
                                 # ``simulate_plan(shadow_extra=...)``
    # -- observability --
    cost_retention: int = 4096   # rows kept in `Controller.costs` (the
                                 # (t_s, $/h) ring sampled every tick);
                                 # the ring's ``total``/``dropped``
                                 # expose overflow.  The unbounded
                                 # ``cost_series`` list this replaces
                                 # grew for the whole run


class ArrivalEstimator:
    """EWMA arrival-rate + CV^2 burstiness from monitor-window arrivals.

    Fed once per control period with the arrivals observed in that
    window.  Inter-arrival gaps are chained across windows through the
    last seen arrival so burstiness sees inter-burst gaps too — a spike
    train's signature lives BETWEEN windows as much as within them.
    """

    def __init__(self, rate_rps: float, cfg: Optional[ControllerConfig] = None,
                 burstiness: float = 1.0):
        self.cfg = cfg or ControllerConfig()
        self.rate_rps = float(rate_rps)   # prior: the provisioned rate
        self.trend_rps = 0.0              # EWMA per-window rate delta
        self.cv2 = float(burstiness)      # prior: the budget's burstiness
        self.n_windows = 0
        self.n_gaps = 0
        self.ever_active = False          # any arrival seen at all
        self.empty_ms = 0.0               # current zero-arrival stretch
        self.window_ms = 1000.0           # last observation window
        self._last_arrival: Optional[float] = None
        self._gap_buf: List[float] = []   # gaps awaiting a moment update
        self._g1: Optional[float] = None  # EWMA mean gap [ms]
        self._g2: Optional[float] = None  # EWMA mean squared gap [ms^2]
        # bounded raw per-window rate history for the predictive tier's
        # autocorrelation period scan (maintained unconditionally: one
        # float per control period, and the deque caps the memory)
        self.history: deque = deque(
            maxlen=max(int(self.cfg.forecast_history), 8))

    @property
    def projected_rps(self) -> float:
        """Rate one control period ahead: EWMA estimates lag a ramp by
        construction, so up-drift sizing extrapolates the trend (never
        below the smoothed estimate — a falling trend is not projected,
        shrinking is the hysteresis band's slow path)."""
        return self.rate_rps + max(0.0, self.trend_rps)

    def rate_sigma(self) -> float:
        """Std of the smoothed rate estimate under Poisson counting
        noise: sqrt(R / T_window) shrunk by the EWMA's variance factor
        alpha / (2 - alpha) — what the hysteresis band must exceed for
        noise-only input to stay quiet."""
        var_factor = self.cfg.alpha / (2.0 - self.cfg.alpha)
        lam = max(self.rate_rps * self.window_ms / 1000.0, 1.0)
        return (math.sqrt(lam * var_factor) * 1000.0 / self.window_ms
                if self.window_ms > 0 else 0.0)

    def detect_period(self) -> Optional[int]:
        """Dominant period of the rate history, in control periods, or
        None.  Demeaned autocorrelation over lags in
        [forecast_min_period, n/2]; a lag qualifies only when its
        coefficient clears `forecast_autocorr` AND the series variance
        clears `forecast_snr` x the Poisson counting-noise variance —
        the double gate is what keeps constant-rate Poisson input (whose
        lag-k autocorrelation is ~N(0, 1/n)) period-free at any seed.
        Among qualifying lags the smallest one within 10% of the best
        coefficient wins, so a fundamental beats its own harmonics."""
        cfg = self.cfg
        n = len(self.history)
        max_lag = n // 2
        if max_lag < cfg.forecast_min_period:
            return None
        x = np.asarray(self.history, dtype=np.float64)
        x = x - x.mean()
        denom = float(np.dot(x, x))
        if denom <= 0.0:
            return None
        # counting-noise floor: a Poisson window of lam = R * T_w
        # arrivals has rate variance R / T_w — flat + noise sits AT this
        # floor while a real seasonal swing carries far more power
        noise_var = self.rate_rps * 1000.0 / max(self.window_ms, 1e-9)
        if denom / n < cfg.forecast_snr * max(noise_var, 1e-12):
            return None
        lags = np.arange(cfg.forecast_min_period, max_lag + 1)
        acf = np.array([float(np.dot(x[:-k], x[k:])) / denom
                        for k in lags])
        best = float(acf.max())
        if best < cfg.forecast_autocorr:
            return None
        return int(lags[np.argmax(acf >= best - 0.1 * abs(best))])

    def forecast_rps(self, horizon: float) -> float:
        """Short-horizon rate forecast: trend extrapolation ``rate +
        max(0, trend) * horizon`` (a falling trend is not projected —
        shrinking stays on the reactive slow path), raised to the
        seasonal level one detected period back at t + horizon when the
        history carries a significant period.  Never below the current
        smoothed estimate, and monotone in the trend — a linear ramp's
        forecasts rise monotonically (tests/test_forecast.py)."""
        f = self.rate_rps + max(0.0, self.trend_rps) * max(horizon, 0.0)
        p = self.detect_period()
        n = len(self.history)
        if p is not None and n > p:
            idx = n - 1 + int(round(horizon)) - p
            while idx >= n:          # horizon beyond one period: wrap
                idx -= p
            if idx >= 0:
                h = list(self.history)
                lo, hi = max(0, idx - 1), min(n, idx + 2)
                seasonal = float(np.mean(h[lo:hi]))  # 3-point smooth
                f = max(f, seasonal)
        return f

    def observe(self, arrivals: np.ndarray, window_ms: float) -> None:
        cfg = self.cfg
        arrivals = np.asarray(arrivals, dtype=np.float64)
        inst_rate = arrivals.size * 1000.0 / max(window_ms, 1e-9)
        self.history.append(inst_rate)
        prev = self.rate_rps
        self.rate_rps += cfg.alpha * (inst_rate - self.rate_rps)
        self.trend_rps += cfg.alpha * ((self.rate_rps - prev)
                                       - self.trend_rps)
        self.n_windows += 1
        self.window_ms = window_ms

        if arrivals.size == 0:
            self.empty_ms += window_ms
            return
        self.empty_ms = 0.0
        self.ever_active = True
        if self._last_arrival is not None:
            gaps = np.diff(np.concatenate([[self._last_arrival], arrivals]))
        else:
            gaps = np.diff(arrivals)
        self._last_arrival = float(arrivals[-1])
        # buffer gaps across windows so low-rate workloads (fewer than
        # min_gap_obs arrivals per period) still accumulate burstiness
        # evidence instead of discarding every window's gaps
        self._gap_buf.extend(gaps.tolist())
        if len(self._gap_buf) >= cfg.min_gap_obs:
            g = np.asarray(self._gap_buf)
            self._gap_buf = []
            m1 = float(np.mean(g))
            m2 = float(np.mean(g * g))
            if self._g1 is None:
                self._g1, self._g2 = m1, m2
            else:
                self._g1 += cfg.burst_alpha * (m1 - self._g1)
                self._g2 += cfg.burst_alpha * (m2 - self._g2)
            self.n_gaps += int(g.size)
            if self._g1 > 0.0:
                self.cv2 = max(0.0, self._g2 / (self._g1 * self._g1) - 1.0)


# ---------------------------------------------------------------------------
# Health layer: failure / straggler detection from live telemetry
# ---------------------------------------------------------------------------

@dataclass
class HealthReport:
    """One tick's verdicts: devices newly detected failed / straggling,
    and quarantined devices whose probation expired."""
    dead: List[int]
    stragglers: List[int]
    readmit: List[int]


def _pass_groups(svc: np.ndarray) -> List[tuple]:
    """Recover (service_ms, batch) per serving pass from per-request
    ``latency - wait``: every request of a pass completes at the same
    instant it started serving, so consecutive equal values ARE a pass.
    The 1e-6 ms tolerance absorbs float re-association; two REAL passes
    landing within it would only merge into one conservative group."""
    if svc.size == 0:
        return []
    brk = np.flatnonzero(np.abs(np.diff(svc)) > 1e-6) + 1
    starts = np.concatenate([[0], brk])
    ends = np.concatenate([brk, [svc.size]])
    return [(float(svc[s]), int(e - s)) for s, e in zip(starts, ends)]


class HealthMonitor:
    """Device-health detection from what a serving system can actually
    measure — completion counts and per-request latencies — never from
    the fault schedule (the controller must DETECT faults, not read
    them).

    * **Failure**: a device whose instances have pending queued work but
      complete NOTHING for `health_fail_ticks` consecutive control
      periods.  A healthy device completes many passes per period, so
      the only false-positive window is the tick straddling the failure.
    * **Straggler**: per pass, the ratio of measured service time
      (``latency - wait``, exactly the pass's realized inference time)
      to the fitted interference model's prediction at the pass's
      effective batch; each sample is normalized by the fleet median
      ratio at the same effective batch, and a device whose median
      normalized residual sits `health_straggler_factor` above the
      fleet median of device medians for `health_straggler_ticks` ticks
      is straggling.  The fitted model's residual vs the true physics
      varies with effective batch and composition, but the FLEET shares
      that bias — double normalization cancels it, while a straggler's
      multiplier exists outside the fitted coefficient space entirely
      and cannot cancel.  Needs >= 2 reporting devices (a lone device IS
      the fleet median).  Predictions are memoized per composition.

    Quarantined devices are skipped by detection; at probation expiry
    (`health_readmit_s`) the device is PROBED — ``observe``'s ``canary``
    callable measures its current residual — and readmitted only when
    the probe comes back clean (residual <= `health_straggler_factor`).
    A failed probe restarts probation, so a PERMANENT straggler is never
    readmitted; without a canary the legacy timer readmission applies
    (re-detection then has to re-trip, repeating the outage — the bug
    the probe fixes).
    """

    def __init__(self, profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec, cfg: ControllerConfig,
                 telemetry: Optional["telemetry_mod.Telemetry"] = None):
        self.profiles = profiles
        self.hw = hw
        self.cfg = cfg
        self.telemetry = telemetry
        self.quarantined: Dict[int, tuple] = {}   # gpu -> (kind, t_s)
        self._completed: Dict[int, int] = {}      # inst idx -> last count
        self._seen: Dict[int, int] = {}           # inst idx -> consumed lats
        self._gpu: Dict[int, int] = {}            # inst idx -> last device
        self._fail_streak: Dict[int, int] = {}
        self._slow_streak: Dict[int, int] = {}
        self._pred: Dict[tuple, float] = {}       # composition -> t_inf

    def _predicted(self, inst: ServedInstance,
                   peers: List[ServedInstance], nb: int) -> float:
        key = (inst.spec.model, nb, round(inst.r_eff, 9),
               tuple(sorted((p.spec.model, p.batch, round(p.r_eff, 9))
                            for p in peers)))
        t = self._pred.get(key)
        if t is None:
            placed = [pm.PlacedWorkload(
                coeffs=self.profiles[inst.spec.model], batch=nb,
                r=inst.r_eff)]
            placed += [pm.PlacedWorkload(
                coeffs=self.profiles[p.spec.model], batch=p.batch,
                r=p.r_eff) for p in peers]
            t = pm.predict_device(placed, self.hw).per_workload[0].t_inf
            self._pred[key] = t
        return t

    def observe(self, now_s: float,
                instances: List[ServedInstance],
                canary=None) -> HealthReport:
        cfg = self.cfg
        by_gpu: Dict[int, List[int]] = {}
        for i, inst in enumerate(instances):
            by_gpu.setdefault(inst.gpu, []).append(i)
        dead: List[int] = []
        strag: List[int] = []
        dev_samples: Dict[int, List[Tuple[int, float]]] = {}
        for g in sorted(by_gpu):
            if g in self.quarantined:
                continue
            idxs = by_gpu[g]
            progress = any(instances[i].completed
                           > self._completed.get(i, 0) for i in idxs)
            pending = any(len(instances[i].queue) > 0 for i in idxs)
            if pending and not progress:
                streak = self._fail_streak.get(g, 0) + 1
            else:
                streak = 0
            self._fail_streak[g] = streak
            if streak >= cfg.health_fail_ticks:
                dead.append(g)
                continue
            samples: List[Tuple[int, float]] = []   # (nb, ratio)
            for i in idxs:
                inst = instances[i]
                if self._gpu.get(i, inst.gpu) != inst.gpu:
                    continue       # migrated mid-window: the new pass
                                   # samples still blame the OLD device
                lo = self._seen.get(i, 0)
                lats = inst.latencies
                if len(lats) <= lo:
                    continue
                svc = (np.asarray(lats[lo:])
                       - np.asarray(inst.waits[lo:]))
                peers = [instances[k] for k in idxs if k != i]
                for (service, nb) in _pass_groups(svc):
                    nbe = min(nb, inst.batch)
                    pred = self._predicted(inst, peers, nbe)
                    if pred > 0.0:
                        samples.append((nbe, service / pred))
            if samples:
                dev_samples[g] = samples
        # fleet-relative straggler test: the fitted model carries a
        # residual vs the true physics that depends on the effective
        # batch served (partial passes mispredict worst) and on the
        # device's composition — clean devices measure anywhere in
        # ~[0.9, 1.6]x predicted, so an absolute threshold cannot
        # separate model bias from a genuine straggler.  The FLEET
        # shares the bias; a straggler does not share its multiplier.
        # So: collapse each device to its median ratio per effective
        # batch, normalize by the LEAVE-ONE-OUT fleet median of the
        # other devices' medians at that batch (cancels the
        # nb-dependent bias without letting a device that dominates a
        # batch bucket normalize its own multiplier away), and compare
        # the per-device median of those normalized residuals to the
        # fleet median of device scores (cancels the rest).  A batch
        # bucket scores a device only when >= 2 OTHER devices report
        # it; a lone device is always exactly the fleet median.
        dev_nb_med: Dict[int, Dict[int, float]] = {}
        for g, samples in dev_samples.items():
            per_nb: Dict[int, List[float]] = {}
            for nb, r in samples:
                per_nb.setdefault(nb, []).append(r)
            dev_nb_med[g] = {nb: float(np.median(v))
                             for nb, v in per_nb.items()}
        bucket: Dict[int, List[Tuple[int, float]]] = {}
        for g, med_by_nb in dev_nb_med.items():
            for nb, v in med_by_nb.items():
                bucket.setdefault(nb, []).append((g, v))
        score: Dict[int, float] = {}
        for g, med_by_nb in dev_nb_med.items():
            normed = []
            for nb, v in med_by_nb.items():
                # nearest populated batch bucket: a straggler's slow
                # passes accumulate deeper queues, so it often serves
                # at a batch no clean device reports — its own bucket
                # would be empty after leave-one-out and it would never
                # be scored.  The fleet bias falls with nb, so on a tie
                # prefer the SMALLER nb (larger reference, conservative)
                cands = [nb2 for nb2, pts in bucket.items()
                         if sum(1 for (h, _) in pts if h != g) >= 2]
                if not cands:
                    continue
                nb_star = min(cands, key=lambda x: (abs(x - nb), x))
                others = [x for (h, x) in bucket[nb_star] if h != g]
                normed.append(v / float(np.median(others)))
            if normed:
                score[g] = float(np.median(normed))
        if len(dev_samples) >= 2:
            fleet = float(np.median(list(score.values()))) if score else 0.0
            raw = {g: float(np.median([r for _, r in samples]))
                   for g, samples in dev_samples.items()}
            if self.telemetry is not None:
                # the measured-vs-fitted residual series: exactly the
                # triple the quarantine comparison below reads, recorded
                # instead of discarded (docs/observability.md, drift)
                for g in sorted(dev_samples):
                    self.telemetry.record_drift(
                        now_s, g, raw[g], score.get(g, 0.0), fleet)
            for g in sorted(by_gpu):
                if g in self.quarantined or g in dead:
                    continue
                flagged = (g in score and fleet > 0.0
                           and score[g] / fleet
                           > cfg.health_straggler_factor)
                # absolute backstop: when every device in a batch
                # bucket straggles, fleet-relative scoring is blind —
                # but the raw residual is not
                flagged = flagged or (g in raw
                                      and raw[g] > cfg.health_straggler_abs)
                if flagged:
                    slow = self._slow_streak.get(g, 0) + 1
                else:
                    slow = 0
                self._slow_streak[g] = slow
                if slow >= cfg.health_straggler_ticks:
                    strag.append(g)
        for i, inst in enumerate(instances):
            self._completed[i] = inst.completed
            self._seen[i] = len(inst.latencies)
            self._gpu[i] = inst.gpu
        readmit: List[int] = []
        for g in sorted(self.quarantined):
            kind, t0 = self.quarantined[g]
            if now_s - t0 < cfg.health_readmit_s:
                continue
            if canary is not None:
                # active probe, not a timer: readmit only when the device
                # measures clean RIGHT NOW.  A still-down device probes
                # at infinity, a permanent straggler at its multiplier —
                # both fail and restart probation, so they never re-ingest
                # placements just to re-trip detection.
                if not (canary(g, now_s * 1000.0)
                        <= cfg.health_straggler_factor):
                    self.quarantined[g] = (kind, now_s)
                    continue
            readmit.append(g)
        return HealthReport(dead=dead, stragglers=strag, readmit=readmit)


# ---------------------------------------------------------------------------
# Persistent plan state: the hot path for incremental edits
# ---------------------------------------------------------------------------

class PlanState:
    """A live `VecCluster` mirror of the reconciler's current plan.

    The provisioner-level edits (`resize_workload` & co.) are
    plan-in/plan-out and rebuild their cluster state per call — exact,
    oracle-friendly, but O(cluster) each, which at m=1000 puts the
    controller's own latency (the Sec. 5.5 overhead number) in the tens
    of seconds.  This mirror keeps the cluster's cached invariants
    ALIVE across edits so each one costs only the devices it touches:
    a same-device resize re-runs Alg. 2 against that device alone, a
    migration scores every device in ONE vectorized `alloc_all`, and a
    departure is a single `remove_entry`.  Allocation outcomes match
    the sequential provisioner ops (entry order within a device differs,
    which the model's symmetric sums make irrelevant) — pinned by
    `tests/test_controller.py`; emptied devices are additionally reused
    as migration targets instead of stranding them.
    """

    def __init__(self, plan: ProvisioningPlan,
                 profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec, budget: BudgetLike = QUEUEING,
                 backend: str = "numpy",
                 probes: Optional[prov.ProbeCache] = None,
                 max_devices: Optional[int] = None,
                 shadow: Optional[Dict[str, float]] = None):
        self.hw = hw
        self.profiles = profiles
        self.max_devices = max_devices
        self.hardware = plan.hardware or hw
        self.probes = probes
        self.cl = pmv.VecCluster(hw, budget=budget, backend=backend)
        self.row_gpus: List[int] = []          # row q -> plan gpu id
        self.home: Dict[str, int] = {}         # workload name -> row q
        by_gpu: Dict[int, List[Placement]] = {}
        for p in plan.placements:
            by_gpu.setdefault(p.gpu, []).append(p)
        for g in sorted(by_gpu):               # add_workload's row order
            q = self.cl.add_device()
            self.row_gpus.append(g)
            for p in by_gpu[g]:
                self.cl.add_entry(q, p.workload,
                                  profiles[p.workload.model], p.batch, p.r)
                self.home[p.workload.name] = q
        self._next_gpu = (max(by_gpu) + 1) if by_gpu else 0
        # plan gpu ids placement must avoid (health-layer quarantine);
        # the Reconciler keeps this in sync with its quarantine set
        self.banned: set = set()
        # Sec. 4.2 shadow reservations, workload name -> shadow_r.
        # Shared BY REFERENCE with the owning Reconciler's armed book,
        # so every placement sweep sees the reservation the moment it
        # is granted: an activation may push a device to r + shadow_r
        # but never past 1.0 (tests/test_forecast.py pins this)
        self.shadow: Dict[str, float] = shadow if shadow is not None \
            else {}

    def _row_reserved(self, exclude: Optional[str] = None) -> np.ndarray:
        """Per-row armed shadow reservation: the capacity a monitor-tick
        activation may claim, which placement must treat as spoken for."""
        out = np.zeros(self.cl.d)
        for name, sr in self.shadow.items():
            if name == exclude:
                continue
            q = self.home.get(name)
            if q is not None:
                out[q] += sr
        return out

    def set_budget(self, budget: BudgetLike) -> None:
        self.cl.set_budget(budget)

    def remove(self, name: str) -> None:
        q = self.home.pop(name)
        self.cl.remove_entry(q, self._slot_at(q, name))

    def _slot_at(self, q: int, name: str) -> int:
        for i, (s, _, _) in enumerate(self.cl.entries[q]):
            if s.name == name:
                return i
        raise KeyError(name)

    def _place(self, spec: WorkloadSpec, c: WorkloadCoefficients,
               b: int, rl: float) -> None:
        """Min-interference placement over ALL devices (one vectorized
        Alg. 2 sweep) with the fresh-device `self_grant` fallback —
        `add_workload` semantics against the live cluster."""
        cl = self.cl
        feasible, rr, rn, r_inter = cl.alloc_all(spec, c, b, rl)
        if self.banned:
            mask = np.fromiter((g in self.banned for g in self.row_gpus),
                               dtype=bool, count=len(self.row_gpus))
            feasible = feasible & ~mask
            r_inter = np.where(mask, np.inf, r_inter)
        if self.shadow:
            # armed reservations are spoken-for capacity: a row whose
            # re-solved residents + newcomer + reservations would exceed
            # r = 1.0 is infeasible for this placement (the activation
            # headroom must survive every edit)
            resv = self._row_reserved(exclude=spec.name)
            if resv.any():
                load = (rr * cl.mask[:cl.d]).sum(axis=1) + rn + resv
                over = load > 1.0 + 1e-9
                if over.any():
                    feasible = feasible & ~over
                    r_inter = np.where(over, np.inf, r_inter)
        if self.max_devices is not None:
            used = sum(1 for q in range(cl.d) if cl.entries[q])
            if used >= self.max_devices:
                # cap reached: an EMPTY row is one more device in use
                # the moment anything lands on it, so mask empty rows
                # from the sweep along with refusing the fresh fallback
                empty = np.fromiter((not cl.entries[q]
                                     for q in range(cl.d)),
                                    dtype=bool, count=cl.d)
                if empty.any():
                    feasible = feasible & ~empty
                    r_inter = np.where(empty, np.inf, r_inter)
        row = prov._argmin_inter(r_inter) if feasible.any() else -1
        if row == -1:
            if self.max_devices is not None:
                prov._check_device_cap(
                    sum(1 for q in range(cl.d) if cl.entries[q]),
                    self.max_devices, spec.name, self.hw)
            row = cl.add_device()
            self.row_gpus.append(self._next_gpu)
            self._next_gpu += 1
            cl.add_entry(row, spec, c, b,
                         prov.self_grant(spec, c, b, rl, self.hw,
                                         budget=cl.bm))
        else:
            cl.set_row_r(row, rr[row])
            cl.add_entry(row, spec, c, b, float(rn[row]))
        self.home[spec.name] = row

    def _theorem1(self, spec: WorkloadSpec, c: WorkloadCoefficients,
                  batch: str) -> tuple:
        """(b_appr, r_lower) through the shared probe cache when one is
        attached — repeat edits to a (spec, budget) pair skip the
        joint-batch scan entirely."""
        if self.probes is not None:
            return self.probes.theorem1(spec, c, self.hw, self.cl.bm, batch)
        b = prov.appropriate_batch(spec, c, self.hw, budget=self.cl.bm,
                                   batch=batch)
        rl = prov.resource_lower_bound(spec, c, self.hw, b,
                                       budget=self.cl.bm)
        return b, rl

    def add(self, spec: WorkloadSpec, *, batch: str = "joint",
            pin: Optional[tuple] = None) -> None:
        """``pin=(batch, r_floor)`` bypasses Theorem 1 — the health
        layer's capacity-preserving migration (`prov.add_workload`
        semantics)."""
        c = self.profiles[spec.model]
        if pin is not None:
            b, rl = int(pin[0]), float(pin[1])
        else:
            b, rl = self._theorem1(spec, c, batch)
        self._place(spec, c, b, rl)

    def resize(self, spec: WorkloadSpec, *, batch: str = "joint") -> None:
        """Theorem 1 at the new rate, same-device Alg. 2 re-run first,
        vectorized migration fallback (provisioner.resize_workload
        semantics, O(devices touched))."""
        c = self.profiles[spec.model]
        b, rl = self._theorem1(spec, c, batch)
        cl = self.cl
        q = self.home.pop(spec.name)
        cl.remove_entry(q, self._slot_at(q, spec.name))
        if self.row_gpus[q] in self.banned:
            # quarantined home device: no same-device fast path — the
            # resize IS the eviction (min-interference move elsewhere)
            self._place(spec, c, b, rl)
            return
        residents = [(s, cc, bb, float(cl.r[q, i]))
                     for i, (s, cc, bb) in enumerate(cl.entries[q])]
        r_a = pmv.alloc_gpus_vec(residents, spec, c, b, rl, self.hw,
                                 budget=cl.bm)
        if r_a is not None and self.shadow:
            resv_q = math.fsum(
                sr for n2, sr in self.shadow.items()
                if n2 != spec.name and self.home.get(n2) == q)
            if math.fsum(r_a) + resv_q > 1.0 + 1e-9:
                r_a = None           # the reservation holds: migrate
        if r_a is not None:
            cl.set_row_r(q, np.array(r_a[:-1]))
            cl.add_entry(q, spec, c, b, r_a[-1])
            self.home[spec.name] = q
        else:
            self._place(spec, c, b, rl)

    def to_plan(self) -> ProvisioningPlan:
        plan = ProvisioningPlan(hardware=self.hardware)
        cl = self.cl
        for q in range(cl.d):
            for i, (s, _, b) in enumerate(cl.entries[q]):
                plan.placements.append(Placement(
                    workload=s, gpu=self.row_gpus[q],
                    r=float(cl.r[q, i]), batch=b))
        plan.n_gpus = sum(1 for q in range(cl.d) if cl.entries[q])
        return plan


# ---------------------------------------------------------------------------
# Drift reconciliation over incremental plan edits
# ---------------------------------------------------------------------------

@dataclass
class PlanEdit:
    """One reconciliation action, recorded for telemetry/benchmarks."""
    t_s: float
    action: str        # "resize" | "remove" | "add" | "split" | "merge"
                       # | "infeasible" | "migrate" (health eviction)
                       # | "readmit" (workload = "device:<gpu>")
                       # | admission layer: "preempt" / "shed" (victim /
                       #   self parked under the cap), "admit" (shed
                       #   workload re-placed), "capped" (growth refused,
                       #   demand queues at the old allocation)
                       # | predictive tier: "forecast" (pre-size /
                       #   pre-split to the forecast rate; rate_to = the
                       #   sized target), "shadow_arm" / "shadow_disarm"
                       #   (Sec. 4.2 reservations granted / released;
                       #   replicas = instances touched)
    workload: str      # BASE workload name (replicas are one workload)
    rate_from: float
    rate_to: float
    burstiness: float
    replicas: int = 1  # replica count AFTER the edit (0 on remove)


class Reconciler:
    """Hysteresis-banded drift detection + incremental plan edits.

    Holds the CURRENT plan (starting from the provisioned one) and the
    per-workload target specs it was last reconciled to.  Each tick
    compares estimator state against those targets; a sustained breach
    (debounce) triggers `resize_workload` at the estimated rate (plus
    headroom on up-drift), departures (`remove_workload`) and
    re-arrivals (`add_workload`).  The queueing budget's burstiness is
    refreshed from the rate-weighted mean CV^2 estimate whenever edits
    are issued, so re-solved budgets track the measured arrival process.
    """

    def __init__(self, plan: ProvisioningPlan,
                 profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec, *,
                 config: Optional[PlannerConfig] = None,
                 budget: Optional[BudgetLike] = None,
                 batch: Optional[str] = None,
                 engine: Optional[str] = None,
                 cfg: Optional[ControllerConfig] = None,
                 telemetry: Optional["telemetry_mod.Telemetry"] = None):
        self.plan = plan
        self.profiles = profiles
        self.hw = hw
        self.cfg = cfg or ControllerConfig()
        self.telemetry = telemetry
        # planner-knob resolution: config= > cfg.planner > the legacy
        # keywords over the controller's joint-batch default
        base = (self.cfg.planner if self.cfg.planner is not None
                else PlannerConfig(batch="joint", k_max=self.cfg.k_max))
        self.planner = planner_config(config, base=base, budget=budget,
                                      batch=batch, engine=engine)
        self.base_bm = resolve(self.planner.budget)
        self.bm = self.base_bm
        self.batch = self.planner.batch
        self.engine = self.planner.engine
        self.k_max = self.planner.k_max
        # one probe cache across ALL edits: repeat (spec, budget) probes
        # — the dominant cost of a reconciliation at large m — are O(1)
        self.probes = prov.ProbeCache()
        # engine="vec": lazily-built persistent VecCluster mirror (the
        # O(devices-touched) hot path); engine="scalar": each edit goes
        # through the plan-in/plan-out provisioner ops (the oracle)
        self._state: Optional[PlanState] = None
        self._state_bm = self.bm
        # targets are keyed by BASE workload name: a replica group is
        # reconciled as ONE workload whose target spec carries the full
        # (summed) rate; the plan holds the per-replica share specs
        self.targets: Dict[str, WorkloadSpec] = {}
        for base, group in replication.group_placements(
                plan.placements).items():
            spec0 = group[0].workload
            if len(group) == 1 and not replication.is_replica(spec0.name):
                self.targets[base] = spec0
            else:
                self.targets[base] = dataclasses.replace(
                    spec0, name=base,
                    rate_rps=sum(p.workload.rate_rps for p in group))
        self.departed: Dict[str, WorkloadSpec] = {}
        self.edits: List[PlanEdit] = []
        self._breach: Dict[str, tuple] = {}    # name -> (kind, streak)
        self._period_ms = 1000.0           # refreshed per reconcile call
        # health-layer quarantine: plan gpu ids banned from placement
        # (every edit path — evictions AND ordinary drift edits — avoids
        # them until readmission)
        self.quarantined: set = set()
        # admission layer (docs/control-plane.md, Overload): workloads
        # shed under the device cap, keyed by BASE name and holding the
        # TRUE target spec.  A shed workload's arrival stream stays
        # visible to its estimator (the simulator drops requests at the
        # instance, not the stream), so its silence on the SERVED side
        # is policy — never a departure — and readmission resumes from
        # live priors instead of re-bootstrapping from zero.
        self.max_devices = self.cfg.max_devices
        self.shed: Dict[str, WorkloadSpec] = {}
        self.brownout: Dict[str, float] = {}     # base -> working mult
        self._readmit_at: Dict[str, float] = {}  # base -> next retry t_s
        self.admission_log: List[tuple] = []     # (t_s, event, detail)
        self._adm = {"preempt": 0, "shed": 0, "readmit": 0, "capped": 0,
                     "brownout_ticks": 0, "brownout_max": 0}
        # predictive tier (cfg.forecast + docs/control-plane.md
        # Forecasting): armed Sec. 4.2 shadow reservations keyed by
        # PLACEMENT name.  Shared by reference with the vec mirror
        # (PlanState.shadow) so every edit path accounts for them; the
        # scalar oracle threads the same book through the provisioner
        # ops' ``reserved=`` map.  Also adopts simulator-armed
        # (shadow=True) reservations at the controller's first tick.
        self.armed: Dict[str, float] = {}
        self._fc_streak: Dict[str, int] = {}  # base -> breach streak
        self._fc_clear: Dict[str, int] = {}   # base -> breach-free ticks
        self._fc_edited: set = set()          # bases pre-sized THIS tick
        # bases with an ACTIVE shadow this tick (fed by the Controller):
        # an active reservation is never released mid-drain
        self.shadow_active_bases: set = set()

    # -- drift detection ----------------------------------------------------

    def _departed_now(self, name: str, est: ArrivalEstimator) -> bool:
        """A zero-arrival stretch long enough that the provisioned rate
        would have produced >= depart_missed arrivals: the workload left
        (much faster than waiting for the EWMA to decay to ~zero).
        Requires PRIOR activity — a workload that has never sent a
        request is "not started yet", not departed: reclaiming its
        capacity would manufacture a cold start the moment it begins."""
        return (est.ever_active
                and est.empty_ms * self._orig_rate(name) / 1000.0
                >= self.cfg.depart_missed)

    def _drift_kind(self, name: str, est: ArrivalEstimator) -> str:
        """"up" / "down" / "burst" / "" (in-band).

        The rate bands are widened to `noise_sigmas` sigmas of the
        smoothed Poisson counting noise, so low-rate workloads need a
        proportionally larger relative drift — that is what keeps a
        noise-only (constant-rate Poisson) run at zero reconfigurations.
        """
        cfg = self.cfg
        plan_rate = (self.targets[name].rate_rps
                     if name in self.targets else 0.0)
        if plan_rate <= 0.0:     # departed: any sustained rate re-adds it
            return "up" if (est.rate_rps
                            > cfg.depart_frac * self._orig_rate(name)
                            and est.empty_ms == 0.0) else ""
        if not est.ever_active:  # no traffic yet: the provisioned plan
            return ""            # is the best prior, leave it alone
        noise = cfg.noise_sigmas * est.rate_sigma() / plan_rate
        if est.projected_rps / plan_rate > 1.0 + max(cfg.band_up, noise):
            return "up"
        if (est.rate_rps / plan_rate < 1.0 - max(cfg.band_down, noise)
                or self._departed_now(name, est)):
            return "down"
        if (self.bm.mode == "queueing"
                and est.n_gaps >= cfg.min_gap_obs
                and est.cv2 > self.bm.burstiness + cfg.burst_band):
            return "burst"       # burstier than budgeted: tighten
        return ""

    def _orig_rate(self, name: str) -> float:
        spec = (self.targets.get(name) or self.departed.get(name)
                or self.shed.get(name))
        return max(spec.rate_rps, 1e-9) if spec is not None else 1e-9

    def _cluster_cv2(self, estimators: Dict[str, ArrivalEstimator]) -> float:
        """Rate-weighted mean CV^2 across workloads with enough data —
        the single `BudgetModel.burstiness` the budget split consumes."""
        num = den = 0.0
        for est in estimators.values():
            if est.n_gaps >= self.cfg.min_gap_obs:
                num += est.rate_rps * est.cv2
                den += est.rate_rps
        return num / den if den > 0.0 else self.bm.burstiness

    # -- reconciliation -----------------------------------------------------

    def reconcile(self, now_s: float,
                  estimators: Dict[str, ArrivalEstimator],
                  backlog: Optional[Dict[str, float]] = None,
                  period_ms: float = 1000.0) -> bool:
        """One control period: returns True when the plan changed.

        ``backlog`` maps workload -> queued requests at the tick (from
        the live instances); it feeds the resize target so recovering
        from an under-capacity stretch budgets DRAIN capacity, not just
        the go-forward arrival rate.
        """
        cfg = self.cfg
        self._period_ms = period_ms
        need = {"up": cfg.debounce_up, "down": cfg.debounce_down,
                "burst": cfg.debounce_burst}
        pending: List[str] = []
        for name, est in estimators.items():
            if name in self.shed:
                # admission-layer shed: the workload's silence on the
                # served side is POLICY, not drift or departure — the
                # readmission pass below owns its lifecycle
                continue
            kind = self._drift_kind(name, est)
            prev_kind, prev_n = self._breach.get(name, ("", 0))
            # kind-aware debounce: consecutive same-kind breaches;
            # a departure-length silence bypasses it (nothing noisy
            # about depart_missed expected arrivals not showing up)
            n = prev_n + 1 if kind and kind == prev_kind else (1 if kind
                                                               else 0)
            self._breach[name] = (kind, n)
            if kind and (n >= need[kind]
                         or (kind == "down"
                             and self._departed_now(name, est))):
                pending.append(name)
        changed = False
        if cfg.forecast:
            # proactive tier BEFORE the reactive pass: the rate signal
            # LEADS the p99 signal, and a forecast edit raises its
            # base's target so the reactive drift check below compares
            # against the post-edit plan — the two tiers cannot
            # double-fire on one signal in this order either, and the
            # forecast keeps its one-tick head start (a 2 s flash crowd
            # is over before a reactive resize lands)
            changed |= self._forecast_pass(now_s, estimators, backlog or {})
            if self._fc_edited:
                # a base the forecast just pre-sized must not ALSO fire
                # reactively this tick: its group was re-placed against
                # the raised target, so the reactive reading (and the
                # group snapshot it would edit) are both stale
                pending = [n for n in pending if n not in self._fc_edited]
                for n in self._fc_edited:
                    self._breach[n] = ("", 0)
        if pending or self.shed:
            if pending and self.base_bm.mode == "queueing":
                # online burstiness, FLOORED at the provisioned model's:
                # a deterministic trace's cv2 ~ 0 must not loosen budgets
                # mid-drift (tail slack is what absorbs the transition),
                # while a spike train's cv2 >> 1 tightens them
                self.bm = self.base_bm.with_burstiness(
                    max(self._cluster_cv2(estimators),
                        self.base_bm.burstiness))
            self._ensure_state()
            if self.shed:
                changed |= self._readmit_shed(now_s, estimators)
            backlog = backlog or {}
            for name in pending:
                if name in self.shed:
                    # preempted by an EARLIER edit this same tick (its
                    # drift breach predates the preemption decision)
                    self._breach[name] = ("", 0)
                    continue
                est = estimators[name]
                changed |= self._apply(now_s, name, est,
                                       backlog.get(name, 0.0))
                self._breach[name] = ("", 0)
        # per-tick brownout depth record (admission telemetry): only
        # while the admission layer is active, so a cap-slack run's log
        # stays empty and its output byte-identical to pre-overload
        depth = len(self.brownout)
        if depth or self.shed:
            self.admission_log.append((now_s, "tick", depth))
        if depth:
            self._adm["brownout_ticks"] += 1
            self._adm["brownout_max"] = max(self._adm["brownout_max"],
                                            depth)
        if changed and self._state is not None:
            self.plan = self._state.to_plan()
        return changed

    def _ensure_state(self) -> None:
        """Lazily build / budget-sync the persistent VecCluster mirror
        (engine="vec" only; the scalar oracle edits plan-in/plan-out)."""
        if self.engine != "vec":
            return
        if self._state is None:
            self._state = PlanState(self.plan, self.profiles, self.hw,
                                    budget=self.bm,
                                    backend=self.planner.backend,
                                    probes=self.probes,
                                    max_devices=self.max_devices,
                                    shadow=self.armed)
            self._state_bm = self.bm
            self._state.banned = set(self.quarantined)
        elif self.bm != self._state_bm:
            self._state.set_budget(self.bm)
            self._state_bm = self.bm

    # -- health-layer actions (quarantine / evict / readmit) ----------------

    def quarantine(self, gpus) -> None:
        """Ban devices from every placement path until readmission."""
        self.quarantined.update(int(g) for g in gpus)
        if self._state is not None:
            self._state.banned = set(self.quarantined)

    def readmit(self, now_s: float, gpus) -> None:
        """Lift the ban (probation expired); recorded as edits."""
        for g in gpus:
            self.quarantined.discard(int(g))
            self.edits.append(PlanEdit(now_s, "readmit", f"device:{g}",
                                       0.0, 0.0, self.bm.burstiness, 0))
        if self._state is not None:
            self._state.banned = set(self.quarantined)

    def _fitted_util(self, p: Placement) -> float:
        """Fitted-model utilization of one placement in isolation:
        rate x predicted t_inf(batch, r) / (1000 x batch).  Ignoring
        co-resident interference under-estimates — the residual guard
        in the eviction split decision covers both gaps."""
        c = self.profiles[p.workload.model]
        t = pm.predict_device(
            [pm.PlacedWorkload(coeffs=c, batch=p.batch, r=p.r)],
            self.hw).per_workload[0].t_inf
        return p.workload.rate_rps * t / (1000.0 * p.batch)

    def evict(self, now_s: float) -> bool:
        """Migrate every live-rate placement off quarantined devices to
        min-interference homes elsewhere.  Two shapes per victim group:

        * capacity-preserving move — the placement is re-homed with its
          planned ``(batch, r)`` PINNED (banned `alloc_all` sweep with
          the fresh-device fallback), never re-derived: the budget may
          have drifted since provisioning (measured burstiness refresh),
          and re-running Theorem 1 at eviction time can hand a heavy
          victim a smaller batch than it was provisioned with — small
          enough to push its TRUE utilization past 1 on any device.
        * drain split — a victim pinned at its throughput ceiling can
          never drain the backlog it accumulated during detection
          latency (headroom ~0).  When the group's worst fitted
          utilization x `health_residual_guard` exceeds
          `health_drain_util`, the whole group is re-placed as enough
          equal-share replicas — each pinned at the group's planned
          capacity point — to put every member under that target,
          buying the drain real headroom.

        Zero-share parked replicas stay put: there is no traffic to
        save."""
        cfg = self.cfg
        bad = self.quarantined
        if not bad:
            return False
        victims = [p for p in self.plan.placements
                   if p.gpu in bad and p.workload.rate_rps > 0.0]
        if not victims:
            return False
        self._ensure_state()
        by_base: Dict[str, List[Placement]] = {}
        for p in victims:
            by_base.setdefault(replication.base_name(p.workload.name),
                               []).append(p)
        for base in sorted(by_base):
            rate = sum(p.workload.rate_rps for p in by_base[base])
            group = self._group(base)
            c = self.profiles[by_base[base][0].workload.model]
            k_cur = max(1, len(group))
            k_new = k_cur
            if self.k_max > 1:
                util = max(self._fitted_util(p) for p in group) \
                    * cfg.health_residual_guard
                if util > cfg.health_drain_util:
                    k_new = min(self.k_max,
                                max(k_cur + 1,
                                    math.ceil(k_cur * util
                                              / cfg.health_drain_util)))
            plan0 = self._checkpoint()
            try:
                if k_new > k_cur:
                    total = replication.group_rate(
                        [p.workload for p in group])
                    proto = dataclasses.replace(
                        by_base[base][0].workload, name=base,
                        rate_rps=total)
                    reps = replication.make_replicas(proto, k_new)
                    # pin every replica at the group's planned capacity
                    # point (heaviest member's batch and grant): per-
                    # replica serving capacity is preserved while the
                    # rate share drops 1/k — that gap IS the drain
                    # headroom.  A re-derived Theorem 1 placement at the
                    # share rate would hand back a minimum-capacity
                    # allocation instead, and minimum capacity is
                    # exactly what cannot drain.
                    pin = max(((p.batch, p.r) for p in group),
                              key=lambda t: (t[0], t[1]))
                    for p in group:
                        self._remove_name(p.workload.name)
                    for rs in reps:
                        self._add_spec(rs, pin=pin)
                else:
                    for p in by_base[base]:
                        self._remove_name(p.workload.name)
                        self._add_spec(p.workload, pin=(p.batch, p.r))
            except prov.DeviceCapError:
                # the cap refuses the re-home: leave the victim on the
                # quarantined device (honest degraded state) rather
                # than half-moving its group
                self._restore(plan0)
                self._adm["capped"] += 1
                self.admission_log.append((now_s, "capped", base))
                continue
            self.edits.append(PlanEdit(
                now_s, "migrate", base, rate, rate,
                self.bm.burstiness, k_new))
        if self._state is not None:
            self.plan = self._state.to_plan()
        return True

    # -- plan-edit plumbing (replica-aware) ---------------------------------

    def _group(self, base: str) -> List[Placement]:
        """Current replica placements of one base workload.

        A direct prefix scan rather than `replication.group_placements`:
        rebuilding the FULL plan's group index per edit was a dominant
        controller-overhead term at m=1000 (one O(plan) dict build and
        per-group sort per probe).  Same membership and replica order —
        replica names are exactly ``base + SEP + int``.
        """
        pref = base + replication.SEP
        group = [p for p in self.plan.placements
                 if p.workload.name == base
                 or p.workload.name.startswith(pref)]
        group.sort(key=lambda p: replication.replica_index(
            p.workload.name) or 0)
        return group

    def _reserved_map(self) -> Optional[Dict[int, float]]:
        """Plan-gpu -> armed shadow reservation, for the scalar
        provisioner ops (the vec mirror reads the shared book
        directly).  None while nothing is armed — the historical
        call signature, byte-identical behavior."""
        if not self.armed:
            return None
        by_name = {p.workload.name: p.gpu for p in self.plan.placements}
        gpus: Dict[int, float] = {}
        for name, sr in self.armed.items():
            g = by_name.get(name)
            if g is not None:
                gpus[g] = gpus.get(g, 0.0) + sr
        return gpus or None

    def _remove_name(self, name: str) -> None:
        # a removed placement's reservation leaves with it: reservations
        # are valid only for the placement they were computed against
        self.armed.pop(name, None)
        if self._state is not None:
            self._state.remove(name)
            if self.telemetry is not None:
                self.telemetry.count("prov_remove")
        else:
            self.plan = prov.remove_workload(self.plan, name,
                                             telemetry=self.telemetry)

    def _add_spec(self, spec: WorkloadSpec,
                  pin: Optional[tuple] = None) -> None:
        if self._state is not None:
            self._state.add(spec, batch=self.batch, pin=pin)
            if self.telemetry is not None:
                self.telemetry.count("prov_add")
        else:
            self.plan = prov.add_workload(
                self.plan, spec, self.profiles, self.hw,
                config=self.planner.replace(budget=self.bm),
                exclude_gpus=frozenset(self.quarantined) or None,
                pin=pin, max_devices=self.max_devices,
                reserved=self._reserved_map(),
                telemetry=self.telemetry)

    def _resize_spec(self, spec: WorkloadSpec) -> None:
        # the resized placement's own reservation was computed against
        # its OLD allocation: drop it (the forecast pass re-arms against
        # the new one on its next breach tick)
        self.armed.pop(spec.name, None)
        if self._state is not None:
            self._state.resize(spec, batch=self.batch)
            if self.telemetry is not None:
                self.telemetry.count("prov_resize")
        else:
            self.plan = prov.resize_workload(
                self.plan, spec, self.profiles, self.hw,
                config=self.planner.replace(budget=self.bm),
                max_devices=self.max_devices,
                reserved=self._reserved_map(),
                telemetry=self.telemetry)

    def _validate(self, reps: List[WorkloadSpec],
                  c: WorkloadCoefficients) -> bool:
        """Pre-flight Theorem 1 on every replica spec so a multi-replica
        edit either applies atomically or not at all (a mid-loop
        InfeasibleError would leave the group half-edited)."""
        try:
            for rs in reps:
                self.probes.theorem1(rs, c, self.hw, self.bm, self.batch)
        except prov.InfeasibleError:
            return False
        return True

    def _apply(self, now_s: float, name: str, est: ArrivalEstimator,
               backlog: float) -> bool:
        cfg = self.cfg
        cur = self.targets.get(name)
        orig = cur if cur is not None else self.departed[name]
        plan_rate = cur.rate_rps if cur is not None else 0.0
        group = self._group(name)
        k_cur = len(group)

        # departure: sustained near-zero rate or a long-enough silence
        if cur is not None and (
                est.rate_rps < cfg.depart_frac * self._orig_rate(name)
                or self._departed_now(name, est)):
            for p in group:
                self._remove_name(p.workload.name)
            self.departed[name] = cur
            del self.targets[name]
            self.edits.append(PlanEdit(now_s, "remove", name,
                                       plan_rate, 0.0, self.bm.burstiness,
                                       0))
            return True

        new_rate = est.rate_rps
        if est.projected_rps > plan_rate:   # up-drift: lead the ramp and
            new_rate = est.projected_rps * (1.0 + cfg.headroom)
            # budget capacity to drain the accumulated backlog within
            # ~one control period (capped so a transient spike cannot
            # demand an absurd allocation)
            drain = min(backlog * 1000.0 / max(self._period_ms, 1e-9),
                        cfg.drain_cap * est.rate_rps)
            new_rate += drain
        new_spec = dataclasses.replace(orig, name=name, rate_rps=new_rate)
        c = self.profiles[orig.model]
        # scale-out/scale-in decision: the smallest solo-feasible replica
        # count at the new rate (None = hopeless at ANY k).  Up-drift
        # never merges in the same edit (freeing capacity mid-ramp is
        # the expensive error — scale-in rides the slow, debounced down
        # path like any release), and a hopeless workload KEEPS its
        # current membership: merging a working group down to one
        # guaranteed-violating instance would destroy capacity the
        # residual still uses.
        k_need = self.probes.required_replicas(new_spec, c, self.hw,
                                               self.bm, self.batch,
                                               k_max=self.k_max) \
            if self.k_max > 1 else 1
        updrift = est.projected_rps > plan_rate
        try:
            action, k_new = self._edit(name, new_spec, c, k_need,
                                       cur, group, k_cur, updrift)
        except prov.DeviceCapError:
            # the fleet cap — not physics — refused the edit: route the
            # demand through the admission layer (preempt -> brownout ->
            # queue-or-shed) instead of reporting it infeasible
            return self._overloaded(now_s, name, new_spec, c, k_need,
                                    cur, group, k_cur, updrift,
                                    plan_rate)
        except prov.InfeasibleError:
            # beyond any feasible allocation even split k_max ways:
            # keep the current placement, report honestly via the edits
            self.edits.append(PlanEdit(now_s, "infeasible", name,
                                       plan_rate, new_rate,
                                       self.bm.burstiness, k_cur))
            return False
        self.brownout.pop(name, None)    # a true-SLO edit landed:
        self.targets[name] = new_spec    # the brownout has recovered
        self.edits.append(PlanEdit(now_s, action, name, plan_rate,
                                   new_rate, self.bm.burstiness, k_new))
        return True

    # -- transactional edit application -------------------------------------

    def _checkpoint(self) -> tuple:
        """Materialized recovery point for a multi-op edit sequence: the
        device cap can fire MID-sequence (the Theorem-1 pre-flight cannot
        see placement-time cap pressure), and both engine paths must roll
        back to exactly this plan.  The armed shadow book rides along —
        an edit that dropped or granted reservations before failing must
        hand them back too (tests/test_forecast.py injects exactly
        that failure)."""
        plan = self._state.to_plan() if self._state is not None \
            else self.plan
        return plan, dict(self.armed)

    def _restore(self, cp: tuple) -> None:
        """Roll back to checkpoint ``cp``.  The scalar path re-adopts the
        plan directly (the provisioner ops are plan-in/plan-out); the vec
        mirror is discarded and rebuilt from it — the rebuild's
        gpu-sorted row order matches what the incremental history
        produced, so every subsequent allocation stays identical to the
        scalar oracle's.  The armed book is restored IN PLACE: the
        rebuilt mirror shares the same dict."""
        plan0, armed0 = cp
        self.plan = plan0
        self.armed.clear()
        self.armed.update(armed0)
        if self._state is not None:
            self._state = None
            self._ensure_state()

    def _edit(self, name: str, new_spec: WorkloadSpec,
              c: WorkloadCoefficients, k_need: Optional[int],
              cur: Optional[WorkloadSpec], group: List[Placement],
              k_cur: int, updrift: bool) -> tuple:
        """Apply one workload's plan edit atomically; returns
        ``(action, k_new)`` or raises (`DeviceCapError` /
        `InfeasibleError`) with the plan rolled back to its pre-edit
        state."""
        if cur is None:               # re-arrival of a departed workload
            reps = replication.make_replicas(new_spec, k_need or 1)
            if len(reps) > 1 and not self._validate(reps, c):
                raise prov.InfeasibleError(name)
            plan0 = self._checkpoint()
            try:
                for rs in reps:
                    self._add_spec(rs)
            except prov.InfeasibleError:
                self._restore(plan0)
                raise
            del self.departed[name]
            return "add", len(reps)
        if k_need is None:
            k_new = max(k_cur, 1)        # hopeless: keep membership
        elif updrift:
            k_new = max(k_cur, k_need)
        else:
            k_new = k_need
        k_new = max(1, min(k_new, self.k_max))
        reps = replication.make_replicas(new_spec, k_new)
        same = [r.name for r in reps] == [p.workload.name
                                          for p in group]
        # pre-flight anything non-atomic: a membership change mutates
        # the plan across several remove/add calls, and a multi-replica
        # resize across several resize calls — a mid-loop physics raise
        # would leave the group half-edited (the checkpoint additionally
        # covers cap errors, which no pre-flight can rule out)
        if (not same or len(reps) > 1) and not self._validate(reps, c):
            raise prov.InfeasibleError(name)
        plan0 = self._checkpoint()
        try:
            if same:
                # same membership: per-replica same-device resize
                for rs in reps:
                    self._resize_spec(rs)
                return "resize", k_new
            # membership changes: re-place the whole group (the
            # removed rate shares renormalize over the new k)
            for p in group:
                self._remove_name(p.workload.name)
            for rs in reps:
                self._add_spec(rs)
            return ("split" if k_new > k_cur else "merge"), k_new
        except prov.InfeasibleError:
            self._restore(plan0)
            raise

    # -- admission layer (device cap: preempt / brownout / shed) ------------

    def _shed_base(self, now_s: float, base: str, action: str) -> None:
        """Park one base workload under the cap: its placements leave
        the plan (freeing allocation), its target moves to ``shed``, and
        `Controller._apply_plan` marks its instances shed so the
        simulator drops (and counts) their requests."""
        for p in self._group(base):
            self._remove_name(p.workload.name)
        spec = self.targets.pop(base)
        self.shed[base] = spec
        self._readmit_at[base] = now_s + self.cfg.readmit_backoff_s
        self.brownout.pop(base, None)
        self._adm["preempt" if action == "preempt" else "shed"] += 1
        self.admission_log.append((now_s, action, base))
        self.edits.append(PlanEdit(now_s, action, base, spec.rate_rps,
                                   0.0, self.bm.burstiness, 0))

    def _overloaded(self, now_s: float, name: str,
                    new_spec: WorkloadSpec, c: WorkloadCoefficients,
                    k_need: Optional[int], cur: Optional[WorkloadSpec],
                    group: List[Placement], k_cur: int, updrift: bool,
                    plan_rate: float) -> bool:
        """The device cap refused ``name``'s edit.  In order: preempt
        strictly-lower-priority groups (worst footprint first, the
        `replication.preemption_order`), then retry under a brownout
        (loosened WORKING SLO shrinks the demand), then queue-or-shed.
        Every decision lands in ``admission_log`` and ``edits``."""
        cfg = self.cfg
        pr = int(new_spec.priority)
        # 1) preemption: shed cheaper classes until the grant fits or
        # victims run out (the order is priority-ascending, so the first
        # victim at or above our class ends the hunt)
        groups = replication.group_placements(self.plan.placements)
        for victim in replication.preemption_order(groups):
            if victim == name or victim not in self.targets:
                continue
            if replication.group_priority(groups[victim]) >= pr:
                break
            self._shed_base(now_s, victim, "preempt")
            try:
                action, k_new = self._edit(name, new_spec, c, k_need,
                                           cur, group, k_cur, updrift)
            except prov.DeviceCapError:
                continue              # freed too little: next victim
            except prov.InfeasibleError:
                break                 # physics says no: stop shedding
            self.brownout.pop(name, None)
            self.targets[name] = new_spec
            self.edits.append(PlanEdit(now_s, action, name, plan_rate,
                                       new_spec.rate_rps,
                                       self.bm.burstiness, k_new))
            return True
        # 2) brownout: retry with a loosened WORKING SLO.  The target
        # keeps the true SLO — every later breach retries recovery, and
        # per-class accounting measures against ``slo0`` — so this only
        # changes what the planner is asked for, never what is reported.
        if cfg.brownout_mult > 1.0:
            loose = dataclasses.replace(
                new_spec, slo_ms=new_spec.slo_ms * cfg.brownout_mult)
            k_loose = self.probes.required_replicas(
                loose, c, self.hw, self.bm, self.batch,
                k_max=self.k_max) if self.k_max > 1 else 1
            try:
                action, k_new = self._edit(name, loose, c, k_loose,
                                           cur, group, k_cur, updrift)
            except prov.InfeasibleError:
                action = ""
            if action:
                self.brownout[name] = cfg.brownout_mult
                self.targets[name] = new_spec
                self.admission_log.append((now_s, "brownout", name))
                self.edits.append(PlanEdit(now_s, action, name,
                                           plan_rate, new_spec.rate_rps,
                                           self.bm.burstiness, k_new))
                return True
        # 3) queue-or-shed: a workload still holding capacity KEEPS it
        # and queues (the cap refused growth, not service); a re-arrival
        # with nothing placed is shed outright until capacity frees
        self._adm["capped"] += 1
        self.admission_log.append((now_s, "capped", name))
        if cur is not None:
            self.edits.append(PlanEdit(now_s, "capped", name, plan_rate,
                                       new_spec.rate_rps,
                                       self.bm.burstiness, k_cur))
            return False
        del self.departed[name]
        self.shed[name] = dataclasses.replace(new_spec,
                                              rate_rps=plan_rate
                                              if plan_rate > 0.0
                                              else new_spec.rate_rps)
        self._readmit_at[name] = now_s + cfg.readmit_backoff_s
        self._adm["shed"] += 1
        self.edits.append(PlanEdit(now_s, "shed", name, 0.0,
                                   new_spec.rate_rps,
                                   self.bm.burstiness, 0))
        return True

    def _readmit_shed(self, now_s: float,
                      estimators: Dict[str, "ArrivalEstimator"]) -> bool:
        """Per-tick readmission pass, highest priority first.  A shed
        workload whose demand ACTUALLY left (the estimator still sees
        its arrival stream) moves to the ordinary departure book; the
        rest retry placement under the cap with exponential-free backoff
        (`readmit_backoff_s`), resuming from live estimator priors."""
        changed = False
        for base in sorted(self.shed,
                           key=lambda b: (-self.shed[b].priority, b)):
            est = estimators.get(base)
            if est is not None and self._departed_now(base, est):
                self.departed[base] = self.shed.pop(base)
                self._readmit_at.pop(base, None)
                self.admission_log.append((now_s, "shed-departed", base))
                self.edits.append(PlanEdit(now_s, "remove", base, 0.0,
                                           0.0, self.bm.burstiness, 0))
                continue
            if now_s < self._readmit_at.get(base, 0.0):
                continue
            spec0 = self.shed[base]
            rate = spec0.rate_rps
            if est is not None and est.ever_active:
                rate = max(est.rate_rps, est.projected_rps)
            trial = dataclasses.replace(spec0, rate_rps=rate)
            c = self.profiles[spec0.model]
            k = self.probes.required_replicas(trial, c, self.hw, self.bm,
                                              self.batch,
                                              k_max=self.k_max) \
                if self.k_max > 1 else 1
            try:
                reps = replication.make_replicas(trial, k or 1)
                if not self._validate(reps, c):
                    raise prov.InfeasibleError(base)
                plan0 = self._checkpoint()
                try:
                    for rs in reps:
                        self._add_spec(rs)
                except prov.InfeasibleError:
                    self._restore(plan0)
                    raise
            except prov.InfeasibleError:
                # still capped (or still infeasible): back off and retry
                self._readmit_at[base] = now_s \
                    + self.cfg.readmit_backoff_s
                continue
            del self.shed[base]
            self._readmit_at.pop(base, None)
            self.targets[base] = trial
            self._adm["readmit"] += 1
            self.admission_log.append((now_s, "readmit", base))
            self.edits.append(PlanEdit(now_s, "admit", base, 0.0, rate,
                                       self.bm.burstiness, len(reps)))
            changed = True
        return changed

    # -- predictive tier (forecast-armed Sec. 4.2 shadows) -------------------

    def _armed_names(self, base: str) -> List[str]:
        pref = base + replication.SEP
        return [n for n in self.armed
                if n == base or n.startswith(pref)]

    def _forecast_pass(self, now_s: float,
                       estimators: Dict[str, ArrivalEstimator],
                       backlog: Dict[str, float]) -> bool:
        """One tick of the proactive tier: per base workload, compare the
        horizon forecast against the plan target behind its own
        (noise-widened, debounced) band; a sustained breach pre-sizes /
        pre-splits the group to the forecast rate AND arms Sec. 4.2
        shadows on its devices, both through the same transactional edit
        machinery as reactive drift.  Runs BEFORE the reactive pass — the
        rate signal leads the p99 signal, and a forecast edit raises the
        target the reactive drift check is then re-evaluated against, so
        the two tiers never double-fire on one signal.  Breach-free
        for `forecast_hold` ticks releases a base's reservations, unless
        one is ACTIVE (the Controller feeds ``shadow_active_bases``)."""
        cfg = self.cfg
        changed = False
        acted_any = False
        self._fc_edited.clear()
        for base in sorted(self.targets):
            est = estimators.get(base)
            cur = self.targets[base]
            if est is None or not est.ever_active or cur.rate_rps <= 0.0:
                continue
            plan_rate = cur.rate_rps
            f = est.forecast_rps(cfg.forecast_horizon)
            band = max(cfg.forecast_band,
                       cfg.forecast_sigmas * est.rate_sigma() / plan_rate)
            if f / plan_rate > 1.0 + band:
                self._fc_clear[base] = 0
                streak = self._fc_streak.get(base, 0) + 1
                self._fc_streak[base] = streak
                if streak >= cfg.forecast_debounce:
                    if (not acted_any
                            and self.base_bm.mode == "queueing"):
                        # same online-burstiness tightening the reactive
                        # pass applies before its edits: a spike train's
                        # cv^2 >> 1 must tighten the forecast pre-size's
                        # budgets too (floored at the provisioned model)
                        self.bm = self.base_bm.with_burstiness(
                            max(self._cluster_cv2(estimators),
                                self.base_bm.burstiness))
                    acted_any = True
                    changed |= self._forecast_act(
                        now_s, base, est, f, backlog.get(base, 0.0))
            else:
                self._fc_streak[base] = 0
                if self._armed_names(base):
                    clear = self._fc_clear.get(base, 0) + 1
                    self._fc_clear[base] = clear
                    if (clear >= cfg.forecast_hold
                            and base not in self.shadow_active_bases):
                        changed |= self._disarm(now_s, base)
        return changed

    def _forecast_act(self, now_s: float, base: str,
                      est: ArrivalEstimator, f: float,
                      backlog: float = 0.0) -> bool:
        """Act on a debounced forecast breach: pre-size (and pre-split,
        when `required_replicas` says the forecast rate needs it) the
        group to the forecast target, then arm shadows on every device
        the group lands on.  A cap- or physics-refused pre-size still
        arms — the reservation costs nothing until activation and is the
        cheaper half of the insurance.  The proactive tier never invokes
        the admission layer: preempting live workloads on a prediction
        is the wrong trade."""
        cfg = self.cfg
        self._ensure_state()      # lazy: only a tick that ACTS builds
        cur = self.targets[base]  # the vec mirror
        plan_rate = cur.rate_rps
        c = self.profiles[cur.model]
        # same sizing rule as the reactive up-drift path, driven by the
        # HORIZON forecast instead of the one-period projection: lead
        # the ramp, plus capacity to drain the backlog the spike has
        # already queued within ~one control period (capped)
        target = max(f, est.projected_rps) * (1.0 + cfg.headroom)
        target += min(backlog * 1000.0 / max(self._period_ms, 1e-9),
                      cfg.drain_cap * est.rate_rps)
        new_spec = dataclasses.replace(cur, name=base, rate_rps=target)
        group = self._group(base)
        k_cur = len(group)
        k_need = self.probes.required_replicas(
            new_spec, c, self.hw, self.bm, self.batch,
            k_max=self.k_max) if self.k_max > 1 else 1
        changed = False
        try:
            action, k_new = self._edit(base, new_spec, c, k_need, cur,
                                       group, k_cur, True)
        except (prov.DeviceCapError, prov.InfeasibleError):
            action, k_new = "", k_cur
        if action:
            self.targets[base] = new_spec
            self._fc_edited.add(base)
            self.edits.append(PlanEdit(now_s, "forecast", base,
                                       plan_rate, target,
                                       self.bm.burstiness, k_new))
            changed = True
        changed |= self._arm_shadows(now_s, base, plan_rate, f)
        if changed:
            self._fc_streak[base] = 0
        return changed

    def _device_used(self, gpu: int, q: Optional[int]) -> float:
        """Live r committed on one device (exactly-rounded fsum, so the
        vec mirror and the scalar plan agree bit-for-bit regardless of
        summation order), plus every armed reservation homed there."""
        if self._state is not None and q is not None:
            st = self._state
            used = math.fsum(float(st.cl.r[q, i])
                             for i in range(len(st.cl.entries[q])))
            resv = math.fsum(sr for n, sr in self.armed.items()
                             if st.home.get(n) == q)
        else:
            used = math.fsum(p.r for p in self.plan.placements
                             if p.gpu == gpu)
            by_name = {p.workload.name: p.gpu
                       for p in self.plan.placements}
            resv = math.fsum(sr for n, sr in self.armed.items()
                             if by_name.get(n) == gpu)
        return used + resv

    def _arm_shadows(self, now_s: float, base: str, plan_rate: float,
                     f: float) -> bool:
        """Reserve Sec. 4.2 shadow capacity (`shadow_extra`, capped by
        the device's free share) for every replica of ``base`` that does
        not already hold one.  Arming only writes the book — the
        Controller maps it onto ``inst.shadow_r`` and the simulator's
        monitor tick activates it the moment the window p99 breaches the
        SLO, well inside the adjust period a reactive resize waits for."""
        cfg = self.cfg
        st = self._state
        armed_any = False
        if st is not None:
            pref = base + replication.SEP
            members = sorted(
                (n for n in st.home if n == base or n.startswith(pref)),
                key=lambda n: replication.replica_index(n) or 0)
            homes = [(n, st.row_gpus[st.home[n]], st.home[n])
                     for n in members]
        else:
            homes = [(p.workload.name, p.gpu, None)
                     for p in self._group(base)]
        for name, gpu, q in homes:
            if self.armed.get(name, 0.0) > 0.0:
                continue
            free_r = 1.0 - self._device_used(gpu, q)
            sr = min(cfg.shadow_extra, max(0.0, free_r))
            if sr <= 1e-12:
                continue
            self.armed[name] = sr
            armed_any = True
        if armed_any:
            self.edits.append(PlanEdit(now_s, "shadow_arm", base,
                                       plan_rate, f, self.bm.burstiness,
                                       len(homes)))
        return armed_any

    def _disarm(self, now_s: float, base: str) -> bool:
        """Release ``base``'s reservations (forecast clear for
        `forecast_hold` ticks, none active): the freed capacity returns
        to the placement sweeps and the Controller zeroes the live
        instances' ``shadow_r`` on apply."""
        names = self._armed_names(base)
        if not names:
            return False
        for n in names:
            del self.armed[n]
        self._fc_clear[base] = 0
        rate = self.targets[base].rate_rps if base in self.targets \
            else 0.0
        self.edits.append(PlanEdit(now_s, "shadow_disarm", base, rate,
                                   rate, self.bm.burstiness, len(names)))
        return True

    def overload_stats(self) -> Dict[str, float]:
        """Admission-layer counters for `SimResult.stats` — EMPTY until
        the first admission decision, which is what keeps a cap-slack
        run's stats byte-identical to the pre-overload build."""
        a = self._adm
        if not (a["preempt"] or a["shed"] or a["readmit"] or a["capped"]
                or a["brownout_ticks"]):
            return {}
        return {
            "overload_active": 1.0,
            "admission_preemptions": float(a["preempt"]),
            "admission_shed_workloads": float(a["shed"]),
            "admission_readmits": float(a["readmit"]),
            "admission_capped_edits": float(a["capped"]),
            "brownout_ticks": float(a["brownout_ticks"]),
            "brownout_depth_max": float(a["brownout_max"]),
            "shed_workloads_final": float(len(self.shed)),
        }


# ---------------------------------------------------------------------------
# The adjust_fn adapter
# ---------------------------------------------------------------------------

class Controller:
    """Closed-loop controller: pass as ``adjust_fn`` with
    ``adjust_scope="cluster"`` (it needs the whole cluster per tick).

    Wiring::

        ctl = Controller(plan, profiles, hw)
        res = simulate_plan(plan, models, hw, trace=trace,
                            adjust_fn=ctl, adjust_scope="cluster",
                            adjust_period_s=1.0)

    Stateful: construct a fresh instance per simulation run.  The
    reconciled plan is ``ctl.plan``; reconfiguration counts/latency land
    in ``SimResult.stats`` (``n_reconfigs`` / ``reconfig_latency_ms``).
    """

    def __init__(self, plan: ProvisioningPlan,
                 profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec, *,
                 config: Optional[PlannerConfig] = None,
                 budget: Optional[BudgetLike] = None,
                 batch: Optional[str] = None,
                 engine: Optional[str] = None,
                 cfg: Optional[ControllerConfig] = None,
                 telemetry: Optional["telemetry_mod.Telemetry"] = None):
        self.cfg = cfg or ControllerConfig()
        self.telemetry = telemetry
        self.reconciler = Reconciler(plan, profiles, hw, config=config,
                                     budget=budget, batch=batch,
                                     engine=engine, cfg=self.cfg,
                                     telemetry=telemetry)
        bm = self.reconciler.base_bm
        # one estimator per BASE workload: replicas of one workload feed
        # a single merged arrival estimate (their slices partition the
        # pooled stream, so the merge IS the workload's arrival process)
        self.estimators: Dict[str, ArrivalEstimator] = {
            base: ArrivalEstimator(
                sum(p.workload.rate_rps for p in group), self.cfg,
                burstiness=bm.burstiness)
            for base, group in replication.group_placements(
                plan.placements).items()}
        self.health = (HealthMonitor(profiles, hw, self.cfg,
                                     telemetry=telemetry)
                       if self.cfg.health else None)
        self._canary = None
        self._last_s = 0.0
        self.n_ticks = 0
        # (t_s, $/h) after each tick: the cost the reconciled plan would
        # bill, so benchmarks can integrate savings from departures and
        # the price of ramp capacity over the run, not just endpoints.
        # Bounded ring (cfg.cost_retention newest rows; .total/.dropped
        # count overflow) — the unbounded list it replaces is still
        # readable through the deprecated `cost_series` property.
        self.costs = telemetry_mod.RingBuffer(self.cfg.cost_retention)

    @property
    def plan(self) -> ProvisioningPlan:
        return self.reconciler.plan

    @property
    def edits(self) -> List[PlanEdit]:
        return self.reconciler.edits

    @property
    def cost_series(self) -> List[tuple]:
        """Deprecated alias for ``list(self.costs)`` — the same
        (t_s, $/h) tuples the unbounded list used to hold, now capped
        at ``ControllerConfig.cost_retention`` rows."""
        warnings.warn(
            "Controller.cost_series is deprecated; read Controller.costs "
            "(a bounded telemetry.RingBuffer of the same tuples)",
            DeprecationWarning, stacklevel=2)
        return self.costs.list()

    def attach_canary(self, canary) -> None:
        """Simulator-installed health probe: ``canary(gpu, now_ms)``
        returns the device's CURRENT residual multiplier (``inf`` while
        down, 1.0 clean).  Consumed only at probation expiry — a real
        canary pass on an otherwise-empty device — so detection stays
        telemetry-driven while readmission becomes an active probe."""
        self._canary = canary

    def overload_stats(self) -> Dict[str, float]:
        """Admission-layer counters the simulator merges into
        `SimResult.stats`; empty until the first admission decision."""
        return self.reconciler.overload_stats()

    def __call__(self, now_s: float,
                 instances: List[ServedInstance]) -> None:
        if now_s == self._last_s and self.n_ticks > 0:
            # two calls at the same tick = the simulator is invoking us
            # once per device: estimators would see ~zero-width windows
            # and report garbage rates — fail loudly instead
            raise RuntimeError(
                "Controller needs the whole cluster per tick: pass "
                "adjust_scope=\"cluster\" to simulate_plan (the default "
                "\"device\" scope calls adjust_fn once per device)")
        if self.n_ticks == 0:
            # adopt simulator-armed (shadow=True) reservations into the
            # armed book, so every plan edit accounts for them — the
            # historical "Controller does not compose with shadow=True"
            # refusal is gone: the book makes reservations visible to
            # the placement sweeps in both engine paths
            for inst in instances:
                if inst.shadow_r > 0.0:
                    self.reconciler.armed.setdefault(
                        inst.spec.name, float(inst.shadow_r))
        window_ms = max((now_s - self._last_s) * 1000.0, 1e-9)
        tm = self.telemetry
        if tm is not None:
            # pre-edit placement snapshot + stream cursors, so every
            # decision this tick drains into an enriched ControlEvent
            t0 = _time.perf_counter()
            n_edits0 = len(self.reconciler.edits)
            n_adm0 = len(self.reconciler.admission_log)
            pre_map: Dict[str, List[tuple]] = {}
            for p in self.plan.placements:
                pre_map.setdefault(
                    replication.base_name(p.workload.name),
                    []).append((p.gpu, p.batch, p.r))
        backlog: Dict[str, float] = {}
        by_base: Dict[str, List[ServedInstance]] = {}
        for inst in instances:
            by_base.setdefault(replication.base_name(inst.spec.name),
                               []).append(inst)
        for base, insts_b in by_base.items():
            est = self.estimators.get(base)
            if est is None:       # instance outside the managed plan
                continue
            if len(insts_b) == 1:
                merged = insts_b[0].recent_arrivals
            else:
                # replica slices partition the pooled stream; their
                # sorted merge is the workload's arrival window
                merged = np.sort(np.concatenate(
                    [np.asarray(i.recent_arrivals) for i in insts_b]))
            est.observe(merged, window_ms)
            backlog[base] = float(sum(len(i.queue) for i in insts_b))
        # bases holding an ACTIVE shadow: the predictive tier's disarm
        # hold waits for these to deactivate before releasing capacity
        self.reconciler.shadow_active_bases = {
            base for base, insts_b in by_base.items()
            if any(i.shadow_active for i in insts_b)}
        changed = False
        rep = None
        if self.health is not None:
            rep = self.health.observe(now_s, instances,
                                      canary=self._canary)
        if tm is not None:
            # Sec. 5.5-style phase walls: probe = estimator + health
            # observation, solve = plan reconciliation, apply = mapping
            # the plan onto live instances
            t1 = _time.perf_counter()
            tm.add_wall("ctl_probe", (t1 - t0) * 1000.0)
        if rep is not None:
            if rep.readmit:
                for g in rep.readmit:
                    self.health.quarantined.pop(g, None)
                self.reconciler.readmit(now_s, rep.readmit)
            for g in rep.dead:
                self.health.quarantined[g] = ("failed", now_s)
            for g in rep.stragglers:
                self.health.quarantined[g] = ("straggler", now_s)
            if rep.dead or rep.stragglers:
                self.reconciler.quarantine(rep.dead + rep.stragglers)
                changed |= self.reconciler.evict(now_s)
        changed |= self.reconciler.reconcile(now_s, self.estimators,
                                             backlog, window_ms)
        solve_ms = 0.0
        if tm is not None:
            t2 = _time.perf_counter()
            solve_ms = (t2 - t1) * 1000.0
            tm.add_wall("ctl_solve", solve_ms)
        if changed:
            self._apply_plan(instances)
        if tm is not None:
            tm.add_wall("ctl_apply", (_time.perf_counter() - t2) * 1000.0)
            self._drain_events(now_s, rep, pre_map, n_edits0, n_adm0,
                               solve_ms)
            tm.gauge("probe_hits", self.reconciler.probes.hits)
            tm.gauge("probe_misses", self.reconciler.probes.misses)
        self._last_s = now_s
        self.n_ticks += 1
        self.costs.append((now_s, self.plan.cost_per_hour()))

    # decision kind -> the signal that drives it (docs/observability.md)
    _CAUSE = {"resize": "drift", "split": "drift", "merge": "drift",
              "infeasible": "drift", "migrate": "health",
              "readmit": "health", "preempt": "admission",
              "shed": "admission", "admit": "admission",
              "capped": "admission", "add": "arrival",
              "remove": "departure", "forecast": "forecast",
              "shadow_arm": "forecast", "shadow_disarm": "forecast"}

    def _drain_events(self, now_s: float, rep, pre_map, n_edits0: int,
                      n_adm0: int, solve_ms: float) -> None:
        """Turn this tick's decisions into typed `telemetry.ControlEvent`
        records: quarantine verdicts first (they precede reconciliation),
        then every new `PlanEdit` enriched with the driving estimator's
        state and the pre/post placement of the touched workload, then
        admission-log entries with no PlanEdit twin (brownout,
        shed-departed).  ``wall_ms`` on each event is the tick's solve
        wall — a host measurement, excluded from engine identity."""
        tm = self.telemetry
        cfg = self.cfg
        rec = self.reconciler
        if rep is not None:
            for kind_c, gpus in (("failed", rep.dead),
                                 ("straggler", rep.stragglers)):
                for g in gpus:
                    tm.record_event(telemetry_mod.ControlEvent(
                        t_s=now_s, kind="quarantine",
                        workload=f"device:{g}", cause=kind_c,
                        gpu_from=g, wall_ms=solve_ms))
        post_map: Dict[str, List[tuple]] = {}
        if len(rec.edits) > n_edits0:
            for p in self.plan.placements:
                post_map.setdefault(
                    replication.base_name(p.workload.name),
                    []).append((p.gpu, p.batch, p.r))
        for e in rec.edits[n_edits0:]:
            pre = pre_map.get(e.workload)
            post = post_map.get(e.workload)
            ev = telemetry_mod.ControlEvent(
                t_s=e.t_s, kind=e.action, workload=e.workload,
                cause=self._CAUSE.get(e.action, "drift"),
                rate_from=e.rate_from, rate_to=e.rate_to,
                burstiness=e.burstiness, replicas=e.replicas,
                pre=None if pre is None else tuple(pre),
                post=None if post is None else tuple(post),
                wall_ms=solve_ms)
            if pre is not None and post is not None \
                    and len(pre) == 1 and len(post) == 1:
                ev.gpu_from, ev.gpu_to = pre[0][0], post[0][0]
            est = self.estimators.get(e.workload)
            if est is not None:
                ev.rate_rps = est.rate_rps
                ev.trend_rps = est.trend_rps
                ev.cv2 = est.cv2
                ev.projected_rps = est.projected_rps
                ev.rate_sigma = est.rate_sigma()
                # the effective hysteresis bands at decision time: the
                # configured band widened to noise_sigmas sigmas of the
                # smoothed counting noise (see Reconciler._drift_kind)
                noise = (cfg.noise_sigmas * ev.rate_sigma / e.rate_from
                         if e.rate_from > 0.0 else 0.0)
                ev.band_up = max(cfg.band_up, noise)
                ev.band_down = max(cfg.band_down, noise)
            tm.record_event(ev)
        for (t_e, event, detail) in rec.admission_log[n_adm0:]:
            if event in ("brownout", "shed-departed"):
                tm.record_event(telemetry_mod.ControlEvent(
                    t_s=t_e, kind=event, workload=str(detail),
                    cause="admission", wall_ms=solve_ms))

    def _apply_plan(self, instances: List[ServedInstance]) -> None:
        """Map the reconciled plan onto the live instances: r / batch /
        gpu deltas the simulator turns into table rebuilds/migrations,
        plus the replica lifecycle —

          * a plan replica with no live instance first ADOPTS an
            unmatched instance of the same base workload (the first
            split renames the live ``w`` to ``w#0``; a merge-to-one
            renames ``w#0`` back to ``w``), else a fresh
            `ServedInstance` is APPENDED (the simulator wires its RNG
            streams and routes it a slice of the pooled arrivals);
          * a live replica the plan no longer names is PARKED at the
            allocation floor with a ZERO rate share, so the re-split
            routes it no further arrivals (it still drains its queue);
          * a departed workload's instances are parked as before
            (their arrivals have stopped; r_unit keeps physics valid).
        """
        by_name = {p.workload.name: p for p in self.plan.placements}
        plan_bases = {replication.base_name(n) for n in by_name}
        live_names = {inst.spec.name for inst in instances}
        armed = self.reconciler.armed
        free: Dict[str, List[ServedInstance]] = {}
        for inst in instances:
            name = inst.spec.name
            if name in by_name:
                p = by_name[name]
                inst.spec = p.workload        # refresh the rate share
                inst.r = p.r
                inst.batch = max(1, p.batch)
                inst.gpu = p.gpu
                inst.shed = False             # in the plan = admitted
                self._apply_shadow(inst, armed.get(name, 0.0))
                continue
            base = replication.base_name(name)
            if base in plan_bases:
                free.setdefault(base, []).append(inst)   # rename/park pool
            elif base in self.reconciler.shed:
                # admission-shed: park the allocation and mark the
                # instance so the simulator drops (and counts) its
                # requests.  The spec's rate SHARE stays — arrivals keep
                # routing here, so the estimator keeps seeing the true
                # demand and readmission resumes from live priors.
                inst.r = self.hw.r_unit
                inst.batch = 1
                inst.shed = True
                self._apply_shadow(inst, 0.0)
            elif base in self.reconciler.departed:
                inst.r = self.hw.r_unit
                inst.batch = 1
                self._apply_shadow(inst, 0.0)
        for p in self.plan.placements:        # plan order = replica order
            name = p.workload.name
            if name in live_names:
                continue
            base = replication.base_name(name)
            pool = free.get(base)
            if pool:
                inst = pool.pop(0)            # adopt: rename in place
                inst.spec = p.workload
                inst.r = p.r
                inst.batch = max(1, p.batch)
                inst.gpu = p.gpu
                inst.shed = False
                self._apply_shadow(inst, armed.get(name, 0.0))
            else:                             # scale-out: fresh replica
                sibling = next(i for i in instances
                               if replication.base_name(i.spec.name)
                               == base)
                instances.append(ServedInstance(
                    spec=p.workload, desc=sibling.desc, r=p.r,
                    batch=max(1, p.batch), gpu=p.gpu,
                    slo0=sibling.slo0,
                    shadow_r=armed.get(name, 0.0)))
        for pool in free.values():            # merged-away replicas
            for inst in pool:
                inst.r = self.hw.r_unit
                inst.batch = 1
                inst.shed = False             # zero share: no arrivals
                inst.spec = dataclasses.replace(inst.spec, rate_rps=0.0)
                self._apply_shadow(inst, 0.0)

    @staticmethod
    def _apply_shadow(inst: ServedInstance, sr: float) -> None:
        """Map the armed book onto one live instance.  Only ever writes
        on a CHANGE, and a released reservation deactivates too — with
        nothing armed this is a no-op on every instance, which is what
        keeps forecast-off runs byte-identical to the reactive build."""
        if sr != inst.shadow_r:
            inst.shadow_r = sr
            if sr <= 0.0:
                inst.shadow_active = False

    @property
    def hw(self) -> HardwareSpec:
        return self.reconciler.hw
