"""Ground-truth co-location physics for the cluster simulator.

Deliberately *richer* than the iGniter analytical model (see DESIGN.md):

  * dispatch queueing is mildly super-linear in the co-location count and
    jittered per pass;
  * bandwidth contention saturates (power-law inflation of the memory
    portion once aggregate demand crosses a knee) instead of being linear
    in the summed neighbor utilization;
  * the frequency/power relation has a soft exponent and a floor, plus
    lognormal measurement noise.

The iGniter model (Eqs. 1-11) is fit *against* this physics from 11 solo
profiles — prediction error is therefore a real quantity, as on hardware.
Base per-model quantities (FLOPs, bytes, kernel counts, IO sizes) come
from the real architecture configs via `repro.profiling.metrics`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import HardwareSpec
from repro.profiling.metrics import ServedModelDesc

BW_KNEE = 0.58        # aggregate bandwidth demand where contention kicks in
BW_EXP = 1.15         # saturation exponent
SCHED_COLOC_SLOPE = 0.65
SCHED_COLOC_EXP = 1.06
FREQ_EXP = 1.05
NOISE_SIGMA = 0.015
ACTIVE_W_SCALE = 1.35  # peak active draw = scale * power_cap (forces
                       # throttling under heavy co-location, cf. Fig. 7)


@dataclass(frozen=True)
class TrueState:
    """Ground-truth instantaneous state of one workload on a device."""
    t_load: float
    t_sched: float
    t_act: float          # after contention, before frequency scaling
    t_feedback: float
    t_gpu: float
    t_inf: float
    power: float          # this workload's draw [W]
    cache_util: float     # solo bandwidth demand fraction
    freq: float           # device frequency [MHz]
    device_power: float


def solo_terms(desc: ServedModelDesc, b: int, r: float, hw: HardwareSpec
               ) -> Tuple[float, float, float, float, float, float, float]:
    """(t_load, k_disp, t_compute, t_mem, power, cache_util, t_feedback)
    solo, no noise.

    Fractional allocation r is an MXU *time share*: both compute and HBM
    streams of this workload only progress during its share.
    """
    t_load = desc.d_load_mb * b / hw.pcie_bw                       # ms
    t_feedback = desc.d_feedback_mb * b / hw.pcie_bw
    flops = desc.flops_per_item * b
    # small super-linear term (attention/batch effects) to keep Eq.11's
    # quadratic honest-but-approximate
    flops *= (1.0 + 0.004 * b)
    bytes_ = desc.weight_bytes + desc.act_bytes_per_item * b
    t_compute = flops / (hw.peak_flops * hw.mxu_efficiency) * 1e3  # ms
    t_mem = bytes_ / hw.hbm_bw * 1e3
    r_eff = max(r, 1e-3)
    t_c = t_compute / r_eff
    t_m = t_mem / r_eff
    t_act = max(t_c, t_m) + 0.35 * min(t_c, t_m) + 0.05            # overlap-ish
    # bandwidth demand while active: bytes over active time
    cache_util = min(1.0, (bytes_ / (t_act * 1e-3)) / hw.hbm_bw)
    # power: active draw proportional to share * utilization
    util = t_c / t_act
    p = hw.power_cap * ACTIVE_W_SCALE * r_eff * (0.35 + 0.65 * util)
    per_kernel = 0.002 + 5e-6 * desc.n_kernels                     # ms/kernel solo
    return t_load, per_kernel, t_c, t_m, p, cache_util, t_feedback


def _pow_stable(x: np.ndarray, e: float) -> np.ndarray:
    """``x ** e`` with libm scalar rounding regardless of array size.

    numpy dispatches large float64 arrays to a SIMD pow whose last-bit
    rounding can differ from the scalar path; the simulator's bitwise
    table-vs-oracle parity requires ONE rounding behavior for every
    shape `device_state_batch` is called with.  The arrays involved are
    tiny (one value per device row), so the Python-level loop is noise.
    """
    x = np.asarray(x, dtype=np.float64)
    flat = np.atleast_1d(x).ravel()
    out = np.array([v ** e for v in flat.tolist()], dtype=np.float64)
    return out.reshape(x.shape)


@dataclass(frozen=True)
class BatchTrueState:
    """Struct-of-arrays `TrueState` over any leading shape.

    Per-workload arrays have shape ``(..., n)`` (n = co-located entries);
    per-device arrays (`freq`, `device_power`) have the leading shape
    ``(...)``.  Mirrors `repro.core.perf_model_vec` style: the serving
    simulator evaluates a whole grid of candidate effective batch sizes
    in one call instead of one `device_state` call per serve event.
    """
    t_load: np.ndarray
    t_sched: np.ndarray
    t_act: np.ndarray          # after contention, before noise
    t_feedback: np.ndarray
    t_gpu: np.ndarray
    t_inf: np.ndarray
    power: np.ndarray
    cache_util: np.ndarray
    freq: np.ndarray           # (...)
    device_power: np.ndarray   # (...)


def device_state_batch(descs: Sequence[ServedModelDesc],
                       b: np.ndarray, r: np.ndarray,
                       hw: HardwareSpec) -> BatchTrueState:
    """Ground truth for a full co-location state, batched.

    ``descs`` lists the n co-located workloads; ``b`` and ``r`` are
    arrays broadcastable to ``(..., n)`` — e.g. a ``(K, n)`` grid whose
    rows vary one workload's batch while the peers stay fixed.  Noise is
    NOT applied here: callers sample multipliers on `t_act`/`t_sched`
    (see `simulator._noisy_t_inf`).  `device_state` is a thin wrapper
    over this function, so scalar and batched paths agree bitwise.
    """
    n = len(descs)
    # stacked per-desc constants, shape (n,) broadcasting against (..., n)
    d_load = np.array([d.d_load_mb for d in descs])
    d_fb = np.array([d.d_feedback_mb for d in descs])
    flops_i = np.array([d.flops_per_item for d in descs])
    w_bytes = np.array([d.weight_bytes for d in descs])
    a_bytes = np.array([d.act_bytes_per_item for d in descs])
    n_kern = np.array([float(d.n_kernels) for d in descs])
    return device_state_arrays(d_load, d_fb, flops_i, w_bytes, a_bytes,
                               n_kern, b, r, n, hw)


def device_state_arrays(d_load: np.ndarray, d_fb: np.ndarray,
                        flops_i: np.ndarray, w_bytes: np.ndarray,
                        a_bytes: np.ndarray, n_kern: np.ndarray,
                        b: np.ndarray, r: np.ndarray,
                        n_co: int, hw: HardwareSpec) -> BatchTrueState:
    """`device_state_batch` on pre-stacked per-entry constants.

    The per-desc constants may carry any shape broadcastable to
    ``(..., n_co)`` — in particular ``(R, n_co)`` rows drawn from
    DIFFERENT devices, which is what lets the simulator build every
    latency table of one co-location width in one call
    (`simulator._build_tables_bulk`).  ``n_co`` is the Python-int
    co-location count: every reduction here runs over a last axis of
    exactly that width, so a multi-device bulk call is bitwise-identical
    to the per-device calls it replaces (same summation grouping, and
    `_pow_stable` is shape-independent by construction).
    """
    n = int(n_co)
    b = np.asarray(b, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    b, r = np.broadcast_arrays(b, r)

    # over-subscription: if Sum r > 1 the scheduler time-slices everyone
    # down proportionally AND pays context-thrash overhead (the long-tail
    # SM contention of the paper's Sec. 2.3 GSLICE example)
    total_r = r.sum(axis=-1)
    shrink = np.maximum(1.0, total_r)
    thrash = 1.0 + 0.6 * np.maximum(0.0, total_r - 1.0)
    r = r / shrink[..., None]

    # solo terms (`solo_terms` on arrays)
    t_load = d_load * b / hw.pcie_bw                               # ms
    t_feedback = d_fb * b / hw.pcie_bw
    flops = flops_i * b
    flops = flops * (1.0 + 0.004 * b)
    bytes_ = w_bytes + a_bytes * b
    t_compute = flops / (hw.peak_flops * hw.mxu_efficiency) * 1e3  # ms
    t_mem = bytes_ / hw.hbm_bw * 1e3
    r_eff = np.maximum(r, 1e-3)
    t_c = t_compute / r_eff
    t_m = t_mem / r_eff
    t_act_solo = np.maximum(t_c, t_m) + 0.35 * np.minimum(t_c, t_m) + 0.05
    cache_util = np.minimum(1.0, (bytes_ / (t_act_solo * 1e-3)) / hw.hbm_bw)
    util = t_c / t_act_solo
    power = hw.power_cap * ACTIVE_W_SCALE * r_eff * (0.35 + 0.65 * util)
    per_kernel = 0.002 + 5e-6 * n_kern                             # ms/kernel

    total_bw = cache_util.sum(axis=-1)
    device_power = hw.idle_power + power.sum(axis=-1)
    excess = np.maximum(device_power - hw.power_cap, 0.0)
    freq = np.where(device_power <= hw.power_cap, hw.max_freq,
                    np.maximum(hw.max_freq
                               + hw.alpha_f * _pow_stable(excess, FREQ_EXP),
                               0.6 * hw.max_freq))
    slow = freq / hw.max_freq

    # dispatch: round-robin growth with co-location
    per_kernel = per_kernel * (1.0 + SCHED_COLOC_SLOPE *
                               max(0.0, (n - 1)) ** SCHED_COLOC_EXP)
    t_sched = per_kernel * n_kern * np.ones_like(b)
    # bandwidth contention: inflate the memory-bound portion
    infl = np.where(total_bw > BW_KNEE,
                    _pow_stable(total_bw / BW_KNEE, BW_EXP), 1.0)
    t_m_infl = t_m * infl[..., None]
    t_act = (np.maximum(t_c, t_m_infl) + 0.35 * np.minimum(t_c, t_m_infl)
             + 0.05) * thrash[..., None]
    t_gpu = (t_sched + t_act) / slow[..., None]
    t_inf = t_load + t_gpu + t_feedback
    return BatchTrueState(
        t_load=t_load * np.ones_like(b), t_sched=t_sched, t_act=t_act,
        t_feedback=t_feedback * np.ones_like(b), t_gpu=t_gpu, t_inf=t_inf,
        power=power, cache_util=cache_util, freq=freq,
        device_power=device_power)


def device_state(entries: Sequence[Tuple[ServedModelDesc, int, float]],
                 hw: HardwareSpec,
                 rng: Optional[np.random.Generator] = None
                 ) -> List[TrueState]:
    """Ground truth for a full co-location state.

    entries: (desc, batch, r) per workload on the device.  Thin wrapper
    over `device_state_batch` (one row); with ``rng``, lognormal noise is
    applied per entry in declaration order, preserving the historical
    draw sequence.
    """
    descs = [d for (d, _, _) in entries]
    b = np.array([float(bb) for (_, bb, _) in entries])
    r = np.array([float(rr) for (_, _, rr) in entries])
    st = device_state_batch(descs, b, r, hw)
    freq = float(st.freq)
    slow = freq / hw.max_freq
    device_power = float(st.device_power)
    out = []
    for i in range(len(entries)):
        t_act = float(st.t_act[i])
        t_sched = float(st.t_sched[i])
        if rng is not None:
            t_act *= float(rng.lognormal(0.0, NOISE_SIGMA))
            t_sched *= float(rng.lognormal(0.0, 2 * NOISE_SIGMA))
        t_load = float(st.t_load[i])
        t_fb = float(st.t_feedback[i])
        t_gpu = (t_sched + t_act) / slow
        out.append(TrueState(
            t_load=t_load, t_sched=t_sched, t_act=t_act, t_feedback=t_fb,
            t_gpu=t_gpu, t_inf=t_load + t_gpu + t_fb,
            power=float(st.power[i]), cache_util=float(st.cache_util[i]),
            freq=freq, device_power=device_power))
    return out
