"""Ground-truth co-location physics for the cluster simulator.

Deliberately *richer* than the iGniter analytical model (see DESIGN.md):

  * dispatch queueing is mildly super-linear in the co-location count and
    jittered per pass;
  * bandwidth contention saturates (power-law inflation of the memory
    portion once aggregate demand crosses a knee) instead of being linear
    in the summed neighbor utilization;
  * the frequency/power relation has a soft exponent and a floor, plus
    lognormal measurement noise.

The iGniter model (Eqs. 1-11) is fit *against* this physics from 11 solo
profiles — prediction error is therefore a real quantity, as on hardware.
Base per-model quantities (FLOPs, bytes, kernel counts, IO sizes) come
from the real architecture configs via `repro.profiling.metrics`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import HardwareSpec
from repro.profiling.metrics import ServedModelDesc

BW_KNEE = 0.58        # aggregate bandwidth demand where contention kicks in
BW_EXP = 1.15         # saturation exponent
SCHED_COLOC_SLOPE = 0.65
SCHED_COLOC_EXP = 1.06
FREQ_EXP = 1.05
NOISE_SIGMA = 0.015
ACTIVE_W_SCALE = 1.35  # peak active draw = scale * power_cap (forces
                       # throttling under heavy co-location, cf. Fig. 7)


@dataclass(frozen=True)
class TrueState:
    """Ground-truth instantaneous state of one workload on a device."""
    t_load: float
    t_sched: float
    t_act: float          # after contention, before frequency scaling
    t_feedback: float
    t_gpu: float
    t_inf: float
    power: float          # this workload's draw [W]
    cache_util: float     # solo bandwidth demand fraction
    freq: float           # device frequency [MHz]
    device_power: float


def solo_terms(desc: ServedModelDesc, b: int, r: float, hw: HardwareSpec
               ) -> Tuple[float, float, float, float, float, float]:
    """(t_load, k_disp, t_compute, t_mem, power, cache_util) solo, no noise.

    Fractional allocation r is an MXU *time share*: both compute and HBM
    streams of this workload only progress during its share.
    """
    t_load = desc.d_load_mb * b / hw.pcie_bw                       # ms
    t_feedback = desc.d_feedback_mb * b / hw.pcie_bw
    flops = desc.flops_per_item * b
    # small super-linear term (attention/batch effects) to keep Eq.11's
    # quadratic honest-but-approximate
    flops *= (1.0 + 0.004 * b)
    bytes_ = desc.weight_bytes + desc.act_bytes_per_item * b
    t_compute = flops / (hw.peak_flops * hw.mxu_efficiency) * 1e3  # ms
    t_mem = bytes_ / hw.hbm_bw * 1e3
    r_eff = max(r, 1e-3)
    t_c = t_compute / r_eff
    t_m = t_mem / r_eff
    t_act = max(t_c, t_m) + 0.35 * min(t_c, t_m) + 0.05            # overlap-ish
    # bandwidth demand while active: bytes over active time
    cache_util = min(1.0, (bytes_ / (t_act * 1e-3)) / hw.hbm_bw)
    # power: active draw proportional to share * utilization
    util = t_c / t_act
    p = hw.power_cap * ACTIVE_W_SCALE * r_eff * (0.35 + 0.65 * util)
    per_kernel = 0.002 + 5e-6 * desc.n_kernels                     # ms/kernel solo
    return t_load, per_kernel, t_c, t_m, p, cache_util, t_feedback


def device_state(entries: Sequence[Tuple[ServedModelDesc, int, float]],
                 hw: HardwareSpec,
                 rng: Optional[np.random.Generator] = None
                 ) -> List[TrueState]:
    """Ground truth for a full co-location state.

    entries: (desc, batch, r) per workload on the device.
    """
    n = len(entries)
    # over-subscription: if Sum r > 1 the scheduler time-slices everyone
    # down proportionally AND pays context-thrash overhead (the long-tail
    # SM contention of the paper's Sec. 2.3 GSLICE example)
    total_r = sum(r for (_, _, r) in entries)
    shrink = max(1.0, total_r)
    thrash = 1.0 + 0.6 * max(0.0, total_r - 1.0)
    entries = [(d, b, r / shrink) for (d, b, r) in entries]
    solos = [solo_terms(d, b, r, hw) for (d, b, r) in entries]
    total_bw = sum(s[5] for s in solos)

    # power/frequency
    device_power = hw.idle_power + sum(s[4] for s in solos)
    if device_power <= hw.power_cap:
        freq = hw.max_freq
    else:
        excess = device_power - hw.power_cap
        freq = max(hw.max_freq + hw.alpha_f * (excess ** FREQ_EXP),
                   0.6 * hw.max_freq)
    slow = freq / hw.max_freq

    out = []
    for (desc, b, r), (t_load, per_k, t_c, t_m, p, c, t_fb) in zip(entries, solos):
        # dispatch: round-robin growth with co-location
        per_kernel = per_k * (1.0 + SCHED_COLOC_SLOPE *
                              max(0.0, (n - 1)) ** SCHED_COLOC_EXP)
        t_sched = per_kernel * desc.n_kernels
        # bandwidth contention: inflate the memory-bound portion
        infl = 1.0
        if total_bw > BW_KNEE:
            infl = (total_bw / BW_KNEE) ** BW_EXP
        t_act = (max(t_c, t_m * infl) + 0.35 * min(t_c, t_m * infl) + 0.05) \
            * thrash
        if rng is not None:
            t_act *= float(rng.lognormal(0.0, NOISE_SIGMA))
            t_sched *= float(rng.lognormal(0.0, 2 * NOISE_SIGMA))
        t_gpu = (t_sched + t_act) / slow
        out.append(TrueState(
            t_load=t_load, t_sched=t_sched, t_act=t_act, t_feedback=t_fb,
            t_gpu=t_gpu, t_inf=t_load + t_gpu + t_fb,
            power=p, cache_util=c, freq=freq, device_power=device_power))
    return out
