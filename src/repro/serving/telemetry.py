"""Opt-in observability: control-plane event tracing, metric timelines,
drift series, and Sec. 5.5-style overhead accounting.

One `Telemetry` object is threaded (``telemetry=`` keyword, default
``None``) through the simulator (both engines), the controller stack
(`Controller` / `Reconciler` / `HealthMonitor`), and the provisioner
edit ops.  It records four streams into bounded ring buffers:

* **events** — every control-plane decision (resize / migrate / split /
  merge / quarantine / evict-migrate / readmit / preempt / brownout /
  shed / admit / capped / reconfig) as a typed `ControlEvent` carrying
  the cause, the estimator inputs that drove it (rate / trend / CV^2,
  hysteresis bands), the pre/post placement of the touched workload,
  and the tick's controller wall time;
* **workloads / devices** — per-monitor-tick metric timelines:
  per-workload p99 / avg / rps / queue-wait, and per-device utilization,
  effective batch, and the interference terms of the true physics
  (Sigma-power, Sigma-cache, Delta_sch, DVFS frequency — the
  `VecCluster` analogues, evaluated noise-free);
* **drift** — the measured-vs-fitted residual series the
  `HealthMonitor` computes per device (raw median ratio, fleet-
  normalized score, fleet median) — the signal quarantine decisions
  are made from;
* **counters / walls / gauges** — overhead profiling: per-phase
  controller wall (probe / solve / apply), `ProbeCache` hits/misses,
  provisioner-op and jit-vs-numpy dispatch counts.

Hard contracts (pinned by `tests/test_telemetry.py`):

* ``telemetry=None`` is byte-identical to the pre-telemetry build —
  every hook is behind ``if telemetry is not None``;
* for a fixed seed, the scalar and vec engines emit IDENTICAL event
  and timeline content (wall-time fields excepted — they measure the
  host, not the simulation).  Timeline rows are therefore computed
  with pure-Python arithmetic from values both engines share, and the
  device interference snapshot is evaluated through the same bucketed
  `physics.device_state_arrays` path for both;
* counter names prefixed ``dispatch_`` / ``prov_`` are engine- or
  path-specific by design and excluded from the identity contract;
* `benchmarks/dynamic_sweep.py --telemetry --check` bounds telemetry-on
  wall overhead at m=1000 to <= 10% over telemetry-off.

Exporters: `Telemetry.to_jsonl` (one typed record per line + a summary
trailer), `Telemetry.prometheus_text` (text-format snapshot), and
`benchmarks/telemetry_report.py` (self-contained HTML / terminal
timeline report rendered FROM the JSONL, stdlib-only).
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import HardwareSpec
from repro.serving import physics

__all__ = ["RingBuffer", "ControlEvent", "Telemetry", "DEFAULT_RETENTION"]

DEFAULT_RETENTION = 4096     # rows kept per ring (events / timelines)


class RingBuffer:
    """Bounded append-only buffer: keeps the newest ``capacity`` rows,
    counts everything ever appended (``total``) so overflow is visible
    (``dropped``) instead of silent."""

    __slots__ = ("_dq", "total")

    def __init__(self, capacity: int = DEFAULT_RETENTION):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._dq: deque = deque(maxlen=int(capacity))
        self.total = 0

    @property
    def capacity(self) -> int:
        return self._dq.maxlen

    @property
    def dropped(self) -> int:
        return self.total - len(self._dq)

    def append(self, row) -> None:
        self._dq.append(row)
        self.total += 1

    def __len__(self) -> int:
        return len(self._dq)

    def __iter__(self) -> Iterator:
        return iter(self._dq)

    def __getitem__(self, i):
        return self._dq[i]

    def list(self) -> list:
        return list(self._dq)


@dataclass
class ControlEvent:
    """One typed control-plane decision.

    ``kind`` is the decision type: the `PlanEdit` actions (resize /
    remove / add / split / merge / infeasible / migrate / readmit /
    preempt / shed / admit / capped / forecast / shadow_arm /
    shadow_disarm — the last three are the predictive tier's pre-size
    and Sec. 4.2 reservation lifecycle) plus ``quarantine`` (health
    layer), ``brownout`` (admission layer), and ``reconfig``
    (simulator-side: one per instance whose placement tuple actually
    changed at an adjust tick).  ``cause`` groups kinds by driving
    signal: "drift" (estimator band breach), "health", "admission",
    "arrival", "departure", "adjust", "scale_out", "forecast".

    Estimator fields are 0.0 when no estimator drove the decision
    (health / simulator events).  ``pre`` / ``post`` are tuples of
    ``(gpu, batch, r)`` per replica — ``None`` when not applicable.
    ``wall_ms`` is host wall time (the tick's solve wall for controller
    events); it is EXCLUDED from the engine-identity contract.
    """
    t_s: float
    kind: str
    workload: str
    cause: str = ""
    rate_from: float = 0.0
    rate_to: float = 0.0
    burstiness: float = 0.0
    replicas: int = 1
    # estimator inputs at decision time
    rate_rps: float = 0.0
    trend_rps: float = 0.0
    cv2: float = 0.0
    projected_rps: float = 0.0
    rate_sigma: float = 0.0
    band_up: float = 0.0
    band_down: float = 0.0
    # placement delta
    pre: Optional[Tuple[Tuple[int, int, float], ...]] = None
    post: Optional[Tuple[Tuple[int, int, float], ...]] = None
    gpu_from: int = -1
    gpu_to: int = -1
    wall_ms: float = 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["pre"] = None if self.pre is None else [list(p) for p in self.pre]
        d["post"] = (None if self.post is None
                     else [list(p) for p in self.post])
        return d


def _p99(window: Sequence[float]) -> float:
    """np.percentile(window, 99) (the default 'linear' interpolation)
    in pure Python — per-instance-per-tick numpy calls dominated the
    telemetry overhead budget at m=1000."""
    n = len(window)
    if n == 0:
        return 0.0
    s = sorted(window)
    if n == 1:
        return float(s[0])
    pos = 0.99 * (n - 1)
    lo = int(pos)
    frac = pos - lo
    if lo + 1 >= n:
        return float(s[-1])
    return float(s[lo] + frac * (s[lo + 1] - s[lo]))


class Telemetry:
    """The recorder.  Construct one per run and pass it everywhere the
    ``telemetry=`` keyword exists; ``retention`` bounds every ring.

    The hooks are written so that ALL cost is skipped when the object
    is absent — the callers guard with ``if telemetry is not None`` and
    never build intermediate state otherwise.
    """

    def __init__(self, retention: int = DEFAULT_RETENTION):
        self.retention = int(retention)
        self.events = RingBuffer(self.retention)       # ControlEvent
        self.workloads = RingBuffer(self.retention)    # dict rows
        self.devices = RingBuffer(self.retention)      # dict rows
        self.drift = RingBuffer(self.retention)        # dict rows
        self.counters: Dict[str, int] = {}
        self.walls: Dict[str, float] = {}              # name -> total ms
        self.gauges: Dict[str, float] = {}

    # -- scalars ------------------------------------------------------------

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def add_wall(self, name: str, ms: float) -> None:
        self.walls[name] = self.walls.get(name, 0.0) + ms

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    # -- events -------------------------------------------------------------

    def record_event(self, ev: ControlEvent) -> None:
        self.events.append(ev)
        self.count("events_" + ev.kind)
        if ev.kind == "reconfig":
            # overflow-immune: the n_reconfigs reconciliation gate must
            # survive the ring dropping old rows
            self.count("reconfig_events")

    # -- drift series (HealthMonitor) ---------------------------------------

    def record_drift(self, t_s: float, gpu: int, raw: float,
                     score: float, fleet: float) -> None:
        """One device's measured/fitted residual at a control tick:
        ``raw`` is the median measured/predicted service-time ratio,
        ``score`` the leave-one-out fleet-normalized residual (0.0 when
        the device could not be scored), ``fleet`` the fleet median of
        scores — exactly the triple quarantine decisions compare."""
        self.drift.append({"t_s": t_s, "gpu": int(gpu), "raw": float(raw),
                           "score": float(score), "fleet": float(fleet)})

    # -- metric timelines (simulator monitor ticks) -------------------------

    def sample_tick(self, t_ms: float, instances, by_gpu, hw: HardwareSpec,
                    rows: List[Tuple[int, Sequence[float], Sequence[float],
                                     Sequence[float], int]]) -> None:
        """Record one monitor tick.  ``rows`` holds, per instance index,
        ``(i, window_latencies, window_waits, window_done_stamps,
        queue_len)`` — values BOTH engines derive identically from the
        shared completion streams, so the recorded timelines are
        engine-identical by construction.  All per-row arithmetic is
        pure Python (see `_p99`); the device interference snapshot is
        one bucketed `physics.device_state_arrays` call per co-location
        width, mirroring the vec engine's `_build_tables_bulk` grouping.
        """
        t_s = t_ms / 1000.0
        per_inst: Dict[int, Tuple[int, int, int]] = {}
        for (i, window, waits, stamps, qlen) in rows:
            inst = instances[i]
            k = len(window)
            passes = 0
            prev = None
            for d in stamps:
                if d != prev:
                    passes += 1
                    prev = d
            per_inst[i] = (k, passes, qlen)
            self.workloads.append({
                "t_s": t_s, "workload": inst.spec.name,
                "p99_ms": _p99(window),
                "avg_ms": (sum(window) / k) if k else 0.0,
                "rps": float(k),
                "wait_avg_ms": (sum(waits) / k) if k else 0.0,
                "queue": int(qlen),
                "r": inst.r_eff, "batch": inst.batch,
                "shed": bool(inst.shed),
            })
        self._sample_devices(t_s, instances, by_gpu, hw, per_inst)

    def _sample_devices(self, t_s: float, instances, by_gpu,
                        hw: HardwareSpec, per_inst) -> None:
        gpus = sorted(by_gpu)
        buckets: Dict[int, List[int]] = {}
        for g in gpus:
            buckets.setdefault(len(by_gpu[g]), []).append(g)
        for n, gs in sorted(buckets.items()):
            R = len(gs)
            b = np.empty((R, n))
            r = np.empty((R, n))
            consts = [np.empty((R, n)) for _ in range(6)]
            d_load, d_fb, flops_i, w_bytes, a_bytes, n_kern = consts
            for row, g in enumerate(gs):
                for j, i in enumerate(by_gpu[g]):
                    inst = instances[i]
                    b[row, j] = max(1, inst.batch)
                    r[row, j] = inst.r_eff
                    dsc = inst.desc
                    d_load[row, j] = dsc.d_load_mb
                    d_fb[row, j] = dsc.d_feedback_mb
                    flops_i[row, j] = dsc.flops_per_item
                    w_bytes[row, j] = dsc.weight_bytes
                    a_bytes[row, j] = dsc.act_bytes_per_item
                    n_kern[row, j] = float(dsc.n_kernels)
            st = physics.device_state_arrays(
                d_load, d_fb, flops_i, w_bytes, a_bytes, n_kern, b, r,
                n, hw)
            power_sum = st.power.sum(axis=-1)
            cache_sum = st.cache_util.sum(axis=-1)
            delta_sch = (0.0 if n <= 1
                         else hw.alpha_sch * n + hw.beta_sch)   # Eq. 6
            for row, g in enumerate(gs):
                comp = passes = qsum = 0
                util = 0.0
                for i in by_gpu[g]:
                    util += instances[i].r_eff
                    k, p, q = per_inst.get(i, (0, 0, 0))
                    comp += k
                    passes += p
                    qsum += q
                self.devices.append({
                    "t_s": t_s, "gpu": int(g), "n_colocated": n,
                    "util": util, "queue": qsum,
                    "completions": comp,
                    "eff_batch": (comp / passes) if passes else 0.0,
                    "power_sum": float(power_sum[row]),
                    "cache_sum": float(cache_sum[row]),
                    "delta_sch": float(delta_sch),
                    "freq": float(st.freq[row]),
                    "device_power": float(st.device_power[row]),
                })

    # -- exporters ----------------------------------------------------------

    def summary(self) -> Dict:
        return {
            "counters": dict(sorted(self.counters.items())),
            "walls_ms": {k: round(v, 3)
                         for k, v in sorted(self.walls.items())},
            "gauges": dict(sorted(self.gauges.items())),
            "rings": {name: {"rows": len(ring), "total": ring.total,
                             "dropped": ring.dropped}
                      for name, ring in (("events", self.events),
                                         ("workloads", self.workloads),
                                         ("devices", self.devices),
                                         ("drift", self.drift))},
        }

    def to_jsonl(self, path: str) -> None:
        """One typed record per line: ``{"type": "event" | "workload" |
        "device" | "drift" | "summary", ...}``.  The summary trailer is
        last, so `benchmarks/telemetry_report.py` can stream-parse."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps({"type": "event", **ev.to_dict()}))
                f.write("\n")
            for name, ring in (("workload", self.workloads),
                               ("device", self.devices),
                               ("drift", self.drift)):
                for row in ring:
                    f.write(json.dumps({"type": name, **row}))
                    f.write("\n")
            f.write(json.dumps({"type": "summary", **self.summary()}))
            f.write("\n")

    def prometheus_text(self) -> str:
        """Text-format metrics snapshot (counters, wall totals, gauges,
        ring fill) — the pull-scrape view of the same state."""
        lines = []

        def emit(name, mtype, items):
            lines.append(f"# TYPE {name} {mtype}")
            lines.extend(items)

        emit("repro_telemetry_count", "counter",
             [f'repro_telemetry_count{{name="{k}"}} {v}'
              for k, v in sorted(self.counters.items())])
        emit("repro_telemetry_wall_ms", "counter",
             [f'repro_telemetry_wall_ms{{phase="{k}"}} {v:.3f}'
              for k, v in sorted(self.walls.items())])
        emit("repro_telemetry_gauge", "gauge",
             [f'repro_telemetry_gauge{{name="{k}"}} {v}'
              for k, v in sorted(self.gauges.items())])
        emit("repro_telemetry_ring_rows", "gauge",
             [f'repro_telemetry_ring_rows{{ring="{name}"}} {len(ring)}'
              for name, ring in (("events", self.events),
                                 ("workloads", self.workloads),
                                 ("devices", self.devices),
                                 ("drift", self.drift))])
        return "\n".join(lines) + "\n"
