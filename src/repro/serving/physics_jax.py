"""Jitted twin of `physics.device_state_arrays` for bulk table builds.

`simulator._build_tables_bulk` batches every latency-table row of one
co-location width into a single ``(R, n)`` evaluation; with
``backend="jax"`` that evaluation runs here under ``jax.jit`` instead of
numpy.  Only the five quantities a `_LatTable` stores are returned
(t_load, t_sched, t_act, t_feedback, freq).

Numerical contract — same as `repro.core.perf_model_jax`: float64
(x64 enabled at import), agreement with the numpy path to <= 1e-6
relative (XLA fuses/reorders float ops, and ``x ** e`` is XLA's pow,
not the libm loop of `physics._pow_stable`).  The numpy backend stays
the pinned bitwise oracle; see docs/reproduction-notes.md deviation 5.

Compilation is keyed on (hw, n_co, R): `_build_tables_bulk` pads each
chunk's row count R up to a power of two so a long run settles into a
handful of compiled shapes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax

jax.config.update("jax_enable_x64", True)   # before any jnp array work

import jax.numpy as jnp  # noqa: E402

from repro.core.types import HardwareSpec  # noqa: E402
from repro.serving import physics  # noqa: E402


@functools.partial(jax.jit, static_argnames=("hw", "n_co"))
def _tables_jit(hw: HardwareSpec, n_co: int,
                d_load: jnp.ndarray, d_fb: jnp.ndarray,
                flops_i: jnp.ndarray, w_bytes: jnp.ndarray,
                a_bytes: jnp.ndarray, n_kern: jnp.ndarray,
                b: jnp.ndarray, r: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    total_r = r.sum(axis=-1)
    shrink = jnp.maximum(1.0, total_r)
    thrash = 1.0 + 0.6 * jnp.maximum(0.0, total_r - 1.0)
    r = r / shrink[..., None]

    t_load = d_load * b / hw.pcie_bw
    t_feedback = d_fb * b / hw.pcie_bw
    flops = flops_i * b * (1.0 + 0.004 * b)
    bytes_ = w_bytes + a_bytes * b
    t_compute = flops / (hw.peak_flops * hw.mxu_efficiency) * 1e3
    t_mem = bytes_ / hw.hbm_bw * 1e3
    r_eff = jnp.maximum(r, 1e-3)
    t_c = t_compute / r_eff
    t_m = t_mem / r_eff
    t_act_solo = jnp.maximum(t_c, t_m) + 0.35 * jnp.minimum(t_c, t_m) + 0.05
    cache_util = jnp.minimum(1.0, (bytes_ / (t_act_solo * 1e-3)) / hw.hbm_bw)
    util = t_c / t_act_solo
    power = hw.power_cap * physics.ACTIVE_W_SCALE * r_eff * (0.35 + 0.65 * util)
    per_kernel = 0.002 + 5e-6 * n_kern

    total_bw = cache_util.sum(axis=-1)
    device_power = hw.idle_power + power.sum(axis=-1)
    excess = jnp.maximum(device_power - hw.power_cap, 0.0)
    freq = jnp.where(device_power <= hw.power_cap, hw.max_freq,
                     jnp.maximum(hw.max_freq
                                 + hw.alpha_f * excess ** physics.FREQ_EXP,
                                 0.6 * hw.max_freq))

    per_kernel = per_kernel * (1.0 + physics.SCHED_COLOC_SLOPE *
                               max(0.0, (n_co - 1)) ** physics.SCHED_COLOC_EXP)
    t_sched = per_kernel * n_kern * jnp.ones_like(b)
    infl = jnp.where(total_bw > physics.BW_KNEE,
                     (total_bw / physics.BW_KNEE) ** physics.BW_EXP, 1.0)
    t_m_infl = t_m * infl[..., None]
    t_act = (jnp.maximum(t_c, t_m_infl)
             + 0.35 * jnp.minimum(t_c, t_m_infl) + 0.05) * thrash[..., None]
    return (t_load * jnp.ones_like(b), t_sched, t_act,
            t_feedback * jnp.ones_like(b), freq)


def table_values(d_load, d_fb, flops_i, w_bytes, a_bytes, n_kern,
                 b, r, n_co: int, hw: HardwareSpec):
    """Numpy-in / numpy-out wrapper over the jitted table evaluation."""
    import numpy as np
    out = _tables_jit(hw, int(n_co), d_load, d_fb, flops_i, w_bytes,
                      a_bytes, n_kern, b, r)
    return tuple(np.asarray(a) for a in out)
