"""Serving workload sets: the 12-workload App study (paper Table 3
analogue) over four heterogeneous served models from the assigned
architecture pool.

Paper Table 3 uses 4 CNNs x 3 Apps with latency SLOs (ms) and expected
throughputs (req/s).  Our analogue serves 4 transformer-family models
(attention-free RWKV6, dense GQA, VLM, encoder-decoder audio) at request
shapes sized for sub-100 ms single-chip inference on TPU v5e.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.types import WorkloadSpec
from repro.profiling.metrics import ServedModelDesc, serving_models

# (model, latency SLO ms, rate req/s) per App — W1..W12.
APP_TABLE = [
    # App1: tight latency
    ("rwkv6-1.6b",        60.0, 120.0),   # W1
    ("qwen1.5-4b",        90.0,  60.0),   # W2
    ("qwen2-vl-7b",      130.0,  60.0),   # W3
    ("whisper-large-v3", 130.0,  30.0),   # W4
    # App2: high rate
    ("rwkv6-1.6b",        90.0, 250.0),   # W5
    ("qwen1.5-4b",       180.0,  60.0),   # W6
    ("qwen2-vl-7b",      180.0,  60.0),   # W7
    ("whisper-large-v3",  90.0,  60.0),   # W8
    # App3: relaxed latency
    ("rwkv6-1.6b",       130.0, 120.0),   # W9
    ("qwen1.5-4b",       240.0,  30.0),   # W10
    ("qwen2-vl-7b",      240.0,  60.0),   # W11
    ("whisper-large-v3", 240.0,  60.0),   # W12
]


def twelve_workloads() -> List[WorkloadSpec]:
    return [WorkloadSpec(name=f"W{i+1}", model=m, slo_ms=slo, rate_rps=rate)
            for i, (m, slo, rate) in enumerate(APP_TABLE)]


def specs_by_name() -> Dict[str, WorkloadSpec]:
    return {w.name: w for w in twelve_workloads()}


def models() -> Dict[str, ServedModelDesc]:
    return serving_models()


def synthetic_workloads(m: int, seed: int = 0) -> List[WorkloadSpec]:
    """m synthetic workloads for the large-cluster scale sweep (paper
    Sec. 5.4 claims Alg. 1 provisions m=1000 in 4.61 s).

    Each workload is a jittered sample of an `APP_TABLE` row — SLO x
    U[0.8, 1.6), rate x U[0.5, 1.5) — so the mix stays feasible on the
    fitted profiles while exercising heterogeneous SLO/rate pressure.
    """
    import numpy as np
    rng = np.random.default_rng(seed)
    out = []
    for i in range(m):
        model, slo, rate = APP_TABLE[int(rng.integers(len(APP_TABLE)))]
        out.append(WorkloadSpec(
            name=f"S{i}", model=model,
            slo_ms=round(float(slo * rng.uniform(0.8, 1.6)), 1),
            rate_rps=round(float(rate * rng.uniform(0.5, 1.5)), 1)))
    return out


# The illustrative 3-workload example of paper Sec. 2.3 (Table 1).
def three_workloads() -> List[WorkloadSpec]:
    return [
        WorkloadSpec(name="A", model="rwkv6-1.6b", slo_ms=60.0, rate_rps=120.0),
        WorkloadSpec(name="R", model="qwen1.5-4b", slo_ms=150.0, rate_rps=60.0),
        WorkloadSpec(name="V", model="qwen2-vl-7b", slo_ms=200.0, rate_rps=60.0),
    ]
