"""Queueing-aware SLO budget split (beyond the paper's fixed T_slo/2).

iGniter's Theorem 1 / Alg. 2 (Eqs. 14, 17, 18) give inference the entire
`T_slo / 2` service budget with zero tail slack: the provisioned point
puts every instance at utilization ~1 (batch service time ~= batch
accumulation time), so queueing waits explode under arrival bursts and
latency noise — the measured 5-predicted-vs-178-simulated violation gap
at m=1000 (see ROADMAP).  Clipper-style adaptive batching and SLO-aware
schedulers with explicit waiting-time models both put a queueing term in
the latency budget; this module does the same for the provisioner.

Model — greedy dynamic batching server (serve-all-waiting up to b_appr,
exactly what `serving/simulator.py` implements):

  * **batch-accumulation wait**: a batch of b spans an arrival window of
    (b - 1) / R_ms; a request waits (b - 1) / (2 R_ms) in expectation
    and up to (b - 1) / R_ms at the tail (the greedy server's in-flight
    pass residual is bounded by the same quantity at the provisioned
    point, where one pass accumulates the next batch).
  * **M/D/1-style utilization wait**: the batch processor is a single
    server with deterministic service t_inf and utilization
    rho = R_ms * t_inf / b.  Arrivals of FULL batches are b-fold
    aggregated Poisson (squared arrival CV = burstiness / b), so the
    Kingman/Pollaczek-Khinchine mean wait is
        W = burstiness * rho * t_inf / (2 b (1 - rho)),
    and the tail quantile follows the standard exponential-tail
    approximation W_q = W * -ln(1 - q).  rho >= 1 means the batch
    server cannot sustain the arrival rate: infinite wait.

Budget split: the inference budget B replaces T_slo / 2 as the Alg. 2
threshold.  B is the largest value satisfying

    B + t_queue_tail(b, R, t_inf = B) + slack <= T_slo

solved by fixed-iteration bisection (deterministic and engine-
independent: the scalar and vectorized provisioning engines consume the
exact same float).  Evaluating the tail at t_inf = B is conservative —
the realized service time is below its budget — and makes the split a
pure function of (T_slo, R, b).  B is capped at T_slo / 2 so a
queueing-aware allocation is NEVER looser than the paper's half split;
the cap binds only when the queueing terms are negligible.

`budget="half"` keeps the paper-faithful fixed split (`T_slo / 2`
bit-for-bit); `budget="queueing"` is the provisioner-wide default.

Online use (docs/control-plane.md): the control plane re-solves budgets
with `BudgetModel.with_burstiness(cv2)` — the measured arrival CV^2
clamped to [BURSTINESS_LO, BURSTINESS_HI] and additionally FLOORED at
the provisioned model's burstiness by the reconciler (the "burstiness
floor": a deterministic trace's cv2 ~ 0 must never loosen budgets
mid-drift, while a spike train's cv2 >> 1 tightens them).  Replica
groups need no special casing here: each replica's budget is solved at
its RATE SHARE, which is what makes splitting an infeasible workload
recover a feasible per-replica budget (docs/provisioning.md).

The full narrative — model, solver, and how the split closed the
5-predicted-vs-178-simulated violation gap — lives in
docs/provisioning.md ("The SLO budget split").
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass
from typing import Union

import numpy as np

# Utilizations at/above this are treated as unstable (infinite wait).
RHO_MAX = 1.0 - 1e-9
# Bisection iterations: 60 halvings of a [0, T_slo] bracket put the
# budget within ~1e-15 * T_slo — far inside the engines' 1e-9 contract.
SOLVE_ITERS = 60


@dataclass(frozen=True)
class QueueingDelay:
    """Decomposed batch-formation/waiting delay for one workload."""
    t_acc_mean: float     # expected batch-accumulation wait  (b-1)/(2R)
    t_acc_tail: float     # worst-request accumulation wait   (b-1)/R
    rho: float            # batch-server utilization R_ms * t_inf / b
    t_util_mean: float    # mean M/D/1-style utilization wait
    t_util_tail: float    # quantile utilization wait
    expected: float       # t_acc_mean + t_util_mean
    tail: float           # t_acc_tail + t_util_tail


def t_queue(b: float, rate_rps: float, t_inf: float, *,
            quantile: float = 0.99,
            burstiness: float = 1.0) -> QueueingDelay:
    """Expected + tail batch-formation/waiting delay [ms].

    ``burstiness`` scales the squared coefficient of variation of the
    arrival process: 1.0 = Poisson, 0.0 = deterministic (zero-burst)
    arrivals, under which the utilization wait vanishes and b=1 queues
    not at all.  Monotonically nondecreasing in utilization (via t_inf,
    for fixed b and R) and in batch size at fixed utilization (t_inf
    scaled with b); at FIXED t_inf a larger batch can wait less near
    rho -> 1, where its capacity relief outweighs the extra
    accumulation.
    """
    r_ms = rate_rps / 1000.0
    if r_ms <= 0.0:          # no arrivals: nothing ever queues
        return QueueingDelay(t_acc_mean=0.0, t_acc_tail=0.0, rho=0.0,
                             t_util_mean=0.0, t_util_tail=0.0,
                             expected=0.0, tail=0.0)
    t_acc_mean = (b - 1.0) / (2.0 * r_ms)
    t_acc_tail = (b - 1.0) / r_ms
    rho = r_ms * t_inf / b
    if rho >= RHO_MAX:
        t_util_mean = t_util_tail = math.inf
    else:
        t_util_mean = burstiness * rho * t_inf / (2.0 * b * (1.0 - rho))
        t_util_tail = t_util_mean * -math.log1p(-quantile)
    return QueueingDelay(
        t_acc_mean=t_acc_mean, t_acc_tail=t_acc_tail, rho=rho,
        t_util_mean=t_util_mean, t_util_tail=t_util_tail,
        expected=t_acc_mean + t_util_mean, tail=t_acc_tail + t_util_tail)


def _tail_ms(b: float, r_ms: float, t_inf: float,
             quantile: float, burstiness: float) -> float:
    """Tail t_queue (scalar fast path of the bisection objective)."""
    if r_ms <= 0.0:          # no arrivals: nothing ever queues
        return 0.0
    rho = r_ms * t_inf / b
    if rho >= RHO_MAX:
        return math.inf
    w = burstiness * rho * t_inf / (2.0 * b * (1.0 - rho))
    return (b - 1.0) / r_ms + w * -math.log1p(-quantile)


@dataclass(frozen=True)
class BudgetModel:
    """SLO budget split policy handed through the provisioning stack.

    mode:       "queueing" (solved split) or "half" (paper's T_slo / 2)
    quantile:   tail quantile the queueing wait is budgeted at
    slack_frac: extra safety slack as a fraction of T_slo (absorbs the
                simulator's ~1.5% lognormal service-time noise at p99)
    burstiness: arrival-process squared-CV scale (1 = Poisson)
    """
    mode: str = "queueing"
    quantile: float = 0.99
    slack_frac: float = 0.02
    burstiness: float = 1.0

    # clamp range for online burstiness estimates (`with_burstiness`):
    # the floor keeps a near-deterministic estimate from zeroing the
    # utilization-wait term entirely, the ceiling keeps one pathological
    # window from blowing every budget to the T_slo/2 cap.
    BURSTINESS_LO = 0.25
    BURSTINESS_HI = 8.0

    def __post_init__(self):
        if self.mode not in ("half", "queueing"):
            raise ValueError(f"unknown budget mode {self.mode!r}")

    def with_burstiness(self, cv2: float) -> "BudgetModel":
        """A copy with the arrival-burstiness scale replaced by a
        (clamped) online CV^2 estimate — the control plane's hook for
        adapting the budget split to the measured arrival process."""
        return dataclasses.replace(
            self, burstiness=min(self.BURSTINESS_HI,
                                 max(self.BURSTINESS_LO, float(cv2))))

    def budget_ms(self, slo_ms: float, rate_rps: float, batch: int) -> float:
        """The inference-latency budget B replacing T_slo / 2."""
        if self.mode == "half":
            return slo_ms / 2.0
        return _solve_budget(self, float(slo_ms), float(rate_rps),
                             float(batch))

    def budget_ms_vec(self, slo_ms: np.ndarray, rate_rps: np.ndarray,
                      batch: np.ndarray) -> np.ndarray:
        """Batched budget evaluation — bitwise-identical to `budget_ms`
        per row (same bracket, iteration count and float operations;
        the quantile factor MUST come from `math.log1p`, whose last ulp
        differs from `np.log1p`'s, or the two paths drift 1e-14 apart
        and the bitwise plan-identity contracts break)."""
        slo = np.asarray(slo_ms, dtype=np.float64)
        if self.mode == "half":
            return slo / 2.0
        r_ms = np.asarray(rate_rps, dtype=np.float64) / 1000.0
        b = np.asarray(batch, dtype=np.float64)
        target = slo * (1.0 - self.slack_frac)
        qf = -math.log1p(-self.quantile)
        lo = np.zeros_like(slo)
        hi = slo.copy()
        # Loop constants hoisted (same float ops per iteration as the
        # scalar solver — `2.0 * b * (...)` associates left, so b2 is
        # the exact intermediate): this bisection runs on every
        # controller probe, where per-iteration numpy dispatch is the
        # dominant edit-overhead term.
        b2 = 2.0 * b
        no_arrivals = ~(r_ms > 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            acc = (b - 1.0) / r_ms
            for _ in range(SOLVE_ITERS):
                mid = 0.5 * (lo + hi)
                rho = r_ms * mid / b
                w = self.burstiness * rho * mid / (b2 * (1.0 - rho))
                tail = np.where(rho >= RHO_MAX, np.inf, acc + w * qf)
                tail = np.where(no_arrivals, 0.0, tail)
                ok = mid + tail <= target
                lo = np.where(ok, mid, lo)
                hi = np.where(ok, hi, mid)
        return np.minimum(lo, slo / 2.0)


@functools.lru_cache(maxsize=200_000)
def _solve_budget(bm: BudgetModel, slo_ms: float, rate_rps: float,
                  batch: float) -> float:
    """Scalar bisection for the budget split (cached: the provisioning
    hot loops re-evaluate the same (workload, batch) pairs constantly).
    Bitwise-identical to one row of `budget_ms_vec` — same bracket,
    iteration count and float operations."""
    r_ms = rate_rps / 1000.0
    target = slo_ms * (1.0 - bm.slack_frac)
    lo, hi = 0.0, slo_ms
    for _ in range(SOLVE_ITERS):
        mid = 0.5 * (lo + hi)
        if mid + _tail_ms(batch, r_ms, mid, bm.quantile,
                          bm.burstiness) <= target:
            lo = mid
        else:
            hi = mid
    return min(lo, slo_ms / 2.0)


# Shared singletons: `resolve` maps the string API (budget="half" /
# "queueing") onto them so identity-based caches stay warm.
HALF = BudgetModel(mode="half")
QUEUEING = BudgetModel(mode="queueing")

BudgetLike = Union[str, BudgetModel]


def resolve(budget: BudgetLike) -> BudgetModel:
    """Accept "half" / "queueing" / a BudgetModel instance."""
    if isinstance(budget, BudgetModel):
        return budget
    if budget == "half":
        return HALF
    if budget == "queueing":
        return QUEUEING
    raise ValueError(f"unknown budget {budget!r} "
                     "(expected 'half', 'queueing' or a BudgetModel)")
