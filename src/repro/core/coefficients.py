"""Coefficient acquisition (paper Sec. 3.1, "Obtaining Model Coefficients").

The paper fits all workload-specific coefficients from **11 solo
profiling configurations** plus a handful of co-located runs, using least
squares.  This module implements exactly that:

  * Eq. (11) surface k_act(b, r): grid-search k4, linear least squares for
    (k1, k2, k3, k5) at each candidate (the model is linear given k4).
  * p(b/k_act), c(b/k_act): 1-D linear fits.
  * alpha_cache: through-origin slope of active-time inflation vs the
    summed neighbor cache utilization (2..5 co-located runs).
  * hardware (alpha_sch, beta_sch): linear fit of the per-kernel extra
    dispatch delay vs the co-location count; alpha_f: slope of frequency
    drop vs excess power.

The profiling *testbed* is abstracted behind `ProfilingTestbed`; the
discrete-event simulator implements it (and on real hardware, Nsight-
style measurement would).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Protocol, Sequence, Tuple

import numpy as np

from repro.core.types import HardwareSpec, WorkloadCoefficients


@dataclass(frozen=True)
class ProfileSample:
    """One measured run of a workload (solo or co-located)."""
    model: str
    batch: int
    r: float
    t_load: float          # ms
    t_sched: float         # ms (total dispatch delay)
    t_act: float           # ms (active time)
    t_feedback: float      # ms
    power: float           # W (this workload's draw)
    cache_util: float      # [0,1] solo bandwidth/L2 demand
    n_kernels: int
    d_load: float          # MB at this batch
    d_feedback: float      # MB at this batch
    device_freq: float = 0.0    # MHz (co-located runs)
    device_power: float = 0.0   # W total (co-located runs)


class ProfilingTestbed(Protocol):
    def run_solo(self, model: str, batch: int, r: float) -> ProfileSample: ...
    def run_colocated(self, entries: Sequence[Tuple[str, int, float]]
                      ) -> List[ProfileSample]: ...


# The paper's 11 configurations: 5 x resource sweep, 5 x batch sweep, +1.
ELEVEN_CONFIGS: Tuple[Tuple[int, float], ...] = (
    (8, 0.2), (8, 0.4), (8, 0.6), (8, 0.8), (8, 1.0),
    (1, 0.5), (2, 0.5), (4, 0.5), (16, 0.5), (32, 0.5),
    (4, 0.3),
)


def fit_k_act(samples: Sequence[ProfileSample],
              k4_grid: np.ndarray | None = None
              ) -> Tuple[float, float, float, float, float]:
    """Fit Eq. (11) by k4 grid search + linear least squares."""
    if k4_grid is None:
        k4_grid = np.linspace(0.0, 1.0, 101)[1:]   # k4 > 0 keeps r+k4 nonzero
    b = np.array([s.batch for s in samples], dtype=np.float64)
    r = np.array([s.r for s in samples], dtype=np.float64)
    y = np.array([s.t_act for s in samples], dtype=np.float64)
    best = None
    for k4 in k4_grid:
        den = r + k4
        X = np.stack([b * b / den, b / den, 1.0 / den, np.ones_like(b)], axis=1)
        theta, *_ = np.linalg.lstsq(X, y, rcond=None)
        resid = y - X @ theta
        sse = float(resid @ resid)
        if best is None or sse < best[0]:
            best = (sse, k4, theta)
    _, k4, (k1, k2, k3, k5) = best
    return float(k1), float(k2), float(k3), float(k4), float(k5)


def _linfit(x: np.ndarray, y: np.ndarray) -> Tuple[float, float]:
    X = np.stack([x, np.ones_like(x)], axis=1)
    (a, b), *_ = np.linalg.lstsq(X, y, rcond=None)
    return float(a), float(b)


def fit_workload(model: str, hw: HardwareSpec, testbed: ProfilingTestbed, *,
                 configs: Sequence[Tuple[int, float]] = ELEVEN_CONFIGS,
                 partners: Sequence[Tuple[int, float]] = ((1, 0.4), (1, 0.6),
                                                          (1, 0.8), (2, 0.8)),
                 coloc_batch: int = 8, coloc_r: float = 0.2
                 ) -> WorkloadCoefficients:
    """Full lightweight acquisition for one workload on one hardware type."""
    solo = [testbed.run_solo(model, b, r) for (b, r) in configs]
    k1, k2, k3, k4, k5 = fit_k_act(solo)

    ability = np.array([s.batch / s.t_act for s in solo])
    a_p, b_p = _linfit(ability, np.array([s.power for s in solo]))
    a_c, b_c = _linfit(ability, np.array([s.cache_util for s in solo]))

    s1 = solo[0]
    d_load = s1.d_load / s1.batch
    d_feedback = s1.d_feedback / s1.batch
    k_sch = float(np.mean([s.t_sched / s.n_kernels for s in solo]))

    # alpha_cache: pair runs against an increasingly bandwidth-hungry
    # partner (paper: 2..5 concurrent launches); through-origin slope of
    # active-time inflation vs summed neighbor utilization.
    solo_ref = testbed.run_solo(model, coloc_batch, coloc_r)
    xs, ys = [], []
    for (bp_, rp) in partners:
        runs = testbed.run_colocated(
            [(model, coloc_batch, coloc_r), (model, bp_, rp)])
        me = runs[0]
        xs.append(sum(r_.cache_util for r_ in runs[1:]))
        ys.append(max(0.0, me.t_act / solo_ref.t_act - 1.0))
    xs_a, ys_a = np.array(xs), np.array(ys)
    denom = float(xs_a @ xs_a)
    alpha_cache = float(xs_a @ ys_a / denom) if denom > 0 else 0.0

    return WorkloadCoefficients(
        model=model, hardware=hw.name,
        d_load=d_load, d_feedback=d_feedback,
        n_kernels=s1.n_kernels, k_sch=k_sch,
        k1=k1, k2=k2, k3=k3, k4=k4, k5=k5,
        alpha_power=a_p, beta_power=b_p,
        alpha_cacheutil=a_c, beta_cacheutil=b_c,
        alpha_cache=alpha_cache,
    )


def fit_hardware(reference_model: str, base_hw: HardwareSpec,
                 testbed: ProfilingTestbed, *,
                 coloc_counts: Sequence[int] = (2, 3, 4, 5),
                 batch: int = 8) -> HardwareSpec:
    """Fit (alpha_sch, beta_sch, alpha_f) with one reference workload
    (paper: VGG-19, ~229 s once per GPU type)."""
    solo = testbed.run_solo(reference_model, batch, 0.2)
    k_sch = solo.t_sched / solo.n_kernels

    ns, deltas = [], []
    freq_x, freq_y = [], []
    for n in coloc_counts:
        runs = testbed.run_colocated([(reference_model, batch, 0.2)] * n)
        me = runs[0]
        deltas.append(me.t_sched / me.n_kernels - k_sch)
        ns.append(float(n))
        if me.device_power > base_hw.power_cap:
            freq_x.append(me.device_power - base_hw.power_cap)
            freq_y.append(me.device_freq - base_hw.max_freq)
    a_sch, b_sch = _linfit(np.array(ns), np.array(deltas))
    if len(freq_x) >= 2:
        alpha_f, _ = _linfit(np.array(freq_x), np.array(freq_y))
    else:
        alpha_f = base_hw.alpha_f
    return dataclasses.replace(base_hw, alpha_sch=a_sch, beta_sch=b_sch,
                               alpha_f=alpha_f)
