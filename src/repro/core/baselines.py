"""Baseline provisioning strategies the paper compares against (Sec. 5.1):

  FFD+      first-fit-decreasing, allocates exactly r_lower (interference-
            oblivious both in placement and allocation).
  FFD++     FFD placement but allocation via Alg. 2 (`alloc_gpus`) — the
            paper's Fig. 19 ablation.
  GSLICE+   GSLICE patched with our placement; tunes r and b *reactively*
            and separately per workload with a fixed threshold, oblivious
            to co-located workloads (can over-subscribe a device).
  gpu-lets+ throughput-maximizing resource sizing over a coarse grid
            {20,40,50,60,80}%, at most TWO workloads per device, best-fit
            placement, pairwise-only interference estimate, and never
            re-adjusts the originally-placed workload.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import perf_model as pm
from repro.core import perf_model_vec as pmv
from repro.core import provisioner as prov
from repro.core.queueing import BudgetLike, QUEUEING, resolve
from repro.core.types import (HardwareSpec, Placement, PlannerConfig,
                              ProvisioningPlan, WorkloadCoefficients,
                              WorkloadSpec, planner_config)

R_MAX = 1.0


# ---------------------------------------------------------------------------
# FFD+ / FFD++
# ---------------------------------------------------------------------------

def provision_ffd(specs: Sequence[WorkloadSpec],
                  profiles: Dict[str, WorkloadCoefficients],
                  hw: HardwareSpec, *, use_alloc_gpus: bool = False,
                  config: Optional[PlannerConfig] = None,
                  engine: Optional[str] = None,
                  budget: Optional[BudgetLike] = None) -> ProvisioningPlan:
    cfg = planner_config(config, engine=engine, budget=budget)
    bm = resolve(cfg.budget)
    prepared = prov._prepare(specs, profiles, hw, budget=bm)
    if use_alloc_gpus and cfg.engine == "vec":
        return _provision_ffd_vec(prepared, hw, bm, backend=cfg.backend)

    devs: List[prov._Dev] = []
    for (s, c, b, rl) in prepared:
        placed = False
        for dev in devs:
            if use_alloc_gpus:
                r_a = prov.alloc_gpus(dev, s, c, b, rl, hw, budget=bm)
                if r_a is not None:
                    dev.entries = [
                        (e[0], e[1], e[2], r_new)
                        for e, r_new in zip(dev.entries, r_a[:-1])
                    ] + [(s, c, b, r_a[-1])]
                    placed = True
                    break
            else:
                if dev.total() + rl <= R_MAX + 1e-9:
                    dev.entries.append((s, c, b, rl))
                    placed = True
                    break
        if not placed:
            devs.append(prov._Dev(entries=[(s, c, b, rl)]))

    plan = ProvisioningPlan(hardware=hw)
    for g, dev in enumerate(devs):
        for (s, c, b, r) in dev.entries:
            plan.placements.append(Placement(workload=s, gpu=g, r=r, batch=b))
    plan.n_gpus = len(devs)
    return plan


def _provision_ffd_vec(prepared, hw: HardwareSpec,
                       budget: BudgetLike = QUEUEING, *,
                       backend: str = "numpy") -> ProvisioningPlan:
    """FFD++ through the batched scorer: Alg. 2 runs against every open
    device in one call, first-fit picks the earliest feasible one."""
    cl = pmv.VecCluster(hw, budget=budget, backend=backend)
    for (s, c, b, rl) in prepared:
        q_fit = -1
        if cl.d:
            feasible, rr, rn, _ = cl.alloc_all(s, c, b, rl)
            fit = np.where(feasible)[0]
            q_fit = int(fit[0]) if fit.size else -1
        if q_fit == -1:
            q = cl.add_device()
            cl.add_entry(q, s, c, b, rl)
        else:
            cl.set_row_r(q_fit, rr[q_fit])
            cl.add_entry(q_fit, s, c, b, float(rn[q_fit]))

    plan = ProvisioningPlan(hardware=hw)
    for g in range(cl.d):
        for i, (s, c, b) in enumerate(cl.entries[g]):
            plan.placements.append(
                Placement(workload=s, gpu=g, r=float(cl.r[g, i]), batch=b))
    plan.n_gpus = cl.d
    return plan


# ---------------------------------------------------------------------------
# GSLICE+
# ---------------------------------------------------------------------------

MeasureFn = Callable[[List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]]],
                     List[Tuple[float, float]]]
# measure_fn(device entries) -> [(observed avg latency ms, observed rps)] per entry


def provision_gslice(specs: Sequence[WorkloadSpec],
                     profiles: Dict[str, WorkloadCoefficients],
                     hw: HardwareSpec, measure_fn: MeasureFn, *,
                     rounds: int = 5, threshold: float = 0.10,
                     config: Optional[PlannerConfig] = None,
                     budget: Optional[BudgetLike] = None
                     ) -> ProvisioningPlan:
    """GSLICE+ — iGniter's *placement* (per the paper's patch) but GSLICE's
    allocation policy: start from an equal spatial split of each device,
    then run `rounds` of reactive, per-workload threshold tuning against
    observed latency/throughput.  Each workload is tuned separately with
    no awareness of co-located demand, so a device can end up
    over-subscribed (sum r > 100%) — the pathology of Fig. 15/16 — and
    resources are reclaimed whenever latency sits below the threshold
    band, which trades SLO safety for utilization."""
    cfg = planner_config(config, budget=budget)
    bm = resolve(cfg.budget)
    base = prov.provision(specs, profiles, hw, config=cfg.replace(budget=bm))
    devs: Dict[int, List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]]] = {}
    for p in base.placements:
        devs.setdefault(p.gpu, []).append(
            (p.workload, profiles[p.workload.model], p.batch, p.r))
    # GSLICE initial state: equal split, batch grown from 1 reactively
    for g, entries in devs.items():
        share = round((R_MAX / len(entries)) / hw.r_unit) * hw.r_unit
        devs[g] = [(s, c, 1, share) for (s, c, b, r) in entries]

    for g, entries in devs.items():
        for _ in range(rounds):
            obs = measure_fn(entries)
            new_entries = []
            changed = False
            for (s, c, b, r), (lat, rps) in zip(entries, obs):
                target = bm.budget_ms(s.slo_ms, s.rate_rps, b)
                if lat > target:                        # violating -> grow
                    r = min(R_MAX, round(r + 2 * hw.r_unit, 10))
                    changed = True
                elif lat < (1.0 - threshold) * target:  # reclaim (oscillates)
                    r = max(hw.r_unit, round(r - hw.r_unit, 10))
                    changed = True
                if rps < s.rate_rps and b < 64:         # throughput short
                    b = min(64, b + max(1, int(b * 0.5)))
                    changed = True
                elif rps > (1 + threshold) * s.rate_rps and b > 1 and lat > target:
                    b = b - 1
                    changed = True
                new_entries.append((s, c, b, r))
            entries[:] = new_entries
            if not changed:
                break

    plan = ProvisioningPlan(hardware=hw)
    for g, entries in devs.items():
        for (s, c, b, r) in entries:
            plan.placements.append(Placement(workload=s, gpu=g, r=r, batch=b))
    plan.n_gpus = len(devs)
    return plan


# ---------------------------------------------------------------------------
# gpu-lets+
# ---------------------------------------------------------------------------

_GPULETS_CHOICES = (0.2, 0.4, 0.5, 0.6, 0.8)


def _solo_throughput(c: WorkloadCoefficients, b: int, r: float,
                     hw: HardwareSpec) -> float:
    t_gpu = c.k_sch * c.n_kernels + c.k_act(b, r)
    return 1000.0 * b / (t_gpu + c.t_feedback(b, hw.pcie_bw))


def _most_efficient_r(spec: WorkloadSpec, c: WorkloadCoefficients, b: int,
                      hw: HardwareSpec, knee: float = 0.30,
                      budget: BudgetLike = QUEUEING) -> float:
    """gpu-lets sizing: the grid point where marginal throughput efficiency
    knees, grown until the solo latency budget and arrival rate are met."""
    bm = resolve(budget)
    budget_ms = bm.budget_ms(spec.slo_ms, spec.rate_rps, b)
    choice = _GPULETS_CHOICES[-1]
    for i, r in enumerate(_GPULETS_CHOICES[:-1]):
        cur = _solo_throughput(c, b, r, hw)
        nxt = _solo_throughput(c, b, _GPULETS_CHOICES[i + 1], hw)
        if (nxt - cur) / max(cur, 1e-9) < knee:
            choice = r
            break
    idx = _GPULETS_CHOICES.index(choice)
    while idx < len(_GPULETS_CHOICES) - 1:
        r = _GPULETS_CHOICES[idx]
        me = pm.PlacedWorkload(coeffs=c, batch=b, r=r)
        lat = pm.predict_workload(me, [], hw).t_inf
        if (lat <= budget_ms
                and _solo_throughput(c, b, r, hw) >= spec.rate_rps):
            break
        idx += 1
    return _GPULETS_CHOICES[idx]


def provision_gpulets(specs: Sequence[WorkloadSpec],
                      profiles: Dict[str, WorkloadCoefficients],
                      hw: HardwareSpec, *,
                      config: Optional[PlannerConfig] = None,
                      budget: Optional[BudgetLike] = None) -> ProvisioningPlan:
    cfg = planner_config(config, budget=budget)
    bm = resolve(cfg.budget)
    prepared = []
    for s in specs:
        c = profiles[s.model]
        b = prov.appropriate_batch(s, c, hw,   # paper-modified batch policy
                                   budget=bm)
        r = _most_efficient_r(s, c, b, hw, budget=bm)
        prepared.append((s, c, b, r))
    prepared.sort(key=lambda t: -t[3])

    # best-fit with at most 2 workloads per device; pairwise interference
    # check for the NEW workload only (the original is never re-checked).
    # All candidate devices are scored through one batched-model call.
    devs: List[List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]]] = []
    for (s, c, b, r) in prepared:
        me = pm.PlacedWorkload(coeffs=c, batch=b, r=r)
        cand = [i for i, entries in enumerate(devs)
                if len(entries) < 2
                and sum(e[3] for e in entries) + r <= R_MAX + 1e-9]
        best_i, best_left = -1, None
        if cand:
            batch_pred = pmv.predict_device_batch(
                [[pm.PlacedWorkload(coeffs=e[1], batch=e[2], r=e[3])
                  for e in devs[i]] + [me] for i in cand], hw)
            for q, i in enumerate(cand):
                # newcomer occupies the last slot of candidate device q
                lat = float(batch_pred.t_inf[q, len(devs[i])])
                if lat > bm.budget_ms(s.slo_ms, s.rate_rps, b):
                    continue
                left = R_MAX - sum(e[3] for e in devs[i]) - r
                if best_left is None or left < best_left:
                    best_i, best_left = i, left
        if best_i == -1:
            devs.append([(s, c, b, r)])
        else:
            devs[best_i].append((s, c, b, r))

    plan = ProvisioningPlan(hardware=hw)
    for g, entries in enumerate(devs):
        for (s, c, b, r) in entries:
            plan.placements.append(Placement(workload=s, gpu=g, r=r, batch=b))
    plan.n_gpus = len(devs)
    return plan
