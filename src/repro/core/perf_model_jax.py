"""JAX-jitted twins of the batched iGniter model and budget solver.

`repro.core.perf_model_vec` is the numpy hot path and stays the pinned
oracle; this module re-expresses its three inner loops as jitted XLA
programs for the m=10,000 tier:

  * ``predict_device_batch_jax``  Eqs. (1)-(11) over padded (D, N)
                                  device arrays under `jax.jit` (the
                                  `perf_model_vec._eval` twin)
  * ``budget_ms_vec_jax``         the queueing-aware SLO budget split as
                                  a fixed-iteration `lax.fori_loop`
                                  bisection (`queueing.budget_ms_vec`
                                  twin — SOLVE_ITERS halvings, same
                                  bracket, same cap at T_slo/2)
  * ``alloc_all_jax``             Algorithm 2 against every open device
                                  as ONE `lax.while_loop` with
                                  fixed-capacity shapes (the
                                  `VecCluster.alloc_all` twin), driving
                                  both Alg. 1 placement and the
                                  controller's feasibility probes when
                                  `PlannerConfig(backend="jax")`

Layout contract: shapes are the VecCluster capacities (powers of two),
NOT the live device count d — d arrives as a traced scalar and
``row_valid = arange(cap_d) < d`` masks the padding rows, so XLA
recompiles only when a capacity doubles (~log2(D) times per sweep).
Per-entry SLO budgets are always solved on the numpy side
(`queueing.BudgetModel`) and passed in as arrays: both backends consume
bit-identical thresholds, and only the model arithmetic itself crosses
into XLA.

Numerical contract: agreement with the numpy oracle is pinned at
<= 1e-6 (tests/test_perf_model_jax.py), NOT the scalar-vs-vec 1e-9 —
XLA may reassociate sums and fuse multiply-adds, so last-bit equality is
out of scope by design (docs/reproduction-notes.md, deviation 5).
Plan-level decisions still agree exactly on the pinned workloads
because Alg. 1/2 thresholds carry 1e-9 epsilons, orders of magnitude
above the float divergence.

float64 is mandatory: the 1e-9 decision epsilons drown in float32
noise.  Importing this module enables jax x64 mode process-wide.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 switch on purpose)
from jax import lax  # noqa: E402

from repro.core import perf_model as pm  # noqa: E402
from repro.core import perf_model_vec as pmv  # noqa: E402
from repro.core.queueing import (  # noqa: E402
    RHO_MAX, SOLVE_ITERS, BudgetModel)
from repro.core.types import (  # noqa: E402
    HardwareSpec, WorkloadCoefficients, WorkloadSpec)

R_MAX = pmv.R_MAX

# Index layout of the flat coefficient tuples handed to jitted kernels
# (same order as perf_model_vec.COEFF_FIELDS).
_F = {name: i for i, name in enumerate(pmv.COEFF_FIELDS)}


def _coeff_scalars(c: WorkloadCoefficients) -> Tuple[float, ...]:
    return tuple(float(getattr(c, f)) for f in pmv.COEFF_FIELDS)


def _coeff_arrays(ca: pmv.CoeffArrays) -> Tuple[np.ndarray, ...]:
    return tuple(getattr(ca, f) for f in pmv.COEFF_FIELDS)


def _k_act(ca, b, r):
    """Eq. (11) on a flat coefficient tuple."""
    return ((ca[_F["k1"]] * b * b + ca[_F["k2"]] * b + ca[_F["k3"]])
            / (r + ca[_F["k4"]]) + ca[_F["k5"]])


# ---------------------------------------------------------------------------
# Eqs. (1)-(11), jitted
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("hw",))
def _eval_jit(ca, b, r, mask, hw: HardwareSpec):
    """`perf_model_vec._eval` under jit: identical formula sequence."""
    k_act = _k_act(ca, b, r)
    ability = jnp.where(mask, b / k_act, 0.0)
    power = jnp.where(mask, ca[_F["alpha_power"]] * ability
                      + ca[_F["beta_power"]], 0.0)
    cache = jnp.where(mask, ca[_F["alpha_cacheutil"]] * ability
                      + ca[_F["beta_cacheutil"]], 0.0)

    n_co = mask.sum(axis=-1)
    ds = jnp.where(n_co <= 1, 0.0, hw.alpha_sch * n_co + hw.beta_sch)
    p_demand = hw.idle_power + power.sum(axis=-1)
    freq = jnp.where(p_demand <= hw.power_cap, hw.max_freq,
                     jnp.maximum(hw.max_freq
                                 + hw.alpha_f * (p_demand - hw.power_cap),
                                 0.3 * hw.max_freq))
    slowdown = freq / hw.max_freq

    other_cache = cache.sum(axis=-1)[..., None] - cache
    t_load = ca[_F["d_load"]] * b / hw.pcie_bw
    t_feedback = ca[_F["d_feedback"]] * b / hw.pcie_bw
    t_sch = (ca[_F["k_sch"]] + ds[..., None]) * ca[_F["n_kernels"]]
    t_act = k_act * (1.0 + ca[_F["alpha_cache"]] * other_cache)
    t_gpu = (t_sch + t_act) / slowdown[..., None]
    t_inf = t_load + t_gpu + t_feedback
    throughput = jnp.where(mask, 1000.0 * b / (t_gpu + t_feedback), 0.0)
    return (freq, p_demand, ds, t_load, t_sch, t_act, t_gpu,
            t_feedback, t_inf, throughput)


def predict_device_batch_jax(devices: Sequence[Sequence[pm.PlacedWorkload]],
                             hw: HardwareSpec) -> pmv.BatchPrediction:
    """Jitted drop-in for `perf_model_vec.predict_device_batch`."""
    ca, b, r, mask = pmv._pad_stack(devices)
    out = _eval_jit(_coeff_arrays(ca), b, r, mask, hw)
    (freq, p_demand, ds, t_load, t_sch, t_act, t_gpu,
     t_feedback, t_inf, throughput) = (np.asarray(a) for a in out)
    return pmv.BatchPrediction(
        mask=mask, freq=freq, p_demand=p_demand, delta_sch=ds,
        t_load=t_load, t_sch=t_sch, t_act=t_act, t_gpu=t_gpu,
        t_feedback=t_feedback, t_inf=t_inf, throughput=throughput)


# ---------------------------------------------------------------------------
# Queueing-aware budget split, jitted bisection
# ---------------------------------------------------------------------------

@jax.jit
def _budget_bisect_jit(slo, rate, batch, quantile, slack_frac, burstiness):
    """`queueing.budget_ms_vec`'s fixed-iteration bisection under jit."""
    r_ms = rate / 1000.0
    b = batch
    target = slo * (1.0 - slack_frac)
    qf = -jnp.log1p(-quantile)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        rho = r_ms * mid / b
        w = burstiness * rho * mid / (2.0 * b * (1.0 - rho))
        tail = jnp.where(rho >= RHO_MAX, jnp.inf, (b - 1.0) / r_ms + w * qf)
        tail = jnp.where(r_ms > 0.0, tail, 0.0)
        ok = mid + tail <= target
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = lax.fori_loop(0, SOLVE_ITERS, body,
                           (jnp.zeros_like(slo), slo))
    return jnp.minimum(lo, slo / 2.0)


def budget_ms_vec_jax(bm: BudgetModel, slo_ms, rate_rps, batch) -> np.ndarray:
    """Batched budget split on the JAX backend (numpy arrays in/out)."""
    slo = np.asarray(slo_ms, dtype=np.float64)
    if bm.mode == "half":
        return slo / 2.0
    out = _budget_bisect_jit(slo, np.asarray(rate_rps, dtype=np.float64),
                             np.asarray(batch, dtype=np.float64),
                             np.float64(bm.quantile),
                             np.float64(bm.slack_frac),
                             np.float64(bm.burstiness))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# Algorithm 2 over every open device: lax.while_loop
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("hw",))
def _alloc_all_jit(hw: HardwareSpec, mask, n, ca, b, r0, budget_ms,
                   k_act0, power0, cache0, t_io, t_schk,
                   power_sum, cache_sum, d,
                   cw, bn, r_lower, budget_new, grid):
    """One newcomer vs every open device, full Alg. 2 grant loop.

    Shapes are the cluster CAPACITIES; ``d`` is traced and
    ``row_valid`` masks the padding rows (they start inactive and
    infeasible-irrelevant, and the caller slices them off).  The body
    mirrors `VecCluster.alloc_all` statement for statement; the one
    reordering is the per-row grant delta sums (`np.subtract.at`'s
    sequential accumulation becomes a masked row sum), covered by the
    1e-6 contract.
    """
    cap_d = mask.shape[0]
    row_valid = jnp.arange(cap_d) < d

    def round_grid(x):
        # np.round(x, 10) equivalent.  ``grid`` (1e10) is a TRACED
        # operand on purpose: with a constant divisor XLA's fast-math
        # rewrites ``/ 1e10`` into ``* 1e-10`` (an inexact reciprocal),
        # and the allocations drift one ulp off the numpy oracle's grid
        # — enough to fail bit-identical plan checks.
        return jnp.round(x * grid) / grid

    def solo_new(rn):
        k_act = ((cw[_F["k1"]] * bn * bn + cw[_F["k2"]] * bn + cw[_F["k3"]])
                 / (rn + cw[_F["k4"]]) + cw[_F["k5"]])
        ability = bn / k_act
        return (k_act,
                cw[_F["alpha_power"]] * ability + cw[_F["beta_power"]],
                cw[_F["alpha_cacheutil"]] * ability
                + cw[_F["beta_cacheutil"]])

    rn0 = jnp.full(cap_d, r_lower)
    kan0, pn0, cn0 = solo_new(rn0)
    p_sum0 = power_sum + pn0
    c_sum0 = cache_sum + cn0
    n_co = n + 1
    ds = jnp.where(n_co <= 1, 0.0, hw.alpha_sch * n_co + hw.beta_sch)
    t_load_new = cw[_F["d_load"]] * bn / hw.pcie_bw
    t_fb_new = cw[_F["d_feedback"]] * bn / hw.pcie_bw
    t_schk_new = cw[_F["k_sch"]] * cw[_F["n_kernels"]]

    def cond(st):
        return st[-2].any()

    def body(st):
        (rr, rn, ka, pw, cu, kan, pn, cn,
         p_sum, c_sum, active, feasible) = st
        tot = jnp.where(mask, rr, 0.0).sum(axis=1) + rn
        over = active & (tot > R_MAX + 1e-9)
        feasible = feasible & ~over
        act = active & ~over

        p_dem = hw.idle_power + p_sum                               # Eq. 10
        freq = jnp.where(p_dem <= hw.power_cap, hw.max_freq,        # Eq. 9
                         jnp.maximum(hw.max_freq + hw.alpha_f
                                     * (p_dem - hw.power_cap),
                                     0.3 * hw.max_freq))
        slow = freq / hw.max_freq
        other_res = c_sum[:, None] - cu
        t_act = ka * (1.0 + ca[_F["alpha_cache"]] * other_res)
        t_sch = t_schk + ds[:, None] * ca[_F["n_kernels"]]
        t_gpu = (t_sch + t_act) / slow[:, None]
        t_inf = t_io[:, :, 0] + t_gpu + t_io[:, :, 1]
        viol_res = mask & (t_inf > budget_ms + 1e-9) & act[:, None]

        other_new = c_sum - cn
        t_act_n = kan * (1.0 + cw[_F["alpha_cache"]] * other_new)
        t_gpu_n = (t_schk_new + ds * cw[_F["n_kernels"]] + t_act_n) / slow
        t_inf_n = t_load_new + t_gpu_n + t_fb_new
        viol_new = (t_inf_n > budget_new + 1e-9) & act

        conv = act & ~viol_res.any(axis=1) & ~viol_new
        act = act & ~conv

        # grants: +r_unit to every violator on still-active devices
        grow = viol_res & act[:, None]
        rr2 = jnp.where(grow, round_grid(rr + hw.r_unit), rr)
        k_act_g = _k_act(ca, b, rr2)
        ability_g = b / k_act_g
        p_g = ca[_F["alpha_power"]] * ability_g + ca[_F["beta_power"]]
        c_g = (ca[_F["alpha_cacheutil"]] * ability_g
               + ca[_F["beta_cacheutil"]])
        ka = jnp.where(grow, k_act_g, ka)
        p_sum = p_sum - jnp.where(grow, pw - p_g, 0.0).sum(axis=1)
        c_sum = c_sum - jnp.where(grow, cu - c_g, 0.0).sum(axis=1)
        pw = jnp.where(grow, p_g, pw)
        cu = jnp.where(grow, c_g, cu)

        grow_n = viol_new & act
        rn2 = jnp.where(grow_n, round_grid(rn + hw.r_unit), rn)
        kan_g, pn_g, cn_g = solo_new(rn2)
        p_sum = p_sum + jnp.where(grow_n, pn_g - pn, 0.0)
        c_sum = c_sum + jnp.where(grow_n, cn_g - cn, 0.0)
        kan = jnp.where(grow_n, kan_g, kan)
        pn = jnp.where(grow_n, pn_g, pn)
        cn = jnp.where(grow_n, cn_g, cn)
        return (rr2, rn2, ka, pw, cu, kan, pn, cn,
                p_sum, c_sum, act, feasible)

    init = (r0, rn0, k_act0, power0, cache0, kan0, pn0, cn0,
            p_sum0, c_sum0, row_valid, jnp.ones(cap_d, dtype=bool))
    (rr, rn, _, _, _, _, _, _, _, _, _, feasible) = lax.while_loop(
        cond, body, init)

    grown = jnp.where(mask, jnp.maximum(0.0, rr - r0), 0.0)
    r_inter = grown.sum(axis=1) + jnp.maximum(0.0, rn - r_lower)
    r_inter = jnp.where(feasible, r_inter, jnp.inf)
    return feasible, rr, rn, r_inter


def alloc_all_jax(cl: "pmv.VecCluster", spec: WorkloadSpec,
                  coeffs: WorkloadCoefficients, batch: int, r_lower: float
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Backend dispatch target for `VecCluster.alloc_all` ("jax").

    The per-entry ``budget_ms`` thresholds and the newcomer's budget are
    numpy-solved (cached on the cluster / `BudgetModel.budget_ms`), so
    the jitted kernel sees bit-identical decision thresholds to the
    numpy loop.
    """
    d = cl.d
    if d == 0:
        z = np.zeros(0)
        return z.astype(bool), np.zeros((0, 1)), z, z
    hw = cl.hw
    budget_new = cl.bm.budget_ms(spec.slo_ms, spec.rate_rps, batch)
    feasible, rr, rn, r_inter = _alloc_all_jit(
        hw, cl.mask, cl.n, _coeff_arrays(cl.ca), cl.b, cl.r, cl.budget_ms,
        cl.k_act, cl.power, cl.cache, cl.t_io, cl.t_schk,
        cl.power_sum, cl.cache_sum, np.int64(d),
        _coeff_scalars(coeffs), np.float64(batch), np.float64(r_lower),
        np.float64(budget_new), np.float64(1e10))
    return (np.asarray(feasible)[:d], np.asarray(rr)[:d],
            np.asarray(rn)[:d], np.asarray(r_inter)[:d])
