"""Shared experiment pipeline: fit -> provision -> simulate.

Used by benchmarks (one per paper table/figure) and integration tests.
Results are cached per hardware type within a process.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import baselines as B
from repro.core import coefficients as C
from repro.core import provisioner as prov
from repro.core.types import (HardwareSpec, ProvisioningPlan, V4, V5E,
                              WorkloadCoefficients, WorkloadSpec)
from repro.serving.simulator import SimTestbed, measure_steady, simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads


@dataclass
class FittedContext:
    hw: HardwareSpec
    profiles: Dict[str, WorkloadCoefficients]
    testbed: SimTestbed


@functools.lru_cache(maxsize=4)
def fitted_context(hw_name: str = "tpu-v5e") -> FittedContext:
    base = {"tpu-v5e": V5E, "tpu-v4": V4}[hw_name]
    mods = models()
    tb = SimTestbed(mods, base)
    hw = C.fit_hardware("qwen2-vl-7b", base, tb)
    tb = SimTestbed(mods, hw)
    profiles = {name: C.fit_workload(name, hw, tb) for name in mods}
    return FittedContext(hw=hw, profiles=profiles, testbed=tb)


def all_plans(ctx: Optional[FittedContext] = None, *,
              budget: str = "half") -> Dict[str, ProvisioningPlan]:
    """The paper's Sec. 5.1 strategy comparison (Figs. 15-19).

    Defaults to the paper-faithful ``budget="half"`` T_slo/2 split for
    every strategy so the reproduced cost/violation orderings match the
    paper; pass ``budget="queueing"`` to compare all strategies under
    the queueing-aware split (the provisioner-wide default elsewhere).
    """
    ctx = ctx or fitted_context()
    specs = twelve_workloads()
    mods = models()
    mfn = functools.partial(measure_steady, models=mods, hw=ctx.hw)
    return {
        "iGniter": prov.provision(specs, ctx.profiles, ctx.hw,
                                  budget=budget),
        "FFD+": B.provision_ffd(specs, ctx.profiles, ctx.hw, budget=budget),
        "FFD++": B.provision_ffd(specs, ctx.profiles, ctx.hw,
                                 use_alloc_gpus=True, budget=budget),
        "GSLICE+": B.provision_gslice(specs, ctx.profiles, ctx.hw, mfn,
                                      budget=budget),
        "gpu-lets+": B.provision_gpulets(specs, ctx.profiles, ctx.hw,
                                         budget=budget),
    }


def evaluate_plans(plans: Dict[str, ProvisioningPlan],
                   ctx: Optional[FittedContext] = None,
                   duration_s: float = 30.0):
    ctx = ctx or fitted_context()
    sb = specs_by_name()
    mods = models()
    out = {}
    for name, plan in plans.items():
        res = simulate_plan(plan, mods, ctx.hw, duration_s=duration_s,
                            shadow=(name == "iGniter"))
        out[name] = {
            "n_gpus": plan.n_gpus,
            "cost_per_hour": plan.cost_per_hour(),
            "violations": res.violations(sb),
            "result": res,
            "plan": plan,
        }
    return out
