"""iGniter GPU resource provisioning strategy (paper Sec. 4.1).

Implements Theorem 1 (appropriate batch size b_appr, Eq. 17; resource
lower bound r_lower, Eq. 18), Algorithm 2 (`alloc_gpus`) and Algorithm 1
(`provision`) faithfully, including the ANYFIT new-device rule and the
greedy minimum-interference device selection.

Two interchangeable engines drive the algorithms:

  * ``engine="vec"`` (default): the vectorized/batched performance model
    from `repro.core.perf_model_vec` — Alg. 2 scores ALL open devices in
    one call per placement with incrementally cached device invariants.
    This is the path that meets the paper's m=1000-in-seconds bound
    (Sec. 5.4); `benchmarks/scale_sweep.py` tracks it.
  * ``engine="scalar"``: the original pure-Python reference, kept as the
    cross-check oracle (`tests/test_perf_model_vec.py` asserts both
    engines emit identical plans).
"""
from __future__ import annotations

import math
import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import perf_model as pm
from repro.core import perf_model_vec as pmv
from repro.core import replication
from repro.core.queueing import BudgetLike, BudgetModel, QUEUEING, resolve
from repro.core.types import (HardwareSpec, K_MAX, Placement, PlannerConfig,
                              ProvisioningPlan, WorkloadCoefficients,
                              WorkloadSpec, planner_config)

R_MAX = 1.0
# Replica-count ceiling (`required_replicas`) — canonical home is
# `types.K_MAX`; re-exported here for backward compatibility.


class InfeasibleError(RuntimeError):
    """A workload cannot meet its SLO even alone on a full device.

    When raised by `provision_cheapest`, ``per_hw`` maps each hardware
    name to the error string of the workload that made that type
    infeasible — structured diagnostics instead of one joined string,
    so m=10k infeasibility reports stay actionable."""

    def __init__(self, message: str = "", *,
                 per_hw: Optional[Dict[str, str]] = None):
        super().__init__(message)
        self.per_hw: Dict[str, str] = dict(per_hw) if per_hw else {}


class DeviceCapError(InfeasibleError):
    """The ``max_devices`` fleet cap binds: the workload is physically
    feasible but placing it would open a device beyond the budget.

    Distinct from a Theorem-1 infeasibility — capacity exists in
    principle, the fleet just may not grow — so the controller's
    admission layer can react with shed / brownout / preemption instead
    of reporting a physics error.  Always carries ``per_hw``."""


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------

def appropriate_batch(spec: WorkloadSpec, c: WorkloadCoefficients,
                      hw: HardwareSpec, *, b_max: int = 64,
                      budget: BudgetLike = QUEUEING,
                      batch: str = "eq17") -> int:
    """Eq. (17): smallest batch sustaining the arrival rate within T_slo/2.

    R is req/s; the model works in ms, so R_ms = R / 1000.

    ``batch="eq17"`` (default): the paper's closed-form batch.  The
    batch choice is shared by both budget modes (the queueing-aware
    split reallocates T_slo between waiting and service AT this batch,
    which is what keeps its allocations never looser than the paper's
    half split).  Under ``budget="queueing"`` the batch is additionally
    shrunk — in practice a no-op safety net — while the solved inference
    budget at b is degenerate (<= 0), which can only happen when the
    accumulation tail (b-1)/R_ms eats the whole SLO.

    ``batch="joint"`` (opt-in, beyond-paper): re-optimize b JOINTLY with
    the bisection-solved budget — scan every stable candidate b (batch
    interval b/R_ms covering the solved inference budget B(b), i.e. the
    steady-state condition behind Eq. 17) and keep Eq. 17's b unless
    some candidate's Theorem-1 solo lower bound r_lower is STRICTLY
    smaller (tie-break: smaller batch, less accumulation wait).  Eq. 17
    maximizes b for the fixed half split; with a b-dependent budget a
    smaller batch can trade accumulation slack for service budget and
    shave whole r_units off the lower bound — never-worse by
    construction since Eq. 17's b stays in the candidate set.
    """
    r_ms = spec.rate_rps / 1000.0
    num = spec.slo_ms * r_ms * hw.pcie_bw
    den = 2.0 * (hw.pcie_bw + r_ms * c.d_load)
    b = int(math.ceil(num / den))
    b = max(1, min(b, b_max))
    bm = resolve(budget)
    if bm.mode != "half":
        while b > 1 and bm.budget_ms(spec.slo_ms, spec.rate_rps, b) <= 1e-6:
            b -= 1
    if batch == "eq17":
        return b
    if batch != "joint":
        raise ValueError(f"unknown batch mode {batch!r} "
                         "(expected 'eq17' or 'joint')")

    # One vectorized bisection solves every candidate's budget at once —
    # bitwise-identical to the scalar solver (see `budget_ms_vec`), so
    # the candidate ranking cannot drift from the scalar path.  The
    # controller re-runs this scan on every edit at ever-fresh estimated
    # rates, where 64 scalar bisections per probe dominated the edit
    # overhead.
    bs = np.arange(1, b_max + 1, dtype=np.float64)
    Bs = bm.budget_ms_vec(np.full(b_max, spec.slo_ms),
                          np.full(b_max, spec.rate_rps), bs)

    def _r_lower_at(bb: int) -> Optional[float]:
        B = float(Bs[bb - 1])
        if B <= 1e-6 or (r_ms > 0.0 and bb / r_ms < B - 1e-9):
            return None          # degenerate budget / unstable at B
        try:
            return resource_lower_bound(spec, c, hw, bb, budget=bm,
                                        solved_budget_ms=B)
        except InfeasibleError:
            return None
    best_b, best_r = b, _r_lower_at(b)
    for bb in range(1, b_max + 1):   # ascending: ties keep the smaller b
        if bb == b:
            continue
        r = _r_lower_at(bb)
        if r is None:
            continue
        if best_r is None or r < best_r - 1e-12:
            best_b, best_r = bb, r
        elif (r >= R_MAX - 1e-12 and best_r >= R_MAX - 1e-12
              and bb > best_b):
            # every candidate clamps to a full device: the budget is out
            # of reach either way, so take the batch with the most
            # throughput (largest b) to minimize the rate shortfall
            best_b = bb
    # best_r None: no candidate is feasible — return Eq. 17's b so the
    # caller raises/clamps exactly as it would without joint mode
    return best_b


def resource_lower_bound(spec: WorkloadSpec, c: WorkloadCoefficients,
                         hw: HardwareSpec, b_appr: Optional[int] = None, *,
                         budget: BudgetLike = QUEUEING,
                         solved_budget_ms: Optional[float] = None) -> float:
    """Eq. (18): minimal solo resource fraction meeting the inference
    budget (T_slo/2 under ``budget="half"``, the queueing-aware split
    otherwise).

    Under the queueing budget, a workload whose TIGHTENED budget is out
    of reach even on a full device is clamped to R_MAX (the honest
    residual then surfaces in `predicted_violations`, mirroring the
    `self_grant` fallback); a workload infeasible even at the paper's
    half split still raises InfeasibleError in both modes.

    ``solved_budget_ms`` lets a caller that already solved the budget at
    ``b_appr`` (e.g. the joint-batch scan's vectorized bisection) skip
    re-solving it; it must equal ``budget.budget_ms(slo, rate, b_appr)``
    bit-for-bit.
    """
    bm = resolve(budget)
    b = b_appr if b_appr is not None else appropriate_batch(spec, c, hw,
                                                            budget=bm)
    gamma = c.k1 * b * b + c.k2 * b + c.k3

    def _r_lower(budget_ms: float) -> float:
        delta = (budget_ms
                 - (c.d_load + c.d_feedback) * b / hw.pcie_bw
                 - c.k5 - c.k_sch * c.n_kernels)
        if delta <= 0:
            raise InfeasibleError(
                f"{spec.name}: fixed latency terms exceed the "
                f"{budget_ms:.3f} ms inference budget "
                f"(delta={delta:.3f} ms)")
        r = gamma / delta - c.k4
        r_units = math.ceil(r / hw.r_unit - 1e-9)
        r_lower = max(hw.r_unit, r_units * hw.r_unit)
        if r_lower > R_MAX + 1e-9:
            raise InfeasibleError(
                f"{spec.name}: needs r={r_lower:.3f} > 100% of a device")
        return min(r_lower, R_MAX)

    try:
        return _r_lower(solved_budget_ms if solved_budget_ms is not None
                        else bm.budget_ms(spec.slo_ms, spec.rate_rps, b))
    except InfeasibleError:
        if bm.mode == "half":
            raise
        _r_lower(spec.slo_ms / 2.0)    # raises if infeasible even at T/2
        return R_MAX


# ---------------------------------------------------------------------------
# Device state during provisioning
# ---------------------------------------------------------------------------

@dataclass
class _Dev:
    """Mutable allocation state for one device."""
    entries: List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]] = \
        field(default_factory=list)   # (spec, coeffs, batch, r)

    def total(self) -> float:
        return sum(e[3] for e in self.entries)

    def placed(self) -> List[pm.PlacedWorkload]:
        return [pm.PlacedWorkload(coeffs=c, batch=b, r=r)
                for (_, c, b, r) in self.entries]


# ---------------------------------------------------------------------------
# Algorithm 2: alloc_gpus
# ---------------------------------------------------------------------------

def alloc_gpus(dev: _Dev, w_spec: WorkloadSpec, w_coeffs: WorkloadCoefficients,
               w_batch: int, w_r_lower: float,
               hw: HardwareSpec, *,
               budget: BudgetLike = QUEUEING) -> Optional[List[float]]:
    """Try placing workload w on `dev`; returns the new allocation vector
    r_a (existing entries order, w last), or None if the device cannot host
    it within r_max.

    Faithful to Alg. 2: start w at its lower bound, then iteratively grant
    +r_unit to any workload whose predicted t_inf exceeds its inference
    budget (T_slo/2 under ``budget="half"``, the queueing-aware split
    otherwise), until stable or out of resources.
    """
    bm = resolve(budget)
    specs = [e[0] for e in dev.entries] + [w_spec]
    coeffs = [e[1] for e in dev.entries] + [w_coeffs]
    batches = [e[2] for e in dev.entries] + [w_batch]
    r_a = [e[3] for e in dev.entries] + [w_r_lower]
    budgets = [bm.budget_ms(s.slo_ms, s.rate_rps, b)
               for s, b in zip(specs, batches)]

    flag = True
    while sum(r_a) <= R_MAX + 1e-9 and flag:
        flag = False
        placed = [pm.PlacedWorkload(coeffs=c, batch=b, r=r)
                  for c, b, r in zip(coeffs, batches, r_a)]
        pred = pm.predict_device(placed, hw)
        for i, spec in enumerate(specs):
            if pred.per_workload[i].t_inf > budgets[i] + 1e-9:
                r_a[i] = round(r_a[i] + hw.r_unit, 10)
                flag = True
    if sum(r_a) > R_MAX + 1e-9:
        return None
    return r_a


def self_grant(spec: WorkloadSpec, coeffs: WorkloadCoefficients,
               batch: int, r_lower: float, hw: HardwareSpec, *,
               budget: BudgetLike = QUEUEING) -> float:
    """Alg. 2 run for a workload opening a FRESH device (beyond-paper fix,
    see ROADMAP): Theorem 1's Eq. (18) drops the f/F throttling factor,
    so a solo anchor at r_lower can exceed its budget once its power
    demand crosses the cap.  Grant +r_unit until the model predicts
    t_inf within the inference budget — exactly what `alloc_gpus`
    already does for the FIRST workload (devs[0] starts empty), now
    applied to line-14 devices too.  Falls back to the full device when
    even r=1 cannot meet the budget (the residual is then reported
    honestly by `predicted_violations`).
    """
    r_a = alloc_gpus(_Dev(), spec, coeffs, batch, r_lower, hw, budget=budget)
    return r_a[-1] if r_a is not None else R_MAX


# ---------------------------------------------------------------------------
# Replica groups (beyond-paper, docs/provisioning.md): a workload whose
# inference budget is out of reach even SOLO on a full device is split
# into k replicas, each serving a 1/k rate share — instead of clamping
# to r = 1.0 and reporting a guaranteed violation.
# ---------------------------------------------------------------------------

def solo_feasible(spec: WorkloadSpec, coeffs: WorkloadCoefficients,
                  hw: HardwareSpec, *, budget: BudgetLike = QUEUEING,
                  batch: str = "eq17") -> bool:
    """Can the workload meet its inference budget alone on one device,
    INCLUDING the power-throttling effect Theorem 1 drops (the same
    check `self_grant` applies to fresh devices)?"""
    bm = resolve(budget)
    try:
        b = appropriate_batch(spec, coeffs, hw, budget=bm, batch=batch)
        rl = resource_lower_bound(spec, coeffs, hw, b, budget=bm)
    except InfeasibleError:
        return False
    # rl alone is not decisive: R_MAX may be the tightened-budget clamp,
    # and even rl < R_MAX can throttle-fail once the power cap binds.
    # Run Alg. 2 on an empty device — the authoritative check.
    return alloc_gpus(_Dev(), spec, coeffs, b, rl, hw, budget=bm) is not None


def required_replicas(spec: WorkloadSpec, coeffs: WorkloadCoefficients,
                      hw: HardwareSpec, *, budget: BudgetLike = QUEUEING,
                      batch: str = "eq17",
                      k_max: int = K_MAX) -> Optional[int]:
    """Smallest k such that a 1/k-rate replica of ``spec`` is solo-
    feasible (`solo_feasible`); None when NO k <= k_max suffices.  The
    None is deliberate — "feasible as one instance" (1) and "hopeless
    at any split" must stay distinguishable, or a controller would
    merge a working replica group down to one guaranteed-violating
    instance.  Callers keep hopeless workloads at their CURRENT replica
    count (an honest residual) instead of shattering them into k_max
    equally-impossible slivers."""
    for k in range(1, k_max + 1):
        probe = spec if k == 1 else replication.make_replicas(spec, k)[0]
        if solo_feasible(probe, coeffs, hw, budget=budget, batch=batch):
            return k
    return None


# ---------------------------------------------------------------------------
# Theorem-1 probe cache (online control plane): one reconcile pass probes
# the same (spec, budget) pair 3-4 times — required_replicas, _validate,
# then the PlanState edit itself — and a k-replica scale-out probes every
# k' < k again on the next drift.  All probe inputs are frozen/hashable
# (WorkloadCoefficients, BudgetModel, the batch-mode string), so exact-
# key memoization is safe; `BudgetModel.with_burstiness` copies hash by
# VALUE, so an unchanged burstiness floor keeps the cache warm across
# reconcile rounds.
# ---------------------------------------------------------------------------

_INFEASIBLE = object()          # cached-InfeasibleError sentinel


class ProbeCache:
    """Memoizes `appropriate_batch` + `resource_lower_bound` (Theorem 1),
    `solo_feasible` and `required_replicas` across plan edits.

    Keyed by (coeffs, hw name, budget model, batch mode, slo, rate) —
    everything the probes actually read.  InfeasibleError outcomes are
    cached as a sentinel and re-raised fresh with the current spec name.
    ``hits`` / ``misses`` are exposed for the dynamic-sweep benchmark
    rows."""

    def __init__(self) -> None:
        self._t1: Dict[tuple, object] = {}
        self._solo: Dict[tuple, bool] = {}
        self._reps: Dict[tuple, Optional[int]] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _key(spec: WorkloadSpec, c: WorkloadCoefficients, hw: HardwareSpec,
             bm: BudgetModel, batch: str) -> tuple:
        return (c, hw.name, bm, batch, spec.slo_ms, spec.rate_rps)

    def theorem1(self, spec: WorkloadSpec, c: WorkloadCoefficients,
                 hw: HardwareSpec, bm: BudgetModel,
                 batch: str) -> Tuple[int, float]:
        """Cached (b_appr, r_lower); raises InfeasibleError like the
        underlying probes (also when the miss was cached)."""
        key = self._key(spec, c, hw, bm, batch)
        val = self._t1.get(key)
        if val is not None:
            self.hits += 1
            if val is _INFEASIBLE:
                raise InfeasibleError(
                    f"{spec.name}: infeasible (cached Theorem-1 probe)")
            return val          # type: ignore[return-value]
        self.misses += 1
        try:
            b = appropriate_batch(spec, c, hw, budget=bm, batch=batch)
            rl = resource_lower_bound(spec, c, hw, b, budget=bm)
        except InfeasibleError:
            self._t1[key] = _INFEASIBLE
            raise
        self._t1[key] = (b, rl)
        return b, rl

    def solo_feasible(self, spec: WorkloadSpec, c: WorkloadCoefficients,
                      hw: HardwareSpec, bm: BudgetModel, batch: str) -> bool:
        key = self._key(spec, c, hw, bm, batch)
        val = self._solo.get(key)
        if val is not None:
            self.hits += 1
            return val
        self.misses += 1
        val = solo_feasible(spec, c, hw, budget=bm, batch=batch)
        self._solo[key] = val
        return val

    def required_replicas(self, spec: WorkloadSpec, c: WorkloadCoefficients,
                          hw: HardwareSpec, bm: BudgetModel, batch: str,
                          k_max: int = K_MAX) -> Optional[int]:
        key = self._key(spec, c, hw, bm, batch) + (k_max,)
        if key in self._reps:
            self.hits += 1
            return self._reps[key]
        # per-k solo probes go through the solo cache, so a k-replica
        # answer also warms every k' <= k probe for later edits
        for k in range(1, k_max + 1):
            probe = spec if k == 1 else replication.make_replicas(spec, k)[0]
            if self.solo_feasible(probe, c, hw, bm, batch):
                self._reps[key] = k
                return k
        self._reps[key] = None
        return None


# ---------------------------------------------------------------------------
# Algorithm 1: iGniter provisioning
# ---------------------------------------------------------------------------

def _prepare(specs: Sequence[WorkloadSpec],
             profiles: Dict[str, WorkloadCoefficients],
             hw: HardwareSpec, *, budget: BudgetLike = QUEUEING,
             batch: str = "eq17", replicate: bool = False,
             k_max: int = K_MAX
             ) -> List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]]:
    """Alg. 1 lines 2-3: (b_appr, r_lower) per workload, sorted by
    r_lower descending.  With ``replicate`` a workload that cannot meet
    its budget even solo on a full device is expanded into
    `required_replicas` equal-share replicas (``w#0..w#k-1``), each
    prepared like an ordinary workload at its share rate; stable
    sorting keeps a group's replicas in index order."""
    bm = resolve(budget)
    prepared = []
    for s in specs:
        c = profiles[s.model]
        reps = [s]
        if replicate and not replication.is_replica(s.name):
            k = required_replicas(s, c, hw, budget=bm, batch=batch,
                                  k_max=k_max)
            reps = replication.make_replicas(s, k or 1)
        for rs in reps:
            b = appropriate_batch(rs, c, hw, budget=bm, batch=batch)
            rl = resource_lower_bound(rs, c, hw, b, budget=bm)
            prepared.append((rs, c, b, rl))
    prepared.sort(key=lambda t: -t[3])
    return prepared


def _check_device_cap(used: int, max_devices: Optional[int], name: str,
                      hw: HardwareSpec) -> None:
    """Raise `DeviceCapError` when opening one more device would exceed
    ``max_devices`` (None = uncapped, the historical behavior)."""
    if max_devices is not None and used >= max_devices:
        msg = (f"{name}: device cap {max_devices} reached on {hw.name} "
               f"({used} devices in use); fleet may not grow")
        raise DeviceCapError(msg, per_hw={hw.name: msg})


def provision(specs: Sequence[WorkloadSpec],
              profiles: Dict[str, WorkloadCoefficients],
              hw: HardwareSpec, *,
              config: Optional[PlannerConfig] = None,
              max_devices: Optional[int] = None,
              engine: Optional[str] = None,
              budget: Optional[BudgetLike] = None,
              batch: Optional[str] = None, replicate: Optional[bool] = None,
              k_max: Optional[int] = None) -> ProvisioningPlan:
    """Cost-efficient interference-aware provisioning (Alg. 1).

    All knobs live on ``config`` (a `types.PlannerConfig`); the
    per-knob keywords are deprecated shims (mixing them with
    ``config=`` is a TypeError).  Defaults: vectorized engine, numpy
    backend, queueing-aware budget, Eq.-17 batch, no replication.

    ``engine="vec"`` scores all open devices through the batched model in
    one call per placement (``backend="jax"`` runs that scoring loop as
    the jitted `perf_model_jax.alloc_all_jax`); ``engine="scalar"`` is
    the reference per-device loop (identical output, kept as the oracle).

    ``budget`` selects the SLO split handed to Theorem 1 / Alg. 2:
    ``"queueing"`` (default) budgets a tail queueing-delay term per
    workload; ``"half"`` is the paper-faithful fixed T_slo/2 split.

    ``batch`` selects Theorem 1's batch size: ``"eq17"`` (default,
    paper-faithful) or ``"joint"`` (re-optimized jointly with the
    solved budget split — see `appropriate_batch`).

    ``replicate`` (beyond-paper, opt-in) splits any workload that is
    infeasible even SOLO on a full device into `required_replicas`
    equal-rate-share replicas (``w#0..w#k-1``, capped at ``k_max``)
    instead of clamping it to r = 1.0; a plan that never splits is
    bit-identical to ``replicate=False`` output.

    ``max_devices`` caps the fleet: the line-14 fresh-device rule raises
    `DeviceCapError` (with ``per_hw``) instead of silently opening a
    device beyond the cap.  ``None`` (default) keeps the paper's
    uncapped behavior bit-for-bit; a slack cap changes nothing.
    """
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch,
                         replicate=replicate, k_max=k_max)
    bm = resolve(cfg.budget)
    if cfg.engine == "vec":
        return _provision_vec(specs, profiles, hw, cfg,
                              max_devices=max_devices)
    prepared = _prepare(specs, profiles, hw, budget=bm, batch=cfg.batch,
                        replicate=cfg.replicate, k_max=cfg.k_max)

    devs: List[_Dev] = [_Dev()]
    for (s, c, b, rl) in prepared:
        best_q = -1
        best_alloc: Optional[List[float]] = None
        best_inter = R_MAX + 1.0     # r_inter^min
        for q, dev in enumerate(devs):
            r_a = alloc_gpus(dev, s, c, b, rl, hw, budget=bm)
            if r_a is None:
                continue
            # increased resources caused by interference (line 8)
            old = [e[3] for e in dev.entries] + [rl]
            r_inter = sum(max(0.0, na - oa) for na, oa in zip(r_a, old))
            if r_inter < best_inter - 1e-12:
                best_inter = r_inter
                best_q = q
                best_alloc = r_a
        if best_q == -1:
            _check_device_cap(sum(1 for d in devs if d.entries),
                              max_devices, s.name, hw)
            devs.append(_Dev(                              # line 14
                entries=[(s, c, b, self_grant(s, c, b, rl, hw, budget=bm))]))
        else:
            dev = devs[best_q]
            new_entries = []
            for (e, r_new) in zip(dev.entries, best_alloc[:-1]):
                new_entries.append((e[0], e[1], e[2], r_new))
            new_entries.append((s, c, b, best_alloc[-1]))
            dev.entries = new_entries

    plan = ProvisioningPlan(hardware=hw)
    for g, dev in enumerate(devs):
        for (s, c, b, r) in dev.entries:
            plan.placements.append(Placement(workload=s, gpu=g, r=r, batch=b))
    plan.n_gpus = sum(1 for d in devs if d.entries)
    if cfg.replicate:
        _rebalance_replica_shares(plan, profiles, hw)
    return plan


def _argmin_inter(r_inter: "np.ndarray") -> int:
    """Alg. 1 line 8 fold: earliest device whose score is more than 1e-12
    below every earlier candidate (replicates the scalar `<` fold)."""
    best_q, best = -1, R_MAX + 1.0
    for q, ri in enumerate(r_inter):
        if ri < best - 1e-12:
            best_q, best = q, float(ri)
    return best_q


def _provision_vec(specs: Sequence[WorkloadSpec],
                   profiles: Dict[str, WorkloadCoefficients],
                   hw: HardwareSpec,
                   cfg: PlannerConfig, *,
                   max_devices: Optional[int] = None) -> ProvisioningPlan:
    """Alg. 1 over the batched model: one `VecCluster.alloc_all` call
    scores every open device per placement, and the chosen device's
    invariants are refreshed incrementally."""
    bm = resolve(cfg.budget)
    prepared = _prepare(specs, profiles, hw, budget=bm, batch=cfg.batch,
                        replicate=cfg.replicate, k_max=cfg.k_max)

    cl = pmv.VecCluster(hw, budget=bm, backend=cfg.backend)
    cl.add_device()
    for (s, c, b, rl) in prepared:
        feasible, rr, rn, r_inter = cl.alloc_all(s, c, b, rl)
        best_q = _argmin_inter(r_inter) if feasible.any() else -1
        if best_q == -1:
            _check_device_cap(sum(1 for g in range(cl.d) if cl.entries[g]),
                              max_devices, s.name, hw)
            q = cl.add_device()                                  # line 14
            cl.add_entry(q, s, c, b, self_grant(s, c, b, rl, hw, budget=bm))
        else:
            cl.set_row_r(best_q, rr[best_q])
            cl.add_entry(best_q, s, c, b, float(rn[best_q]))

    plan = ProvisioningPlan(hardware=hw)
    for g in range(cl.d):
        for i, (s, c, b) in enumerate(cl.entries[g]):
            plan.placements.append(
                Placement(workload=s, gpu=g, r=float(cl.r[g, i]), batch=b))
    plan.n_gpus = sum(1 for g in range(cl.d) if cl.entries[g])
    if cfg.replicate:
        _rebalance_replica_shares(plan, profiles, hw)
    return plan


def _rebalance_replica_shares(plan: ProvisioningPlan,
                              profiles: Dict[str, WorkloadCoefficients],
                              hw: HardwareSpec) -> None:
    """Re-split each replica group's total rate proportionally to the
    predicted serving capacity of its placements (``batch / t_inf`` at
    the GRANTED allocation, co-location included), in place.

    `make_replicas`' equal split models identical homes; Alg. 1 places
    replicas greedily, so later replicas routinely land on busier
    devices where the same r buys a slower pass — the slow replica then
    sets the group's pooled p99.  Capacity-proportional shares route
    traffic toward the replicas with real headroom.  Groups whose
    capacities are bitwise equal (k = 1 trivially, and identical-
    composition homes) are left untouched, keeping those plans
    bit-identical to the equal-split output.
    """
    groups = {b: g for b, g
              in replication.group_placements(plan.placements).items()
              if len(g) > 1}
    if not groups:
        return
    metrics = predicted_plan_metrics(plan, profiles, hw)
    for base in sorted(groups):
        group = groups[base]
        caps = [1000.0 * p.batch / metrics[p.workload.name].t_inf
                for p in group]
        shares = replication.proportional_shares(
            replication.group_rate([p.workload for p in group]), caps)
        if shares is None:
            continue
        for p, share in zip(group, shares):
            p.workload = dataclasses.replace(p.workload, rate_rps=share)


# ---------------------------------------------------------------------------
# Online arrival (paper Sec. 4.2: iGniter is "periodically executed to
# provision GPU resources for newly-arrived inference workloads").
# Unlike gpu-lets, Alg. 2 may grow the allocations of ORIGINALLY-PLACED
# workloads on the chosen device to absorb the newcomer's interference.
# ---------------------------------------------------------------------------

def add_workload(plan: ProvisioningPlan, spec: WorkloadSpec,
                 profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec, *,
                 config: Optional[PlannerConfig] = None,
                 engine: Optional[str] = None,
                 budget: Optional[BudgetLike] = None,
                 batch: Optional[str] = None,
                 exclude_gpus: Optional[frozenset] = None,
                 pin: Optional[Tuple[int, float]] = None,
                 max_devices: Optional[int] = None,
                 reserved: Optional[Dict[int, float]] = None,
                 telemetry=None) -> ProvisioningPlan:
    """Place one newly-arrived workload into an existing plan (in place of
    a full re-run of Alg. 1): greedy minimum-interference device selection
    with Alg. 2 reallocation, or a fresh device.  The vec engine scores
    every existing device in a single `alloc_all` call.

    ``exclude_gpus`` removes devices from candidacy (the controller's
    health layer quarantines failed/straggling devices); the fresh-
    device fallback still applies, so placement never lands on an
    excluded device.

    ``pin`` is an explicit ``(batch, r_floor)`` that REPLACES the
    Theorem 1 derivation — the health layer's capacity-preserving
    migration: a moved placement keeps the batch and at least the
    resource grant it was provisioned with, rather than whatever the
    controller's drifted budget would re-derive.

    ``max_devices`` caps the fleet like `provision`'s: the fresh-device
    fallback raises `DeviceCapError` (with ``per_hw``) instead of
    growing past the cap.  Every `InfeasibleError` raised here carries
    ``per_hw`` diagnostics, so overload decisions and sweep logs can
    report WHY a grant failed.

    ``reserved`` maps plan gpu id -> armed Sec. 4.2 shadow reservation
    on that device (the controller's predictive tier): a candidate
    whose re-solved residents + newcomer would eat into the reservation
    (total past r = 1.0) is treated as infeasible, so a later shadow
    activation can never overcommit the device.  Reservations
    attributable to the edited workload itself must be excluded by the
    caller.  The fresh-device fallback is naturally reservation-free.

    ``telemetry`` (duck-typed `repro.serving.telemetry.Telemetry`, kept
    untyped to avoid a core->serving import) counts the op under
    ``prov_add`` — every edit op takes the same keyword."""
    if telemetry is not None:
        telemetry.count("prov_add")
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch)
    bm = resolve(cfg.budget)
    c = profiles[spec.model]
    if pin is not None:
        b, rl = int(pin[0]), float(pin[1])
    else:
        try:
            b = appropriate_batch(spec, c, hw, budget=bm, batch=cfg.batch)
            rl = resource_lower_bound(spec, c, hw, b, budget=bm)
        except InfeasibleError as e:
            if not e.per_hw:
                e.per_hw = {hw.name: str(e)}
            raise

    devs: Dict[int, _Dev] = {}
    for p in plan.placements:
        devs.setdefault(p.gpu, _Dev()).entries.append(
            (p.workload, profiles[p.workload.model], p.batch, p.r))
    cand = devs if not exclude_gpus else \
        {g: d for g, d in devs.items() if g not in exclude_gpus}

    best_q, best_alloc, best_inter = -1, None, R_MAX + 1.0
    if cfg.engine == "vec":
        cl = pmv.VecCluster(hw, budget=bm, backend=cfg.backend)
        gpu_ids = sorted(cand)
        for g in gpu_ids:
            q = cl.add_device()
            for (s, cc, bb, r) in cand[g].entries:
                cl.add_entry(q, s, cc, bb, r)
        if gpu_ids:
            feasible, rr, rn, r_inter = cl.alloc_all(spec, c, b, rl)
            if reserved:
                resv = np.array([reserved.get(g, 0.0) for g in gpu_ids])
                if resv.any():
                    load = (rr * cl.mask[:cl.d]).sum(axis=1) + rn + resv
                    over = load > 1.0 + 1e-9
                    feasible = feasible & ~over
                    r_inter = np.where(over, np.inf, r_inter)
            row = _argmin_inter(r_inter) if feasible.any() else -1
            if row != -1:
                best_q = gpu_ids[row]
                k = int(cl.n[row])
                best_alloc = [float(x) for x in rr[row, :k]] + [float(rn[row])]
    else:
        for q, dev in sorted(cand.items()):
            r_a = alloc_gpus(dev, spec, c, b, rl, hw, budget=bm)
            if r_a is None:
                continue
            if reserved and (math.fsum(r_a) + reserved.get(q, 0.0)
                             > 1.0 + 1e-9):
                continue
            old = [e[3] for e in dev.entries] + [rl]
            r_inter = sum(max(0.0, na - oa) for na, oa in zip(r_a, old))
            if r_inter < best_inter - 1e-12:
                best_q, best_alloc, best_inter = q, r_a, r_inter

    new_plan = ProvisioningPlan(hardware=plan.hardware or hw)
    if best_q == -1:
        _check_device_cap(len(devs), max_devices, spec.name, hw)
        g_new = (max(devs) + 1) if devs else 0
        new_plan.placements = list(plan.placements) + [
            Placement(workload=spec, gpu=g_new,
                      r=self_grant(spec, c, b, rl, hw, budget=bm), batch=b)]
    else:
        for p in plan.placements:
            if p.gpu != best_q:
                new_plan.placements.append(p)
        dev = devs[best_q]
        for (s, _, bb, _), r_new in zip(dev.entries, best_alloc[:-1]):
            new_plan.placements.append(
                Placement(workload=s, gpu=best_q, r=r_new, batch=bb))
        new_plan.placements.append(
            Placement(workload=spec, gpu=best_q, r=best_alloc[-1], batch=b))
    new_plan.n_gpus = len({p.gpu for p in new_plan.placements})
    return new_plan


# ---------------------------------------------------------------------------
# Incremental plan edits (online control plane, paper Sec. 4.2/4.4):
# resize / remove / migrate one workload of an existing plan without a
# full Alg. 1 re-run.  Each edit touches only the devices involved —
# the same-device resize re-runs Alg. 2 on ONE device, the migrate path
# scores every device in a single vectorized `alloc_all` call — and each
# has a scalar-oracle twin pinned by tests.
# ---------------------------------------------------------------------------

def remove_workload(plan: ProvisioningPlan, name: str, *,
                    telemetry=None) -> ProvisioningPlan:
    """Drop one workload's placement (departure).  Remaining residents
    keep their Alg. 2 grants — with less interference on the device they
    can only get faster, so the plan stays feasible; reclaiming the
    slack is the next resize's job."""
    if telemetry is not None:
        telemetry.count("prov_remove")
    new_plan = ProvisioningPlan(hardware=plan.hardware)
    new_plan.placements = [p for p in plan.placements
                           if p.workload.name != name]
    if len(new_plan.placements) == len(plan.placements):
        raise KeyError(f"workload {name!r} not in plan")
    new_plan.n_gpus = len({p.gpu for p in new_plan.placements})
    return new_plan


def resize_workload(plan: ProvisioningPlan, spec: WorkloadSpec,
                    profiles: Dict[str, WorkloadCoefficients],
                    hw: HardwareSpec, *,
                    config: Optional[PlannerConfig] = None,
                    engine: Optional[str] = None,
                    budget: Optional[BudgetLike] = None,
                    batch: Optional[str] = None,
                    max_devices: Optional[int] = None,
                    reserved: Optional[Dict[int, float]] = None,
                    telemetry=None) -> ProvisioningPlan:
    """Re-place one workload under a NEW spec (arrival-rate / SLO drift):
    recompute Theorem 1 at the new rate, re-run Alg. 2 on its CURRENT
    device (the O(1-device) fast path — covers both growth, absorbing
    more interference, and shrink, releasing slack), and fall back to
    `migrate_workload` when the current device can no longer host it.
    Raised `InfeasibleError`s carry ``per_hw`` diagnostics; the migrate
    fallback honors ``max_devices``.  ``reserved`` holds armed shadow
    reservations out of the re-solve, `add_workload`-style: a same-
    device result that would eat into one falls through to migration."""
    if telemetry is not None:
        telemetry.count("prov_resize")
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch)
    bm = resolve(cfg.budget)
    c = profiles[spec.model]
    try:
        b = appropriate_batch(spec, c, hw, budget=bm, batch=cfg.batch)
        rl = resource_lower_bound(spec, c, hw, b, budget=bm)
    except InfeasibleError as e:
        if not e.per_hw:
            e.per_hw = {hw.name: str(e)}
        raise

    cur = next((p for p in plan.placements if p.workload.name == spec.name),
               None)
    if cur is None:
        raise KeyError(f"workload {spec.name!r} not in plan")
    peers = [p for p in plan.placements
             if p.gpu == cur.gpu and p.workload.name != spec.name]
    residents = [(p.workload, profiles[p.workload.model], p.batch, p.r)
                 for p in peers]
    if cfg.engine == "vec":
        r_a = pmv.alloc_gpus_vec(residents, spec, c, b, rl, hw, budget=bm,
                                 backend=cfg.backend)
    else:
        r_a = alloc_gpus(_Dev(entries=residents), spec, c, b, rl, hw,
                         budget=bm)
    if (r_a is not None and reserved
            and (math.fsum(float(x) for x in r_a)
                 + reserved.get(cur.gpu, 0.0) > 1.0 + 1e-9)):
        r_a = None                 # the reservation holds: migrate
    if r_a is None:
        return migrate_workload(plan, spec, profiles, hw,
                                config=cfg.replace(budget=bm),
                                max_devices=max_devices,
                                reserved=reserved)

    peer_r = dict(zip((p.workload.name for p in peers), r_a[:-1]))
    new_plan = ProvisioningPlan(hardware=plan.hardware)
    for p in plan.placements:              # placement order preserved
        if p.workload.name == spec.name:
            new_plan.placements.append(Placement(
                workload=spec, gpu=cur.gpu, r=float(r_a[-1]), batch=b))
        elif p.gpu == cur.gpu:
            new_plan.placements.append(Placement(
                workload=p.workload, gpu=p.gpu,
                r=float(peer_r[p.workload.name]), batch=p.batch))
        else:
            new_plan.placements.append(p)
    new_plan.n_gpus = len({p.gpu for p in new_plan.placements})
    return new_plan


def migrate_workload(plan: ProvisioningPlan, spec: WorkloadSpec,
                     profiles: Dict[str, WorkloadCoefficients],
                     hw: HardwareSpec, *,
                     config: Optional[PlannerConfig] = None,
                     engine: Optional[str] = None,
                     budget: Optional[BudgetLike] = None,
                     batch: Optional[str] = None,
                     exclude_gpus: Optional[frozenset] = None,
                     max_devices: Optional[int] = None,
                     reserved: Optional[Dict[int, float]] = None,
                     telemetry=None) -> ProvisioningPlan:
    """Move one workload to the minimum-interference device that can
    host its (possibly updated) spec — remove + `add_workload`, so the
    destination can also be a fresh device (`self_grant`).
    ``exclude_gpus`` bans devices (health-layer quarantine);
    ``max_devices`` caps the fresh-device fallback; ``reserved`` holds
    armed shadow reservations out of candidacy.  ``telemetry`` counts
    ONE ``prov_migrate`` (the inner remove/add are not
    double-counted)."""
    if telemetry is not None:
        telemetry.count("prov_migrate")
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch)
    return add_workload(remove_workload(plan, spec.name), spec, profiles,
                        hw, config=cfg, exclude_gpus=exclude_gpus,
                        max_devices=max_devices, reserved=reserved)


# ---------------------------------------------------------------------------
# Replica-group plan edits (scale-out / scale-in): re-place one workload
# as k equal-rate-share replicas.  Shares always renormalize to the base
# spec's rate — merging 3 replicas to 2 leaves each survivor at rate/2.
# ---------------------------------------------------------------------------

def _set_replicas(plan: ProvisioningPlan, spec: WorkloadSpec, k: int,
                  profiles: Dict[str, WorkloadCoefficients],
                  hw: HardwareSpec,
                  cfg: PlannerConfig,
                  max_devices: Optional[int] = None) -> ProvisioningPlan:
    """Remove every current replica of ``spec`` (a BASE spec: plain name,
    full workload rate), then `add_workload` each of the k new replicas
    at its rate share — min-interference placement incl. fresh devices
    (capped by ``max_devices``; the input plan is never mutated, so a
    mid-edit `DeviceCapError` leaves it intact)."""
    base = spec.name
    if replication.is_replica(base):
        raise ValueError(f"pass the BASE spec, not replica {base!r}")
    cur = replication.group_placements(plan.placements).get(base)
    if not cur:
        raise KeyError(f"workload {base!r} not in plan")
    out = plan
    for p in cur:
        out = remove_workload(out, p.workload.name)
    for rs in replication.make_replicas(spec, k):
        out = add_workload(out, rs, profiles, hw, config=cfg,
                           max_devices=max_devices)
    return out


def split_workload(plan: ProvisioningPlan, spec: WorkloadSpec, k: int,
                   profiles: Dict[str, WorkloadCoefficients],
                   hw: HardwareSpec, *,
                   config: Optional[PlannerConfig] = None,
                   engine: Optional[str] = None,
                   budget: Optional[BudgetLike] = None,
                   batch: Optional[str] = None,
                   max_devices: Optional[int] = None,
                   telemetry=None) -> ProvisioningPlan:
    """Scale-OUT edit: serve ``spec`` (base name, full rate) with k
    replicas, k strictly above the current count.  Each replica gets an
    equal rate share (summing to ``spec.rate_rps``), its own Theorem-1
    batch/budget at the share rate, and a min-interference placement."""
    if telemetry is not None:
        telemetry.count("prov_split")
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch)
    k_cur = len(replication.group_placements(plan.placements)
                .get(spec.name, ()))
    if k <= k_cur:
        raise ValueError(f"{spec.name!r} already has {k_cur} replicas; "
                         f"split needs k > {k_cur}, got {k}")
    return _set_replicas(plan, spec, k, profiles, hw, cfg, max_devices)


def merge_workload(plan: ProvisioningPlan, spec: WorkloadSpec, k: int,
                   profiles: Dict[str, WorkloadCoefficients],
                   hw: HardwareSpec, *,
                   config: Optional[PlannerConfig] = None,
                   engine: Optional[str] = None,
                   budget: Optional[BudgetLike] = None,
                   batch: Optional[str] = None,
                   max_devices: Optional[int] = None,
                   telemetry=None) -> ProvisioningPlan:
    """Scale-IN edit: drop to k replicas (k below the current count).
    Survivor shares renormalize to ``spec.rate_rps`` — the merged rate
    is re-split equally, never silently lost; ``k = 1`` returns the
    workload to its plain (unreplicated) name."""
    if telemetry is not None:
        telemetry.count("prov_merge")
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch)
    k_cur = len(replication.group_placements(plan.placements)
                .get(spec.name, ()))
    if not 1 <= k < k_cur:
        raise ValueError(f"{spec.name!r} has {k_cur} replicas; "
                         f"merge needs 1 <= k < {k_cur}, got {k}")
    return _set_replicas(plan, spec, k, profiles, hw, cfg, max_devices)


# ---------------------------------------------------------------------------
# Heterogeneous type selection (paper Sec. 5.3, Fig. 20)
# ---------------------------------------------------------------------------

def provision_cheapest(specs: Sequence[WorkloadSpec],
                       profiles_by_hw: Dict[str, Dict[str, WorkloadCoefficients]],
                       hardware: Sequence[HardwareSpec], *,
                       config: Optional[PlannerConfig] = None,
                       max_devices=None,
                       engine: Optional[str] = None,
                       budget: Optional[BudgetLike] = None,
                       batch: Optional[str] = None,
                       replicate: Optional[bool] = None,
                       k_max: Optional[int] = None
                       ) -> Tuple[ProvisioningPlan, HardwareSpec]:
    """Run Alg. 1 per hardware type and pick the cheapest feasible plan.

    ``max_devices`` caps each candidate fleet: an int applies the same
    total cap to every hardware type; a ``{hw_name: cap}`` dict caps
    per type (types absent from the dict stay uncapped).  A type whose
    cap binds is infeasible FOR THAT TYPE and reported through the same
    ``per_hw`` channel as a physics infeasibility.

    When EVERY type is infeasible, the raised `InfeasibleError` carries
    ``per_hw`` — hardware name -> the failing workload's error string —
    alongside the joined message, so m=10k reports stay actionable."""
    cfg = planner_config(config, engine=engine, budget=budget, batch=batch,
                         replicate=replicate, k_max=k_max)
    best: Optional[Tuple[ProvisioningPlan, HardwareSpec]] = None
    errors: Dict[str, str] = {}
    for hw in hardware:
        cap = (max_devices.get(hw.name)
               if isinstance(max_devices, dict) else max_devices)
        try:
            plan = provision(specs, profiles_by_hw[hw.name], hw, config=cfg,
                             max_devices=cap)
        except InfeasibleError as e:
            errors[hw.name] = str(e)
            continue
        if best is None or plan.cost_per_hour() < best[0].cost_per_hour():
            best = (plan, hw)
    if best is None:
        raise InfeasibleError(
            "; ".join(f"{name}: {msg}" for name, msg in errors.items()),
            per_hw=errors)
    return best


def predicted_plan_metrics(plan: ProvisioningPlan,
                           profiles: Dict[str, WorkloadCoefficients],
                           hw: HardwareSpec):
    """Model-predicted latency/throughput for every placement in a plan
    (all devices evaluated through the batched model in one call)."""
    by_gpu = sorted(plan.by_gpu().items())
    devices = [[pm.PlacedWorkload(coeffs=profiles[p.workload.model],
                                  batch=p.batch, r=p.r) for p in pls]
               for _, pls in by_gpu]
    batch = pmv.predict_device_batch(devices, hw)
    out = {}
    for q, (g, pls) in enumerate(by_gpu):
        pred = batch.device(q)
        for p, wp in zip(pls, pred.per_workload):
            out[p.workload.name] = wp
    return out


def predicted_violations(plan: ProvisioningPlan,
                         profiles: Dict[str, WorkloadCoefficients],
                         hw: HardwareSpec, *,
                         config: Optional[PlannerConfig] = None,
                         budget: Optional[BudgetLike] = None) -> List[str]:
    """Workloads whose model-predicted t_inf exceeds their inference
    budget (Constraint 14 check used by the scale sweep).  Pass the same
    ``budget`` the plan was provisioned with: the budget IS the per-
    workload threshold (T_slo/2 under "half").  Replicas are merged to
    BASE names — a workload violates when ANY of its replicas exceeds
    the budget at its rate share — so counts stay comparable across
    replicated and unreplicated plans."""
    cfg = planner_config(config, budget=budget)
    bm = resolve(cfg.budget)
    metrics = predicted_plan_metrics(plan, profiles, hw)
    by_name = {p.workload.name: p for p in plan.placements}
    out: List[str] = []
    seen = set()
    for name, wp in metrics.items():
        if wp.t_inf > bm.budget_ms(by_name[name].workload.slo_ms,
                                   by_name[name].workload.rate_rps,
                                   by_name[name].batch) + 1e-6:
            base = replication.base_name(name)
            if base not in seen:
                seen.add(base)
                out.append(base)
    return out
