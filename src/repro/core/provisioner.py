"""iGniter GPU resource provisioning strategy (paper Sec. 4.1).

Implements Theorem 1 (appropriate batch size b_appr, Eq. 17; resource
lower bound r_lower, Eq. 18), Algorithm 2 (`alloc_gpus`) and Algorithm 1
(`provision`) faithfully, including the ANYFIT new-device rule and the
greedy minimum-interference device selection.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import perf_model as pm
from repro.core.types import (HardwareSpec, Placement, ProvisioningPlan,
                              WorkloadCoefficients, WorkloadSpec)

R_MAX = 1.0


class InfeasibleError(RuntimeError):
    """A workload cannot meet its SLO even alone on a full device."""


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------

def appropriate_batch(spec: WorkloadSpec, c: WorkloadCoefficients,
                      hw: HardwareSpec, *, b_max: int = 64) -> int:
    """Eq. (17): smallest batch sustaining the arrival rate within T_slo/2.

    R is req/s; the model works in ms, so R_ms = R / 1000.
    """
    r_ms = spec.rate_rps / 1000.0
    num = spec.slo_ms * r_ms * hw.pcie_bw
    den = 2.0 * (hw.pcie_bw + r_ms * c.d_load)
    b = int(math.ceil(num / den))
    return max(1, min(b, b_max))


def resource_lower_bound(spec: WorkloadSpec, c: WorkloadCoefficients,
                         hw: HardwareSpec, b_appr: Optional[int] = None) -> float:
    """Eq. (18): minimal solo resource fraction meeting T_slo/2."""
    b = b_appr if b_appr is not None else appropriate_batch(spec, c, hw)
    gamma = c.k1 * b * b + c.k2 * b + c.k3
    delta = (spec.slo_ms / 2.0
             - (c.d_load + c.d_feedback) * b / hw.pcie_bw
             - c.k5 - c.k_sch * c.n_kernels)
    if delta <= 0:
        raise InfeasibleError(
            f"{spec.name}: fixed latency terms exceed T_slo/2 "
            f"(delta={delta:.3f} ms)")
    r = gamma / delta - c.k4
    r_units = math.ceil(r / hw.r_unit - 1e-9)
    r_lower = max(hw.r_unit, r_units * hw.r_unit)
    if r_lower > R_MAX + 1e-9:
        raise InfeasibleError(
            f"{spec.name}: needs r={r_lower:.3f} > 100% of a device")
    return min(r_lower, R_MAX)


# ---------------------------------------------------------------------------
# Device state during provisioning
# ---------------------------------------------------------------------------

@dataclass
class _Dev:
    """Mutable allocation state for one device."""
    entries: List[Tuple[WorkloadSpec, WorkloadCoefficients, int, float]] = \
        field(default_factory=list)   # (spec, coeffs, batch, r)

    def total(self) -> float:
        return sum(e[3] for e in self.entries)

    def placed(self) -> List[pm.PlacedWorkload]:
        return [pm.PlacedWorkload(coeffs=c, batch=b, r=r)
                for (_, c, b, r) in self.entries]


# ---------------------------------------------------------------------------
# Algorithm 2: alloc_gpus
# ---------------------------------------------------------------------------

def alloc_gpus(dev: _Dev, w_spec: WorkloadSpec, w_coeffs: WorkloadCoefficients,
               w_batch: int, w_r_lower: float,
               hw: HardwareSpec) -> Optional[List[float]]:
    """Try placing workload w on `dev`; returns the new allocation vector
    r_a (existing entries order, w last), or None if the device cannot host
    it within r_max.

    Faithful to Alg. 2: start w at its lower bound, then iteratively grant
    +r_unit to any workload whose predicted t_inf exceeds T_slo/2, until
    stable or out of resources.
    """
    specs = [e[0] for e in dev.entries] + [w_spec]
    coeffs = [e[1] for e in dev.entries] + [w_coeffs]
    batches = [e[2] for e in dev.entries] + [w_batch]
    r_a = [e[3] for e in dev.entries] + [w_r_lower]

    flag = True
    while sum(r_a) <= R_MAX + 1e-9 and flag:
        flag = False
        placed = [pm.PlacedWorkload(coeffs=c, batch=b, r=r)
                  for c, b, r in zip(coeffs, batches, r_a)]
        pred = pm.predict_device(placed, hw)
        for i, spec in enumerate(specs):
            if pred.per_workload[i].t_inf > spec.slo_ms / 2.0 + 1e-9:
                r_a[i] = round(r_a[i] + hw.r_unit, 10)
                flag = True
    if sum(r_a) > R_MAX + 1e-9:
        return None
    return r_a


# ---------------------------------------------------------------------------
# Algorithm 1: iGniter provisioning
# ---------------------------------------------------------------------------

def provision(specs: Sequence[WorkloadSpec],
              profiles: Dict[str, WorkloadCoefficients],
              hw: HardwareSpec) -> ProvisioningPlan:
    """Cost-efficient interference-aware provisioning (Alg. 1)."""
    # line 2: b_appr, r_lower per workload
    prepared = []
    for s in specs:
        c = profiles[s.model]
        b = appropriate_batch(s, c, hw)
        rl = resource_lower_bound(s, c, hw, b)
        prepared.append((s, c, b, rl))
    # line 3: sort by r_lower descending
    prepared.sort(key=lambda t: -t[3])

    devs: List[_Dev] = [_Dev()]
    for (s, c, b, rl) in prepared:
        best_q = -1
        best_alloc: Optional[List[float]] = None
        best_inter = R_MAX + 1.0     # r_inter^min
        for q, dev in enumerate(devs):
            r_a = alloc_gpus(dev, s, c, b, rl, hw)
            if r_a is None:
                continue
            # increased resources caused by interference (line 8)
            old = [e[3] for e in dev.entries] + [rl]
            r_inter = sum(max(0.0, na - oa) for na, oa in zip(r_a, old))
            if r_inter < best_inter - 1e-12:
                best_inter = r_inter
                best_q = q
                best_alloc = r_a
        if best_q == -1:
            devs.append(_Dev(entries=[(s, c, b, rl)]))     # line 14
        else:
            dev = devs[best_q]
            new_entries = []
            for (e, r_new) in zip(dev.entries, best_alloc[:-1]):
                new_entries.append((e[0], e[1], e[2], r_new))
            new_entries.append((s, c, b, best_alloc[-1]))
            dev.entries = new_entries

    plan = ProvisioningPlan(hardware=hw)
    for g, dev in enumerate(devs):
        for (s, c, b, r) in dev.entries:
            plan.placements.append(Placement(workload=s, gpu=g, r=r, batch=b))
    plan.n_gpus = sum(1 for d in devs if d.entries)
    return plan


# ---------------------------------------------------------------------------
# Online arrival (paper Sec. 4.2: iGniter is "periodically executed to
# provision GPU resources for newly-arrived inference workloads").
# Unlike gpu-lets, Alg. 2 may grow the allocations of ORIGINALLY-PLACED
# workloads on the chosen device to absorb the newcomer's interference.
# ---------------------------------------------------------------------------

def add_workload(plan: ProvisioningPlan, spec: WorkloadSpec,
                 profiles: Dict[str, WorkloadCoefficients],
                 hw: HardwareSpec) -> ProvisioningPlan:
    """Place one newly-arrived workload into an existing plan (in place of
    a full re-run of Alg. 1): greedy minimum-interference device selection
    with Alg. 2 reallocation, or a fresh device."""
    c = profiles[spec.model]
    b = appropriate_batch(spec, c, hw)
    rl = resource_lower_bound(spec, c, hw, b)

    devs: Dict[int, _Dev] = {}
    for p in plan.placements:
        devs.setdefault(p.gpu, _Dev()).entries.append(
            (p.workload, profiles[p.workload.model], p.batch, p.r))

    best_q, best_alloc, best_inter = -1, None, R_MAX + 1.0
    for q, dev in sorted(devs.items()):
        r_a = alloc_gpus(dev, spec, c, b, rl, hw)
        if r_a is None:
            continue
        old = [e[3] for e in dev.entries] + [rl]
        r_inter = sum(max(0.0, na - oa) for na, oa in zip(r_a, old))
        if r_inter < best_inter - 1e-12:
            best_q, best_alloc, best_inter = q, r_a, r_inter

    new_plan = ProvisioningPlan(hardware=plan.hardware or hw)
    if best_q == -1:
        g_new = (max(devs) + 1) if devs else 0
        new_plan.placements = list(plan.placements) + [
            Placement(workload=spec, gpu=g_new, r=rl, batch=b)]
    else:
        for p in plan.placements:
            if p.gpu != best_q:
                new_plan.placements.append(p)
        dev = devs[best_q]
        for (s, _, bb, _), r_new in zip(dev.entries, best_alloc[:-1]):
            new_plan.placements.append(
                Placement(workload=s, gpu=best_q, r=r_new, batch=bb))
        new_plan.placements.append(
            Placement(workload=spec, gpu=best_q, r=best_alloc[-1], batch=b))
    new_plan.n_gpus = len({p.gpu for p in new_plan.placements})
    return new_plan


# ---------------------------------------------------------------------------
# Heterogeneous type selection (paper Sec. 5.3, Fig. 20)
# ---------------------------------------------------------------------------

def provision_cheapest(specs: Sequence[WorkloadSpec],
                       profiles_by_hw: Dict[str, Dict[str, WorkloadCoefficients]],
                       hardware: Sequence[HardwareSpec]
                       ) -> Tuple[ProvisioningPlan, HardwareSpec]:
    """Run Alg. 1 per hardware type and pick the cheapest feasible plan."""
    best: Optional[Tuple[ProvisioningPlan, HardwareSpec]] = None
    errors = []
    for hw in hardware:
        try:
            plan = provision(specs, profiles_by_hw[hw.name], hw)
        except InfeasibleError as e:
            errors.append(str(e))
            continue
        if best is None or plan.cost_per_hour() < best[0].cost_per_hour():
            best = (plan, hw)
    if best is None:
        raise InfeasibleError("; ".join(errors))
    return best


def predicted_plan_metrics(plan: ProvisioningPlan,
                           profiles: Dict[str, WorkloadCoefficients],
                           hw: HardwareSpec):
    """Model-predicted latency/throughput for every placement in a plan."""
    out = {}
    for g, pls in plan.by_gpu().items():
        placed = [pm.PlacedWorkload(coeffs=profiles[p.workload.model],
                                    batch=p.batch, r=p.r) for p in pls]
        pred = pm.predict_device(placed, hw)
        for p, wp in zip(pls, pred.per_workload):
            out[p.workload.name] = wp
    return out
