"""Core datatypes for the iGniter performance model and provisioner.

Faithful to the paper's notation (Table 2).  Units:
  latency: milliseconds            rate: requests / second
  data sizes: megabytes            bandwidth: MB / ms  (== GB/s)
  power: watts                     frequency: MHz
  resources r: fraction of one accelerator in [0, 1], unit r_unit
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

if TYPE_CHECKING:                      # no runtime dependency: types.py
    from repro.core.queueing import BudgetLike   # stays import-free


# Theorem 1 search ceiling for replica groups (k = 1..K_MAX).  Canonical
# home; `provisioner.K_MAX` re-exports it for backward compatibility.
K_MAX = 8


@dataclass(frozen=True)
class HardwareSpec:
    """Hardware-specific coefficients (paper Sec. 3.1: P, F, p_idle,
    B_pcie, alpha_f, alpha_sch, beta_sch) + pricing."""
    name: str
    power_cap: float          # P   [W]
    max_freq: float           # F   [MHz]
    idle_power: float         # p_idle [W]
    pcie_bw: float            # B_pcie [MB/ms == GB/s] host<->HBM DMA
    alpha_f: float            # MHz per excess W (negative)
    alpha_sch: float          # ms/kernel per co-located workload
    beta_sch: float           # ms/kernel intercept
    r_unit: float = 0.025     # allocation granularity (2.5%)
    price_per_hour: float = 3.06   # $/h per accelerator (p3.2xlarge analogue)
    # TPU-analogue physics used by the ground-truth simulator only:
    peak_flops: float = 197e12     # bf16 FLOP/s per chip (v5e)
    hbm_bw: float = 819e9          # bytes/s
    mxu_efficiency: float = 0.45   # achievable fraction of peak at serving bs

    @property
    def price_per_ms(self) -> float:
        return self.price_per_hour / 3_600_000.0


# TPU v5e chip as the accelerator unit (see DESIGN.md hardware adaptation).
V5E = HardwareSpec(
    name="tpu-v5e",
    power_cap=170.0, max_freq=940.0, idle_power=60.0,
    pcie_bw=10.0, alpha_f=-1.1, alpha_sch=0.0048, beta_sch=-0.009,
    r_unit=0.025, price_per_hour=1.20,
    peak_flops=197e12, hbm_bw=819e9, mxu_efficiency=0.45,
)

# A v4-like bigger/costlier chip for the heterogeneous experiment (Fig. 20).
V4 = HardwareSpec(
    name="tpu-v4",
    power_cap=260.0, max_freq=1050.0, idle_power=90.0,
    pcie_bw=16.0, alpha_f=-0.9, alpha_sch=0.0042, beta_sch=-0.008,
    r_unit=0.025, price_per_hour=3.22,
    peak_flops=275e12, hbm_bw=1228e9, mxu_efficiency=0.5,
)


@dataclass(frozen=True)
class WorkloadCoefficients:
    """Workload-specific coefficients (paper Sec. 3.1), one per
    (DNN model, hardware type).

    d_load/d_feedback: MB per request at b=1 (profiled once, Eq. 3)
    n_kernels: kernel count n_k (fused HLO computations on TPU)
    k_sch: solo per-kernel dispatch delay [ms]
    k1..k5: Eq. 11 solo active-time surface k_act(b, r)
    alpha/beta_power: p(b) = alpha_power * (b / k_act) + beta_power
    alpha/beta_cacheutil: c(b) = alpha_cacheutil * (b / k_act) + beta_cacheutil
    alpha_cache: sensitivity of active time to neighbors' cache util (Eq. 8)
    """
    model: str
    hardware: str
    d_load: float
    d_feedback: float
    n_kernels: int
    k_sch: float
    k1: float
    k2: float
    k3: float
    k4: float
    k5: float
    alpha_power: float
    beta_power: float
    alpha_cacheutil: float
    beta_cacheutil: float
    alpha_cache: float

    # -- solo characteristics (Sec. 3.1) ------------------------------------
    def k_act(self, b: float, r: float) -> float:
        """Solo GPU active time, Eq. 11."""
        return (self.k1 * b * b + self.k2 * b + self.k3) / (r + self.k4) + self.k5

    def power(self, b: float, r: float) -> float:
        """Solo power consumption p^i (linear in processing ability b/k_act)."""
        return self.alpha_power * (b / self.k_act(b, r)) + self.beta_power

    def cache_util(self, b: float, r: float) -> float:
        """Solo L2-cache(/HBM-bandwidth) utilization c^i."""
        return self.alpha_cacheutil * (b / self.k_act(b, r)) + self.beta_cacheutil

    def t_load(self, b: float, pcie_bw: float) -> float:
        return self.d_load * b / pcie_bw

    def t_feedback(self, b: float, pcie_bw: float) -> float:
        return self.d_feedback * b / pcie_bw


@dataclass(frozen=True)
class WorkloadSpec:
    """A DNN inference workload submitted to the iGniter portal.

    ``priority`` is the admission-control class (higher = more
    important; default 0).  The paper's planner never says "no", so
    priority is ignored by provisioning physics — it only orders the
    controller's queue-or-shed / brownout / preemption decisions when a
    device cap binds (docs/control-plane.md, Overload section).
    """
    name: str                 # e.g. "W3"
    model: str                # model key (profile lookup)
    slo_ms: float             # T_slo
    rate_rps: float           # R (request arrival rate == target throughput)
    priority: int = 0         # admission class (higher wins under a cap)


@dataclass
class Placement:
    """One workload's provisioning decision."""
    workload: WorkloadSpec
    gpu: int                  # device index
    r: float                  # allocated resource fraction
    batch: int                # configured batch size b_appr


@dataclass
class ProvisioningPlan:
    placements: List[Placement] = field(default_factory=list)
    n_gpus: int = 0
    hardware: Optional[HardwareSpec] = None

    def by_gpu(self) -> Dict[int, List[Placement]]:
        out: Dict[int, List[Placement]] = {}
        for pl in self.placements:
            out.setdefault(pl.gpu, []).append(pl)
        return out

    def cost_per_hour(self) -> float:
        assert self.hardware is not None
        return self.n_gpus * self.hardware.price_per_hour

    def total_allocated(self, gpu: int) -> float:
        return sum(pl.r for pl in self.placements if pl.gpu == gpu)

    def summary(self) -> str:
        lines = []
        for g, pls in sorted(self.by_gpu().items()):
            body = ", ".join(f"{pl.workload.name}({pl.r*100:.1f}%, b{pl.batch})"
                             for pl in pls)
            lines.append(f"GPU{g}: {body}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Planner configuration (the unified knob object; docs/provisioning.md)
# ---------------------------------------------------------------------------

_BACKENDS = ("numpy", "jax")
_ENGINES = ("vec", "scalar")
_BATCH_MODES = ("eq17", "joint")


@dataclass(frozen=True)
class PlannerConfig:
    """All provisioning knobs in one frozen, hashable object.

    Replaces the five parallel keywords (``engine=``, ``budget=``,
    ``batch=``, ``replicate=``, ``k_max=``) that used to be threaded
    through every planner entry point, and adds the sixth —
    ``backend`` — introduced with the JAX port:

      backend    "numpy" (pinned oracle) | "jax" (jitted hot path;
                 requires the vectorized engine)
      engine     "vec" (batched Alg. 1/2) | "scalar" (reference oracle)
      budget     "queueing" | "half" | a `queueing.BudgetModel`
      batch      "eq17" (closed form) | "joint" (scan b, min r_lower)
      replicate  split solo-infeasible workloads into replica groups
      k_max      Theorem-1 replica search ceiling (k = 1..k_max)

    Every public entry point accepts ``config=``; the legacy keywords
    remain as deprecated shims resolved through `planner_config` (passing
    both is a TypeError).  Defaults reproduce the historical behavior
    bit-for-bit.
    """
    backend: str = "numpy"
    engine: str = "vec"
    budget: "BudgetLike" = "queueing"
    batch: str = "eq17"
    replicate: bool = False
    k_max: int = K_MAX

    def __post_init__(self) -> None:
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.engine not in _ENGINES:
            raise ValueError(f"engine must be one of {_ENGINES}, "
                             f"got {self.engine!r}")
        if self.batch not in _BATCH_MODES:
            raise ValueError(f"batch must be one of {_BATCH_MODES}, "
                             f"got {self.batch!r}")
        if self.backend == "jax" and self.engine != "vec":
            raise ValueError("backend='jax' jits the vectorized engine; "
                             "combine it with engine='vec'")
        if isinstance(self.budget, str) and self.budget not in ("half",
                                                                "queueing"):
            raise ValueError(f"budget string must be 'half' or 'queueing', "
                             f"got {self.budget!r}")
        if self.k_max < 1:
            raise ValueError(f"k_max must be >= 1, got {self.k_max}")

    def replace(self, **changes) -> "PlannerConfig":
        return dataclasses.replace(self, **changes)


def planner_config(config: Optional[PlannerConfig] = None,
                   base: Optional[PlannerConfig] = None,
                   **legacy) -> PlannerConfig:
    """Resolve ``config=`` against the deprecated per-knob keywords.

    Entry points declare their legacy keywords with ``None`` sentinels
    and forward them here: ``config=`` wins, but mixing it with any
    explicit legacy keyword is a TypeError (silently ignoring either
    would be worse).  ``base`` carries a call-site default that differs
    from `PlannerConfig()` (e.g. the controller's ``batch="joint"``).
    """
    given = {k: v for k, v in legacy.items() if v is not None}
    if config is not None:
        if given:
            raise TypeError(
                "pass either config=PlannerConfig(...) or the legacy "
                f"keywords, not both (got config= plus {sorted(given)})")
        return config
    cfg = base if base is not None else PlannerConfig()
    return dataclasses.replace(cfg, **given) if given else cfg
