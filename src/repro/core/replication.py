"""Replica groups: one workload served by k >= 1 instances.

iGniter places exactly ONE instance per workload, so a workload
provisioned near r = 1.0 has zero headroom: once its rate ramps past
what a full device can serve, no re-placement can help it (the m=1000
diurnal residual, see ROADMAP "Replication across devices").  ParvaGPU
(arXiv:2409.14447) splits a workload's demand across multiple GPU
segments and Dynamic Space-Time Scheduling (arXiv:1901.00041) motivates
replica-level load balancing; this module supplies the SHARED vocabulary
for that beyond-paper extension — the naming scheme and rate-share
arithmetic the provisioner, simulator and controller all agree on.

Conventions (docs/provisioning.md "Replica groups"):

  * A workload ``w`` split k >= 2 ways is served by replicas named
    ``w#0 .. w#k-1`` — ordinary `WorkloadSpec`s whose ``rate_rps`` is
    the replica's RATE SHARE.  Shares always sum to the base workload's
    rate (`make_replicas` splits equally; renormalize by re-making).
  * ``k = 1`` keeps the PLAIN name: a single-replica "group" is
    byte-for-byte the pre-replication workload, which is what keeps
    un-split plans (and their simulations) bit-identical to PR-4-era
    output.
  * Everything downstream of a spec treats replicas as independent
    workloads (placement, Alg. 2 grants, budgets at the SHARE rate);
    only arrival generation and violation accounting merge them back to
    the base name (`base_name`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.types import Placement, WorkloadSpec

SEP = "#"


def base_name(name: str) -> str:
    """``"w#3" -> "w"``; plain names pass through."""
    return name.split(SEP, 1)[0]


def replica_index(name: str) -> Optional[int]:
    """``"w#3" -> 3``; None for a plain (unreplicated) name."""
    if SEP not in name:
        return None
    return int(name.split(SEP, 1)[1])


def is_replica(name: str) -> bool:
    return SEP in name


def replica_name(base: str, j: int) -> str:
    return f"{base}{SEP}{j}"


def make_replicas(spec: WorkloadSpec, k: int) -> List[WorkloadSpec]:
    """k replica specs with equal rate shares summing to ``spec.rate_rps``.

    ``spec`` must carry a plain (base) name; ``k = 1`` returns ``[spec]``
    unchanged — the plain-name convention above.
    """
    if is_replica(spec.name):
        raise ValueError(f"{spec.name!r} is already a replica name; "
                         "split from the base spec")
    if k < 1:
        raise ValueError(f"need k >= 1 replicas, got {k}")
    if k == 1:
        return [spec]
    share = spec.rate_rps / k
    return [dataclasses.replace(spec, name=replica_name(spec.name, j),
                                rate_rps=share)
            for j in range(k)]


def group_specs(specs: Iterable[WorkloadSpec]
                ) -> Dict[str, List[WorkloadSpec]]:
    """Group (replica) specs by base name, each group sorted by replica
    index (plain names sort first)."""
    out: Dict[str, List[WorkloadSpec]] = {}
    for s in specs:
        out.setdefault(base_name(s.name), []).append(s)
    for group in out.values():
        group.sort(key=lambda s: replica_index(s.name) or 0)
    return out


def group_placements(placements: Sequence[Placement]
                     ) -> Dict[str, List[Placement]]:
    """Group a plan's placements by base workload name (replica order)."""
    out: Dict[str, List[Placement]] = {}
    for p in placements:
        out.setdefault(base_name(p.workload.name), []).append(p)
    for group in out.values():
        group.sort(key=lambda p: replica_index(p.workload.name) or 0)
    return out


def group_rate(group: Sequence[WorkloadSpec]) -> float:
    """Total workload rate = sum of the group's rate shares."""
    return float(sum(s.rate_rps for s in group))


def group_priority(group: Sequence[Placement]) -> int:
    """Admission class of a replica group (all replicas inherit the base
    spec's ``priority`` through `make_replicas`)."""
    return int(group[0].workload.priority)


def preemption_order(groups: Dict[str, List[Placement]]) -> List[str]:
    """Deterministic victim order for the admission layer's preemption
    (docs/control-plane.md, Overload): lowest priority class first, then
    LARGEST device footprint (total granted r) — each shed frees the
    most capacity per victim — then base name as the stable tie-break.
    Both simulator engines and both reconciler paths must shed in this
    exact order or controlled runs lose bit-identity.
    """
    def key(base: str):
        g = groups[base]
        return (group_priority(g), -sum(p.r for p in g), base)
    return sorted(groups, key=key)


def proportional_shares(total: float,
                        caps: Sequence[float]) -> Optional[List[float]]:
    """Rate shares proportional to per-replica serving capacity.

    `make_replicas` splits equally, which is only load-balanced when
    every replica lands on an identical device composition; on unequal
    devices the slow replica becomes the group's p99.  Returns ``total``
    split as ``caps / sum(caps)`` — or None when every capacity is
    (bitwise) identical, so callers skip the rewrite and equal-device
    groups stay bit-identical to the equal-split plan.
    """
    if not caps:
        return None
    if any(not c > 0.0 for c in caps):
        raise ValueError(f"capacities must be positive, got {list(caps)}")
    if all(c == caps[0] for c in caps):
        return None
    s = float(sum(caps))
    return [float(total) * float(c) / s for c in caps]
