"""iGniter analytical DNN-inference performance model (paper Sec. 3.1).

Implements Eqs. (1)-(11) exactly:

  t_inf  = t_load + t_gpu + t_feedback                                  (1)
  h      = b / (t_gpu + t_feedback)                                     (2)
  t_load = d_load * b / B_pcie ;  t_feedback = d_feedback * b / B_pcie  (3)
  t_gpu  = (t_sch + t_act) / (f / F)                                    (4)
  t_sch  = (k_sch + Delta_sch) * n_k                                    (5)
  Delta_sch = 0 if <=1 workload else alpha_sch * n_colocated + beta_sch (6)
  t_act  = k_act * (1 + alpha_cache * sum_other c)                      (8)
  f      = F if p_demand <= P else F + alpha_f * (p_demand - P)         (9)
  p_demand = p_idle + sum_i p_i                                         (10)
  k_act  = (k1 b^2 + k2 b + k3) / (r + k4) + k5                         (11)

The module is pure Python over small lists and serves as the reference
oracle.  The provisioner calls the model O(m^2) times, which the paper
bounds at 4.61 s for m=1000 — that bound is met by the vectorized
implementation in `repro.core.perf_model_vec` (the provisioner's default
engine); `tests/test_perf_model_vec.py` pins the two to <= 1e-9.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core.types import HardwareSpec, WorkloadCoefficients


@dataclass(frozen=True)
class PlacedWorkload:
    """A (coefficients, batch, resources) triple co-located on one device."""
    coeffs: WorkloadCoefficients
    batch: int
    r: float


@dataclass(frozen=True)
class DevicePrediction:
    """Per-device model outputs."""
    freq: float                     # f^j [MHz]
    p_demand: float                 # total power demand [W]
    delta_sch: float                # Delta_sch^j [ms/kernel]
    per_workload: Tuple["WorkloadPrediction", ...]


@dataclass(frozen=True)
class WorkloadPrediction:
    t_load: float
    t_sch: float
    t_act: float
    t_gpu: float
    t_feedback: float
    t_inf: float                    # Eq. (1)
    throughput: float               # Eq. (2) [req/s]


def delta_sch(hw: HardwareSpec, n_colocated: int) -> float:
    """Eq. (6)."""
    if n_colocated <= 1:
        return 0.0
    return hw.alpha_sch * n_colocated + hw.beta_sch


def gpu_frequency(hw: HardwareSpec, p_demand: float) -> float:
    """Eq. (9)."""
    if p_demand <= hw.power_cap:
        return hw.max_freq
    return max(hw.max_freq + hw.alpha_f * (p_demand - hw.power_cap),
               0.3 * hw.max_freq)


def predict_device(workloads: Sequence[PlacedWorkload],
                   hw: HardwareSpec) -> DevicePrediction:
    """Predict latency/throughput of every workload co-located on a device."""
    n = len(workloads)
    ds = delta_sch(hw, n)

    # Eq. (10): total power demand from solo power draws
    p_demand = hw.idle_power + sum(
        w.coeffs.power(w.batch, w.r) for w in workloads)
    f = gpu_frequency(hw, p_demand)                               # Eq. (9)
    slowdown = f / hw.max_freq

    # solo cache utilizations for Eq. (8)
    caches = [w.coeffs.cache_util(w.batch, w.r) for w in workloads]

    preds = []
    for i, w in enumerate(workloads):
        c = w.coeffs
        t_load = c.t_load(w.batch, hw.pcie_bw)                    # Eq. (3)
        t_feedback = c.t_feedback(w.batch, hw.pcie_bw)
        t_sch = (c.k_sch + ds) * c.n_kernels                      # Eq. (5)
        other_cache = sum(caches) - caches[i]
        t_act = c.k_act(w.batch, w.r) * (1.0 + c.alpha_cache * other_cache)  # Eq. (8)
        t_gpu = (t_sch + t_act) / slowdown                        # Eq. (4)
        t_inf = t_load + t_gpu + t_feedback                       # Eq. (1)
        thr = 1000.0 * w.batch / (t_gpu + t_feedback)             # Eq. (2) -> req/s
        preds.append(WorkloadPrediction(
            t_load=t_load, t_sch=t_sch, t_act=t_act, t_gpu=t_gpu,
            t_feedback=t_feedback, t_inf=t_inf, throughput=thr))
    return DevicePrediction(freq=f, p_demand=p_demand, delta_sch=ds,
                            per_workload=tuple(preds))


def predict_workload(w: PlacedWorkload, neighbors: Sequence[PlacedWorkload],
                     hw: HardwareSpec) -> WorkloadPrediction:
    """Convenience: prediction for one workload among neighbors."""
    all_w = list(neighbors) + [w]
    return predict_device(all_w, hw).per_workload[-1]
