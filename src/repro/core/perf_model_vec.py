"""Vectorized iGniter performance model (Eqs. 1-11) over numpy arrays.

`repro.core.perf_model` is the scalar reference implementation of the
paper's analytical model; Algorithm 1 calls it O(m^2) times, which the
paper bounds at 4.61 s for m = 1000 workloads.  The scalar path
recomputes every co-located workload from scratch on each +r_unit grant,
so it cannot meet that bound.  This module restructures the hot path as
array code:

  * ``CoeffArrays``          struct-of-arrays view of workload coefficients
                             (stacked k1..k5, d_load, cache/power slopes)
  * ``predict_device_vec``   all residents of ONE device in one numpy pass
  * ``predict_device_batch`` all candidate devices x all residents at once
                             (padded 2-D arrays + validity mask)
  * ``VecCluster``           mutable provisioning-time cluster state with
                             incrementally cached per-device invariants
                             (per-resident k_act / power / cache, their
                             sums, and the static t_load/t_sch parts) so a
                             +r_unit grant is O(residents touched), not a
                             full re-predict
  * ``VecCluster.alloc_all`` Algorithm 2 run for ONE newcomer against ALL
                             open devices simultaneously

Entries are replica-aware by construction: each carries its own
`WorkloadSpec`, so a replica ``w#3`` (a per-replica name with a RATE
SHARE, see `repro.core.replication`) is just another entry whose cached
``budget_ms`` was solved at the share rate — the model itself never
needs to know about groups.

Numerical contract: every quantity matches the scalar model to <= 1e-9
(the only reordering is Python ``sum`` -> ``ndarray.sum`` for the power
and cache totals, ~1e-13 relative); `tests/test_perf_model_vec.py`
asserts this across randomized co-location mixes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import perf_model as pm
from repro.core.queueing import BudgetLike, QUEUEING, resolve
from repro.core.types import HardwareSpec, WorkloadCoefficients, WorkloadSpec

R_MAX = 1.0

# Coefficient fields stacked into arrays, in `WorkloadCoefficients` order.
COEFF_FIELDS: Tuple[str, ...] = (
    "k1", "k2", "k3", "k4", "k5", "k_sch", "n_kernels",
    "d_load", "d_feedback",
    "alpha_power", "beta_power",
    "alpha_cacheutil", "beta_cacheutil", "alpha_cache",
)

# Padding values keep every formula finite on masked slots: b=0 with
# k4=1, k5=1 gives k_act=1 and ability b/k_act = 0, hence zero power /
# cache contribution to the device sums.
_PAD = {"k4": 1.0, "k5": 1.0}


@dataclass
class CoeffArrays:
    """Struct-of-arrays over a set of workloads (any leading shape)."""
    k1: np.ndarray
    k2: np.ndarray
    k3: np.ndarray
    k4: np.ndarray
    k5: np.ndarray
    k_sch: np.ndarray
    n_kernels: np.ndarray
    d_load: np.ndarray
    d_feedback: np.ndarray
    alpha_power: np.ndarray
    beta_power: np.ndarray
    alpha_cacheutil: np.ndarray
    beta_cacheutil: np.ndarray
    alpha_cache: np.ndarray

    @classmethod
    def stack(cls, coeffs: Sequence[WorkloadCoefficients]) -> "CoeffArrays":
        return cls(**{f: np.array([getattr(c, f) for c in coeffs],
                                  dtype=np.float64)
                      for f in COEFF_FIELDS})

    def k_act(self, b: np.ndarray, r: np.ndarray) -> np.ndarray:
        """Eq. (11) on arrays."""
        return ((self.k1 * b * b + self.k2 * b + self.k3) / (r + self.k4)
                + self.k5)


# ---------------------------------------------------------------------------
# Batched forward evaluation of Eqs. (1)-(11)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BatchPrediction:
    """Model outputs for D devices x N resident slots (masked)."""
    mask: np.ndarray            # (D, N) bool, True = real workload
    freq: np.ndarray            # (D,)  Eq. (9)
    p_demand: np.ndarray        # (D,)  Eq. (10)
    delta_sch: np.ndarray       # (D,)  Eq. (6)
    t_load: np.ndarray          # (D, N)
    t_sch: np.ndarray
    t_act: np.ndarray
    t_gpu: np.ndarray
    t_feedback: np.ndarray
    t_inf: np.ndarray           # Eq. (1)
    throughput: np.ndarray      # Eq. (2) [req/s]

    def device(self, q: int) -> pm.DevicePrediction:
        """Materialize one device as the scalar dataclasses (drop-in)."""
        idx = np.where(self.mask[q])[0]
        per = tuple(pm.WorkloadPrediction(
            t_load=float(self.t_load[q, i]), t_sch=float(self.t_sch[q, i]),
            t_act=float(self.t_act[q, i]), t_gpu=float(self.t_gpu[q, i]),
            t_feedback=float(self.t_feedback[q, i]),
            t_inf=float(self.t_inf[q, i]),
            throughput=float(self.throughput[q, i])) for i in idx)
        return pm.DevicePrediction(
            freq=float(self.freq[q]), p_demand=float(self.p_demand[q]),
            delta_sch=float(self.delta_sch[q]), per_workload=per)


def _eval(ca: CoeffArrays, b: np.ndarray, r: np.ndarray, mask: np.ndarray,
          hw: HardwareSpec) -> BatchPrediction:
    """Evaluate Eqs. (1)-(11) for (D, N) padded device arrays."""
    k_act = ca.k_act(b, r)
    ability = np.where(mask, b / k_act, 0.0)
    power = np.where(mask, ca.alpha_power * ability + ca.beta_power, 0.0)
    cache = np.where(mask, ca.alpha_cacheutil * ability + ca.beta_cacheutil,
                     0.0)

    n_co = mask.sum(axis=-1)                                      # (D,)
    ds = np.where(n_co <= 1, 0.0, hw.alpha_sch * n_co + hw.beta_sch)  # Eq. 6
    p_demand = hw.idle_power + power.sum(axis=-1)                 # Eq. 10
    freq = np.where(p_demand <= hw.power_cap, hw.max_freq,        # Eq. 9
                    np.maximum(hw.max_freq
                               + hw.alpha_f * (p_demand - hw.power_cap),
                               0.3 * hw.max_freq))
    slowdown = freq / hw.max_freq

    other_cache = cache.sum(axis=-1)[..., None] - cache
    t_load = ca.d_load * b / hw.pcie_bw                           # Eq. 3
    t_feedback = ca.d_feedback * b / hw.pcie_bw
    t_sch = (ca.k_sch + ds[..., None]) * ca.n_kernels             # Eq. 5
    t_act = k_act * (1.0 + ca.alpha_cache * other_cache)          # Eq. 8
    t_gpu = (t_sch + t_act) / slowdown[..., None]                 # Eq. 4
    t_inf = t_load + t_gpu + t_feedback                           # Eq. 1
    with np.errstate(divide="ignore", invalid="ignore"):
        throughput = np.where(mask, 1000.0 * b / (t_gpu + t_feedback), 0.0)
    return BatchPrediction(mask=mask, freq=freq, p_demand=p_demand,
                           delta_sch=ds, t_load=t_load, t_sch=t_sch,
                           t_act=t_act, t_gpu=t_gpu, t_feedback=t_feedback,
                           t_inf=t_inf, throughput=throughput)


def _pad_stack(devices: Sequence[Sequence[pm.PlacedWorkload]]
               ) -> Tuple[CoeffArrays, np.ndarray, np.ndarray, np.ndarray]:
    """Ragged device lists -> padded (D, N) coeff/batch/r arrays + mask."""
    d = len(devices)
    n = max((len(ws) for ws in devices), default=0) or 1
    fields = {f: np.full((d, n), _PAD.get(f, 0.0)) for f in COEFF_FIELDS}
    b = np.zeros((d, n))
    r = np.ones((d, n))
    mask = np.zeros((d, n), dtype=bool)
    for q, ws in enumerate(devices):
        for i, w in enumerate(ws):
            for f in COEFF_FIELDS:
                fields[f][q, i] = getattr(w.coeffs, f)
            b[q, i] = w.batch
            r[q, i] = w.r
            mask[q, i] = True
    return CoeffArrays(**fields), b, r, mask


def predict_device_batch(devices: Sequence[Sequence[pm.PlacedWorkload]],
                         hw: HardwareSpec) -> BatchPrediction:
    """Evaluate the model for ALL candidate devices at once."""
    ca, b, r, mask = _pad_stack(devices)
    return _eval(ca, b, r, mask, hw)


def predict_device_vec(workloads: Sequence[pm.PlacedWorkload],
                       hw: HardwareSpec) -> pm.DevicePrediction:
    """Drop-in vectorized replacement for `perf_model.predict_device`."""
    return predict_device_batch([workloads], hw).device(0)


# ---------------------------------------------------------------------------
# Incremental provisioning-time cluster state
# ---------------------------------------------------------------------------

class VecCluster:
    """Padded struct-of-arrays state for every open device of one plan.

    Rows are devices, columns resident slots.  Alongside the raw
    (coeffs, batch, r) arrays it caches, per resident, the solo
    invariants the model needs at every Alg. 2 iteration —
    k_act / power / cache_util plus the r-independent t_load,
    t_feedback and k_sch*n_k — and, per device, Sigma power,
    Sigma cache and the entry count (which fixes Delta_sch).  A +r_unit
    grant therefore refreshes only the granted entries and the two sums
    (O(residents touched)) instead of re-deriving the whole device.
    """

    def __init__(self, hw: HardwareSpec, cap_d: int = 8, cap_n: int = 4,
                 budget: BudgetLike = QUEUEING, backend: str = "numpy"):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self.hw = hw
        self.backend = backend
        self.bm = resolve(budget)
        self.d = 0                                  # open devices
        self._cap_d, self._cap_n = cap_d, cap_n
        self.entries: List[List[Tuple[WorkloadSpec, WorkloadCoefficients,
                                      int]]] = []
        self.ca = CoeffArrays(**{
            f: np.full((cap_d, cap_n), _PAD.get(f, 0.0))
            for f in COEFF_FIELDS})
        self.b = np.zeros((cap_d, cap_n))
        self.r = np.ones((cap_d, cap_n))
        # per-entry inference budget (T_slo/2 under budget="half", the
        # queueing-aware split otherwise) — the Alg. 2 grant threshold
        self.budget_ms = np.full((cap_d, cap_n), np.inf)
        self.mask = np.zeros((cap_d, cap_n), dtype=bool)
        self.n = np.zeros(cap_d, dtype=np.int64)
        # cached invariants
        self.k_act = np.ones((cap_d, cap_n))
        self.power = np.zeros((cap_d, cap_n))
        self.cache = np.zeros((cap_d, cap_n))
        self.t_io = np.zeros((cap_d, cap_n, 2))     # (t_load, t_feedback)
        self.t_schk = np.zeros((cap_d, cap_n))      # k_sch * n_kernels
        self.power_sum = np.zeros(cap_d)
        self.cache_sum = np.zeros(cap_d)

    # -- capacity management ------------------------------------------------

    def _grow(self, need_d: int, need_n: int) -> None:
        cap_d = max(self._cap_d, need_d)
        cap_n = max(self._cap_n, need_n)
        while self._cap_d < cap_d:
            self._cap_d *= 2
        while self._cap_n < cap_n:
            self._cap_n *= 2
        if (self._cap_d, self._cap_n) == self.mask.shape:
            return

        def grow2(a: np.ndarray, fill: float) -> np.ndarray:
            out = np.full((self._cap_d, self._cap_n) + a.shape[2:], fill,
                          dtype=a.dtype)
            out[:a.shape[0], :a.shape[1]] = a
            return out

        for f in COEFF_FIELDS:
            setattr(self.ca, f, grow2(getattr(self.ca, f), _PAD.get(f, 0.0)))
        self.b = grow2(self.b, 0.0)
        self.r = grow2(self.r, 1.0)
        self.budget_ms = grow2(self.budget_ms, np.inf)
        self.mask = grow2(self.mask, False)
        self.k_act = grow2(self.k_act, 1.0)
        self.power = grow2(self.power, 0.0)
        self.cache = grow2(self.cache, 0.0)
        self.t_io = grow2(self.t_io, 0.0)
        self.t_schk = grow2(self.t_schk, 0.0)
        for name in ("n",):
            a = getattr(self, name)
            out = np.zeros(self._cap_d, dtype=a.dtype)
            out[:a.shape[0]] = a
            setattr(self, name, out)
        for name in ("power_sum", "cache_sum"):
            a = getattr(self, name)
            out = np.zeros(self._cap_d)
            out[:a.shape[0]] = a
            setattr(self, name, out)

    # -- mutation -----------------------------------------------------------

    def add_device(self) -> int:
        self._grow(self.d + 1, 1)
        self.entries.append([])
        self.d += 1
        return self.d - 1

    def add_entry(self, q: int, spec: WorkloadSpec,
                  coeffs: WorkloadCoefficients, batch: int, r: float) -> None:
        i = int(self.n[q])
        self._grow(self.d, i + 1)
        for f in COEFF_FIELDS:
            getattr(self.ca, f)[q, i] = getattr(coeffs, f)
        self.b[q, i] = batch
        self.r[q, i] = r
        self.budget_ms[q, i] = self.bm.budget_ms(spec.slo_ms,
                                                 spec.rate_rps, batch)
        self.mask[q, i] = True
        self.n[q] = i + 1
        self.t_io[q, i, 0] = coeffs.t_load(batch, self.hw.pcie_bw)
        self.t_io[q, i, 1] = coeffs.t_feedback(batch, self.hw.pcie_bw)
        self.t_schk[q, i] = coeffs.k_sch * coeffs.n_kernels
        self.entries[q].append((spec, coeffs, batch))
        self._refresh_row(q)

    def set_row_r(self, q: int, r_row: np.ndarray) -> None:
        """Commit a new allocation vector for device q (Alg. 2 output)."""
        k = int(self.n[q])
        self.r[q, :k] = r_row[:k]
        self._refresh_row(q)

    def set_budget(self, budget: BudgetLike) -> None:
        """Swap the budget model (online burstiness update) and refresh
        every resident's cached inference budget in one vectorized
        bisection call — new entries pick the new model up via add_entry."""
        self.bm = resolve(budget)
        if self.d == 0 or not self.mask[:self.d].any():
            return
        rows, cols = np.nonzero(self.mask[:self.d])
        slo = np.array([self.entries[q][i][0].slo_ms
                        for q, i in zip(rows, cols)])
        rate = np.array([self.entries[q][i][0].rate_rps
                         for q, i in zip(rows, cols)])
        self.budget_ms[rows, cols] = self.bm.budget_ms_vec(
            slo, rate, self.b[rows, cols])

    def remove_entry(self, q: int, i: int) -> None:
        """Remove resident i from device q (workload departure /
        migration source), shifting later residents left so entry order
        — and therefore downstream plan/placement order — is preserved.
        O(residents of q): the device's cached invariants are refreshed,
        every other device is untouched."""
        k = int(self.n[q])
        if not 0 <= i < k:
            raise IndexError(f"device {q} has {k} entries, no index {i}")
        sl_from = np.s_[q, i + 1:k]
        sl_to = np.s_[q, i:k - 1]
        for f in COEFF_FIELDS:
            a = getattr(self.ca, f)
            a[sl_to] = a[sl_from]
            a[q, k - 1] = _PAD.get(f, 0.0)
        for a, fill in ((self.b, 0.0), (self.r, 1.0),
                        (self.budget_ms, np.inf), (self.k_act, 1.0),
                        (self.power, 0.0), (self.cache, 0.0),
                        (self.t_schk, 0.0)):
            a[sl_to] = a[sl_from]
            a[q, k - 1] = fill
        self.t_io[q, i:k - 1] = self.t_io[q, i + 1:k]
        self.t_io[q, k - 1] = 0.0
        self.mask[q, k - 1] = False
        self.n[q] = k - 1
        del self.entries[q][i]
        self._refresh_row(q)

    def _refresh_row(self, q: int) -> None:
        """Recompute the cached solo invariants + sums for one device."""
        k = int(self.n[q])
        if k == 0:
            self.power_sum[q] = self.cache_sum[q] = 0.0
            return
        sl = np.s_[q, :k]
        ca_row = CoeffArrays(**{f: getattr(self.ca, f)[sl]
                                for f in COEFF_FIELDS})
        k_act = ca_row.k_act(self.b[sl], self.r[sl])
        ability = self.b[sl] / k_act
        self.k_act[sl] = k_act
        self.power[sl] = ca_row.alpha_power * ability + ca_row.beta_power
        self.cache[sl] = (ca_row.alpha_cacheutil * ability
                          + ca_row.beta_cacheutil)
        self.power_sum[q] = self.power[sl].sum()
        self.cache_sum[q] = self.cache[sl].sum()

    # -- read-out -----------------------------------------------------------

    def placed(self, q: int) -> List[pm.PlacedWorkload]:
        return [pm.PlacedWorkload(coeffs=c, batch=b, r=float(self.r[q, i]))
                for i, (_, c, b) in enumerate(self.entries[q])]

    def predict(self, q: int) -> pm.DevicePrediction:
        """Full prediction of device q (fresh evaluation, one vectorized
        pass; the cached invariants are only used inside `alloc_all`)."""
        return predict_device_vec(self.placed(q), self.hw)

    def interference_snapshot(self) -> List[Dict[str, float]]:
        """Per-device interference terms straight from the cached
        invariants (no re-evaluation): entry count, Sigma-power,
        Sigma-cache, Delta_sch (Eq. 6) and the implied power demand
        (Eq. 10) — the planner-side view `repro.serving.telemetry`
        pairs with the simulator's measured timelines.  Empty devices
        are skipped (their sums are zero by construction)."""
        hw = self.hw
        out: List[Dict[str, float]] = []
        for q in range(self.d):
            n = int(self.n[q])
            if n == 0:
                continue
            out.append({
                "device": q, "n": n,
                "power_sum": float(self.power_sum[q]),
                "cache_sum": float(self.cache_sum[q]),
                "delta_sch": (0.0 if n <= 1
                              else hw.alpha_sch * n + hw.beta_sch),
                "p_demand": float(hw.idle_power + self.power_sum[q]),
            })
        return out

    # -- Algorithm 2, batched over every open device ------------------------

    def alloc_all(self, spec: WorkloadSpec, coeffs: WorkloadCoefficients,
                  batch: int, r_lower: float
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Try placing (spec, coeffs, batch) on EVERY open device at once.

        Returns ``(feasible, r_res, r_new, r_inter)`` where ``feasible``
        is (D,) bool, ``r_res`` the (D, N) post-Alg.2 resident
        allocations, ``r_new`` the (D,) newcomer allocation and
        ``r_inter`` the (D,) interference-induced extra resources
        (Alg. 1 line 8 score; +inf where infeasible).

        Per-device trajectories are identical to the scalar
        `provisioner.alloc_gpus`: each iteration grants +r_unit to every
        resident or newcomer whose predicted t_inf exceeds T_slo/2, a
        device leaves the loop when it converges or exceeds r_max.

        With ``backend="jax"`` the loop runs as the jitted
        `perf_model_jax.alloc_all_jax` twin instead (<= 1e-6 agreement;
        identical plans on the pinned workloads).
        """
        hw = self.hw
        d = self.d
        if d == 0:
            z = np.zeros(0)
            return z.astype(bool), np.zeros((0, 1)), z, z
        if self.backend == "jax":
            from repro.core import perf_model_jax
            return perf_model_jax.alloc_all_jax(self, spec, coeffs,
                                                batch, r_lower)
        ncap = self.mask.shape[1]
        mask = self.mask[:d]

        # trial copies of the mutable state (residents) + newcomer columns
        rr = self.r[:d].copy()
        ka = self.k_act[:d].copy()
        pw = self.power[:d].copy()
        cu = self.cache[:d].copy()
        rn = np.full(d, r_lower)
        bn = float(batch)

        def solo_new(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
            k_act = ((coeffs.k1 * bn * bn + coeffs.k2 * bn + coeffs.k3)
                     / (rn[rows] + coeffs.k4) + coeffs.k5)
            ability = bn / k_act
            return (k_act,
                    coeffs.alpha_power * ability + coeffs.beta_power,
                    coeffs.alpha_cacheutil * ability + coeffs.beta_cacheutil)

        all_rows = np.arange(d)
        kan = np.empty(d)
        pn = np.empty(d)
        cn = np.empty(d)
        kan[:], pn[:], cn[:] = solo_new(all_rows)

        p_sum = self.power_sum[:d] + pn
        c_sum = self.cache_sum[:d] + cn
        n_co = self.n[:d] + 1
        ds = np.where(n_co <= 1, 0.0,
                      hw.alpha_sch * n_co + hw.beta_sch)        # Eq. 6
        budget_new = self.bm.budget_ms(spec.slo_ms, spec.rate_rps, batch)
        t_load_new = coeffs.t_load(batch, hw.pcie_bw)
        t_fb_new = coeffs.t_feedback(batch, hw.pcie_bw)
        t_schk_new = coeffs.k_sch * coeffs.n_kernels

        active = np.ones(d, dtype=bool)
        feasible = np.ones(d, dtype=bool)
        while True:
            # loop-top capacity check (scalar: `while sum(r_a) <= R_MAX`)
            tot = np.where(mask, rr, 0.0).sum(axis=1) + rn
            over = active & (tot > R_MAX + 1e-9)
            feasible[over] = False
            active[over] = False
            idx = np.where(active)[0]
            if idx.size == 0:
                break

            # model evaluation from cached invariants (active rows only)
            p_dem = hw.idle_power + p_sum[idx]                  # Eq. 10
            freq = np.where(p_dem <= hw.power_cap, hw.max_freq,  # Eq. 9
                            np.maximum(hw.max_freq + hw.alpha_f
                                       * (p_dem - hw.power_cap),
                                       0.3 * hw.max_freq))
            slow = freq / hw.max_freq
            m_i = mask[idx]
            other_res = c_sum[idx][:, None] - cu[idx]
            t_act = ka[idx] * (1.0 + self.ca.alpha_cache[idx] * other_res)
            t_sch = self.t_schk[idx] + ds[idx][:, None] * self.ca.n_kernels[idx]
            t_gpu = (t_sch + t_act) / slow[:, None]
            t_inf = self.t_io[idx, :, 0] + t_gpu + self.t_io[idx, :, 1]
            viol_res = m_i & (t_inf > self.budget_ms[idx] + 1e-9)

            other_new = c_sum[idx] - cn[idx]
            t_act_n = kan[idx] * (1.0 + coeffs.alpha_cache * other_new)
            t_gpu_n = (t_schk_new + ds[idx] * coeffs.n_kernels + t_act_n) / slow
            t_inf_n = t_load_new + t_gpu_n + t_fb_new
            viol_new = t_inf_n > budget_new + 1e-9

            conv = ~viol_res.any(axis=1) & ~viol_new
            active[idx[conv]] = False
            if not (viol_res[~conv].any() or viol_new[~conv].any()):
                continue

            # grants: +r_unit to every violator on still-active devices
            grow = np.zeros((d, ncap), dtype=bool)
            grow[idx] = viol_res & ~conv[:, None]
            if grow.any():
                rows, cols = np.nonzero(grow)
                rr[rows, cols] = np.round(rr[rows, cols] + hw.r_unit, 10)
                ca_g = CoeffArrays(**{f: getattr(self.ca, f)[rows, cols]
                                      for f in COEFF_FIELDS})
                k_act = ca_g.k_act(self.b[rows, cols], rr[rows, cols])
                ability = self.b[rows, cols] / k_act
                p_new = ca_g.alpha_power * ability + ca_g.beta_power
                c_new = ca_g.alpha_cacheutil * ability + ca_g.beta_cacheutil
                np.subtract.at(p_sum, rows, pw[rows, cols] - p_new)
                np.subtract.at(c_sum, rows, cu[rows, cols] - c_new)
                ka[rows, cols] = k_act
                pw[rows, cols] = p_new
                cu[rows, cols] = c_new
            grow_n = np.zeros(d, dtype=bool)
            grow_n[idx] = viol_new & ~conv
            if grow_n.any():
                rows = np.where(grow_n)[0]
                rn[rows] = np.round(rn[rows] + hw.r_unit, 10)
                k_act, p_new, c_new = solo_new(rows)
                p_sum[rows] += p_new - pn[rows]
                c_sum[rows] += c_new - cn[rows]
                kan[rows], pn[rows], cn[rows] = k_act, p_new, c_new

        # Alg. 1 line 8: extra resources caused by interference
        grown = np.where(mask, np.maximum(0.0, rr - self.r[:d]), 0.0)
        r_inter = grown.sum(axis=1) + np.maximum(0.0, rn - r_lower)
        r_inter = np.where(feasible, r_inter, np.inf)
        return feasible, rr, rn, r_inter


def alloc_gpus_vec(residents: Sequence[Tuple[WorkloadSpec,
                                             WorkloadCoefficients,
                                             int, float]],
                   spec: WorkloadSpec, coeffs: WorkloadCoefficients,
                   batch: int, r_lower: float,
                   hw: HardwareSpec, *,
                   budget: BudgetLike = QUEUEING,
                   backend: str = "numpy") -> Optional[List[float]]:
    """Single-device convenience wrapper matching `provisioner.alloc_gpus`
    (same signature semantics: returns the new allocation vector with the
    newcomer last, or None when the device cannot host it)."""
    cl = VecCluster(hw, budget=budget, backend=backend)
    q = cl.add_device()
    for (s, c, b, r) in residents:
        cl.add_entry(q, s, c, b, r)
    feasible, rr, rn, _ = cl.alloc_all(spec, coeffs, batch, r_lower)
    if not bool(feasible[0]):
        return None
    k = int(cl.n[q])
    return [float(x) for x in rr[0, :k]] + [float(rn[0])]
