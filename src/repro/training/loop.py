"""Training loop: jitted step + data pipeline + checkpointing + logging."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import make_pipeline
from repro.models import transformer as T
from repro.models.zoo import build_model
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import AdamW


@dataclasses.dataclass
class TrainReport:
    losses: List[float]
    tokens_per_s: float
    steps: int


def train(cfg: ArchConfig, *, steps: int = 200, batch: int = 8, seq: int = 128,
          seed: int = 0, opt: Optional[AdamW] = None,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
          log_every: int = 20,
          log_fn: Callable[[str], None] = print) -> TrainReport:
    """Single-host training driver (CPU smoke / example scale)."""
    model = build_model(cfg)
    opt = opt or AdamW(lr=1e-3, warmup_steps=20, total_steps=steps,
                       weight_decay=0.01)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = opt.init(params)
    start_step = 0
    if ckpt_dir:
        restored = ckpt_lib.restore_latest(ckpt_dir, (params, opt_state))
        if restored:
            (params, opt_state), start_step = restored
            log_fn(f"restored checkpoint at step {start_step}")

    compute_dtype = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            pc = T.cast_params(p, compute_dtype)
            return model.loss(pc, batch, remat=False)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    data = make_pipeline(cfg, batch, seq, seed=seed)
    losses: List[float] = []
    t0 = time.time()
    n_tokens = 0
    for i in range(start_step, steps):
        b = next(data)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))
        n_tokens += batch * seq
        if (i + 1) % log_every == 0:
            log_fn(f"step {i+1:5d} loss {np.mean(losses[-log_every:]):.4f}")
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, i + 1, (params, opt_state))
    dt = time.time() - t0
    return TrainReport(losses=losses, tokens_per_s=n_tokens / max(dt, 1e-9),
                       steps=steps)
