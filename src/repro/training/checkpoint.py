"""Checkpointing: msgpack-serialized pytrees with metadata + atomic swap.

(orbax is not available offline; this implements the same contract:
save(step) / restore_latest / retention.)
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(a):
    a = np.asarray(a)
    return {
        b"dtype": a.dtype.str if a.dtype != np.dtype("bfloat16") else "bf16",
        b"shape": list(a.shape),
        b"data": (a.astype(np.float32).tobytes() if a.dtype == jnp.bfloat16
                  else a.tobytes()),
    }


def _unpack_leaf(d):
    dtype = d[b"dtype"]
    if dtype == "bf16" or dtype == b"bf16":
        arr = np.frombuffer(d[b"data"], np.float32).reshape(d[b"shape"])
        return jnp.asarray(arr, jnp.bfloat16)
    arr = np.frombuffer(d[b"data"], np.dtype(dtype)).reshape(d[b"shape"])
    return jnp.asarray(arr)


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    leaves, treedef = jax.tree.flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "leaves.msgpack"), "wb") as f:
        f.write(msgpack.packb([_pack_leaf(l) for l in leaves]))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "n_leaves": len(leaves)}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    # retention
    all_ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                       and not d.endswith(".tmp"))
    for old in all_ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of `like` (shape/dtype template)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "leaves.msgpack"), "rb") as f:
        packed = msgpack.unpackb(f.read())
    leaves = [_unpack_leaf(d) for d in packed]
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, leaves), step


def restore_latest(ckpt_dir: str, like: Any) -> Optional[Tuple[Any, int]]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return restore(ckpt_dir, step, like)
