"""AdamW with decoupled weight decay on plain pytrees (f32 master params).

Optimizer state shards exactly like the params (same logical specs), so
ZeRO-style partitioning falls out of the resolver for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # ()
    mu: Any               # like params (f32, or int8 QuantState)
    nu: Any               # like params


class QuantState(NamedTuple):
    """Blockwise int8 quantized tensor (bnb-style 8-bit optimizer state).

    Blocks run along the LAST dim only, so q has the param's exact shape
    (and sharding), and scale has shape (..., last//QUANT_BLOCK) — both
    shard with the same PartitionSpec as the param, keeping dequantize a
    purely local elementwise op under GSPMD.
    """
    q: jax.Array       # int8, param shape
    scale: jax.Array   # f32, (..., last // QUANT_BLOCK)


QUANT_BLOCK = 256
SHARD_ALIGN = 16      # max mesh-axis size a sharded last dim must divide by


def choose_block(shape) -> Optional[int]:
    """Largest power-of-two block <= QUANT_BLOCK such that a 16-way-sharded
    last dim still holds an integer number of blocks per device (otherwise
    GSPMD reshards the block reshape — measured as a 30 GiB blowup on
    dbrx whose F=10752 is 42 blocks of 256)."""
    if len(shape) < 2:
        return None
    last = shape[-1]
    per_shard = last // SHARD_ALIGN if last % SHARD_ALIGN == 0 else last
    b = QUANT_BLOCK
    while b >= 16:
        if per_shard % b == 0 and last % b == 0:
            return b
        b //= 2
    return None


def quantizable(shape) -> bool:
    return choose_block(shape) is not None


def _quantize(x: jax.Array) -> QuantState:
    block = choose_block(x.shape)
    lead, last = x.shape[:-1], x.shape[-1]
    blocks = x.reshape(lead + (last // block, block))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QuantState(q=q.reshape(x.shape), scale=scale[..., 0])


def _dequantize(qs: QuantState, shape) -> jax.Array:
    lead, last = shape[:-1], shape[-1]
    n_blocks = qs.scale.shape[-1]
    block = last // n_blocks
    blocks = qs.q.astype(jnp.float32).reshape(lead + (n_blocks, block))
    return (blocks * qs.scale[..., None]).reshape(shape)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # 8-bit blockwise-quantized moments (bnb-style) for matrices >= this
    # many elements; None disables quantization entirely.  Saves ~8 bytes/
    # param on 100B+ models (EXPERIMENTS.md §Perf, MoE train memory).
    quant_min_size: Optional[int] = None

    def _quantized(self, a) -> bool:
        return (self.quant_min_size is not None and a.ndim >= 2
                and a.size >= self.quant_min_size and quantizable(a.shape))

    def init(self, params) -> AdamWState:
        def z(a):
            if self._quantized(a):
                return _quantize(jnp.zeros(a.shape, jnp.float32))
            return jnp.zeros(a.shape, jnp.float32)
        mu = jax.tree.map(z, params)
        nu = jax.tree.map(z, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1.0) / max(self.warmup_steps, 1))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1.0 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def is_q(x):
            return isinstance(x, QuantState)

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state.mu)
        leaves_v = treedef.flatten_up_to(state.nu)

        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v):
            g = g.astype(jnp.float32)
            mf = _dequantize(m, p.shape) if is_q(m) else m
            vf = _dequantize(v, p.shape) if is_q(v) else v
            mf = self.b1 * mf + (1 - self.b1) * g
            vf = self.b2 * vf + (1 - self.b2) * g * g
            mhat = mf / b1c
            vhat = vf / b2c
            step_ = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:   # decay matrices only
                step_ = step_ + self.weight_decay * p
            new_p.append((p - lr * step_).astype(p.dtype))
            new_m.append(_quantize(mf) if is_q(m) else mf)
            new_v.append(_quantize(vf) if is_q(v) else vf)

        new_params = jax.tree.unflatten(treedef, new_p)
        mu = jax.tree.unflatten(treedef, new_m)
        nu = jax.tree.unflatten(treedef, new_v)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)
