"""Jitted, sharded step builders + ShapeDtypeStruct input specs.

Everything here works on abstract values only (no allocation) so the
512-device dry-run can lower+compile every (arch x shape) combination.
The same builders drive the real CPU smoke runs with a 1x1 mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as sh
from repro.launch.shapes import SHAPES, InputShape, effective_config
from repro.models import transformer as T
from repro.models.zoo import Model, build_model
from repro.training.optimizer import AdamW, AdamWState, QuantState


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ArchConfig, shape: InputShape,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    model = build_model(cfg)
    return model.train_batch_specs(shape.global_batch, shape.seq_len, dtype)


def abstract_cache(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    model = build_model(cfg)
    window = cfg.sliding_window
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 dtype=dtype, window=window))


def decode_input_specs(cfg: ArchConfig, shape: InputShape,
                       dtype=jnp.bfloat16) -> Dict[str, Any]:
    return {
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache": abstract_cache(cfg, shape, dtype),
    }


def prefill_input_specs(cfg: ArchConfig, shape: InputShape,
                        dtype=jnp.bfloat16) -> Dict[str, Any]:
    model = build_model(cfg)
    batch = model.train_batch_specs(shape.global_batch, shape.seq_len, dtype)
    del batch["labels"]
    return {"batch": batch, "cache": abstract_cache(cfg, shape, dtype)}


def input_specs(arch: str, shape_name: str, dtype=jnp.bfloat16):
    """Public entry: all model inputs for one (arch, shape) as
    ShapeDtypeStructs (weak-type-correct, shardable, no allocation)."""
    cfg = effective_config(arch, shape_name)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape, dtype)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape, dtype)
    return decode_input_specs(cfg, shape, dtype)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltStep:
    fn: Any                      # jitted callable
    abstract_args: Tuple         # args as ShapeDtypeStructs (lower(*args))
    in_shardings: Any
    cfg: ArchConfig


SERVE_TP_FIT_BYTES = 6e9   # replicate-over-data threshold for serving params


def _param_shardings(model: Model, mesh: Mesh, dtype, *, serve: bool = False):
    abstract = model.abstract_params(dtype)
    drop = frozenset()
    if serve and "model" in mesh.shape:
        total = sum(jnp.dtype(a.dtype).itemsize * math.prod(a.shape)
                    for a in jax.tree.leaves(abstract))
        if total / mesh.shape["model"] <= SERVE_TP_FIT_BYTES:
            # classic TP serving: replicate over data, shard over model —
            # avoids per-step FSDP all-gathers when the model fits
            drop = frozenset({"fsdp"})
    specs = sh.resolve_tree(model.param_specs(), abstract, mesh, drop)
    return abstract, jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


# gradient-accumulation factor per arch for train_4k: keeps the activation
# working set under one v5e's HBM at global_batch=256 (dry-run validated)
TRAIN_MICROBATCHES = {
    "zamba2-2.7b": 4,
    "mixtral-8x22b": 16,
    "dbrx-132b": 16,
}

# gradient-accumulation dtype: the 132-140B MoE models on a 256-chip v5e
# pod are optimizer-memory-bound (f32 p+m+v+accum = 8.2 GiB/dev); bf16
# accumulation saves 1 GiB/dev at a ~4-bit mantissa cost over 16
# microbatches (EXPERIMENTS.md §Perf discusses the trade and the
# multi-pod ZeRO alternative).
TRAIN_ACC_DTYPE = {
    "mixtral-8x22b": jnp.bfloat16,
    "dbrx-132b": jnp.bfloat16,
}

# 8-bit Adam moments for the 100B+ MoE models (saves ~6 bytes/param/dev;
# the f32 master weights stay full precision) — EXPERIMENTS.md §Perf.
TRAIN_OPTIMIZER = {
    "mixtral-8x22b": AdamW(quant_min_size=1 << 22),
    "dbrx-132b": AdamW(quant_min_size=1 << 22),
}


def make_train_step(arch: str, mesh: Mesh, *,
                    shape: Optional[InputShape] = None,
                    policy: Optional[sh.ActivationPolicy] = None,
                    opt: Optional[AdamW] = None,
                    remat: bool = True,
                    microbatches: Optional[int] = None,
                    moe_ep: Optional[bool] = None) -> BuiltStep:
    shape = shape or SHAPES["train_4k"]
    cfg = effective_config(arch, shape.name)
    policy = policy or sh.ActivationPolicy()
    opt = opt or TRAIN_OPTIMIZER.get(arch, AdamW())
    model = build_model(cfg)
    M = microbatches if microbatches is not None else \
        TRAIN_MICROBATCHES.get(arch, 1)

    abstract_params, p_shard = _param_shardings(model, mesh, jnp.float32)
    abstract_opt = jax.eval_shape(opt.init, abstract_params)

    def _moment_shard(p_ns, opt_leaf):
        if isinstance(opt_leaf, QuantState):
            return QuantState(
                q=p_ns,
                scale=NamedSharding(mesh, sh.resolve_spec(
                    p_ns.spec, opt_leaf.scale.shape, mesh)))
        return p_ns

    _, ptd = jax.tree.flatten(abstract_params)
    def _opt_tree_shard(moments):
        leaves = ptd.flatten_up_to(moments)
        p_ns = ptd.flatten_up_to(p_shard)
        return jax.tree.unflatten(ptd, [
            _moment_shard(ns, ol) for ns, ol in zip(p_ns, leaves)])

    o_shard = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=_opt_tree_shard(abstract_opt.mu),
        nu=_opt_tree_shard(abstract_opt.nu))
    batch_abs = train_input_specs(cfg, shape)
    dp = sh.batch_axes(mesh)
    b_shard = jax.tree.map(
        lambda a: NamedSharding(mesh, sh.resolve_spec(
            P(dp if len(dp) > 1 else (dp[0] if dp else None)), a.shape, mesh)),
        batch_abs)
    hints = policy.hints(mesh, batch=shape.global_batch)
    if moe_ep is None:
        # default: expert parallelism whenever the mesh admits it —
        # n_experts == data axis AND the microbatch shards over all batch
        # axes (EXPERIMENTS.md §Perf pair 2 it. 6: dbrx -2.3x collectives)
        dp_size = 1
        for a in sh.batch_axes(mesh):
            dp_size *= mesh.shape[a]
        moe_ep = (cfg.is_moe
                  and cfg.n_experts * cfg.expert_shards
                  == mesh.shape.get("data", 0)
                  and (shape.global_batch // M) % dp_size == 0)
    if moe_ep:
        assert cfg.is_moe and (cfg.n_experts * cfg.expert_shards
                               == mesh.shape["data"]), \
            "EP requires n_experts * expert_shards == data axis size"
        # expert weights: E over data (resident experts), F over model
        import dataclasses as _dc
        hints = _dc.replace(hints, moe_ep=(mesh, "data",
                                           sh.batch_axes(mesh)))
        for wname, spec in (("w_gate", P(None, "data", None, "model")),
                            ("w_up", P(None, "data", None, "model")),
                            ("w_down", P(None, "data", "model", None))):
            p_shard["blocks"]["moe"][wname] = NamedSharding(mesh, spec)
        o_shard = AdamWState(
            step=o_shard.step,
            mu=_opt_tree_shard(abstract_opt.mu),
            nu=_opt_tree_shard(abstract_opt.nu))
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def loss_fn(params, batch):
        pc = T.cast_params(params, compute_dtype)
        return model.loss(pc, batch, shard=hints, remat=remat)

    def train_step(params, opt_state, batch):
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # gradient accumulation over M microbatches (scan, f32 accum)
            mb = jax.tree.map(
                lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), batch)

            acc_dtype = TRAIN_ACC_DTYPE.get(arch, jnp.float32)
            if acc_dtype == jnp.bfloat16:
                # differentiate wrt the bf16 compute copy: grad transients
                # and the accumulator are bf16 (1 GiB each saved on the
                # 140B MoE models); Adam still sees f32 at update time
                pc = T.cast_params(params, compute_dtype)

                def mb_loss(pc_, m_batch):
                    return model.loss(pc_, m_batch, shard=hints, remat=remat)

                def acc_step(carry, m_batch):
                    loss_acc, g_acc = carry
                    l, g = jax.value_and_grad(mb_loss)(pc, m_batch)
                    g_acc = jax.tree.map(lambda ga, gi: ga + gi, g_acc, g)
                    return (loss_acc + l, g_acc), None

                zeros = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), pc)
            else:
                def acc_step(carry, m_batch):
                    loss_acc, g_acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, m_batch)
                    g_acc = jax.tree.map(
                        lambda ga, gi: ga + gi.astype(acc_dtype), g_acc, g)
                    return (loss_acc + l, g_acc), None

                zeros = jax.tree.map(
                    lambda a: jnp.zeros(a.shape, acc_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / M
            grads = jax.tree.map(lambda g: g / M, grads)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    fn = jax.jit(train_step,
                 in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
                 donate_argnums=(0, 1))
    return BuiltStep(fn=fn,
                     abstract_args=(abstract_params, abstract_opt, batch_abs),
                     in_shardings=(p_shard, o_shard, b_shard), cfg=cfg)


def make_decode_step(arch: str, mesh: Mesh, *,
                     shape: Optional[InputShape] = None,
                     policy: Optional[sh.ActivationPolicy] = None) -> BuiltStep:
    shape = shape or SHAPES["decode_32k"]
    cfg = effective_config(arch, shape.name)
    policy = policy or sh.ActivationPolicy(
        seq_shard_residual=False, kv_seq_shard=True)
    model = build_model(cfg)

    abstract_params, p_shard = _param_shardings(model, mesh, jnp.bfloat16,
                                                serve=True)
    cache_abs = abstract_cache(cfg, shape)
    c_specs = sh.cache_specs(cache_abs, mesh, batch=shape.global_batch,
                             policy=policy)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = sh.batch_axes(mesh)
    tok_sh = NamedSharding(mesh, sh.resolve_spec(
        P(dp if len(dp) > 1 else (dp[0] if dp else None)),
        (shape.global_batch, 1), mesh))
    hints = policy.hints(mesh, batch=shape.global_batch, decode=True)

    def serve_step(params, token, cache):
        logits, new_cache = model.decode_step(params, token, cache,
                                              shard=hints)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, tok_sh, c_shard),
                 out_shardings=(tok_sh, c_shard),
                 donate_argnums=(2,))
    tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return BuiltStep(fn=fn, abstract_args=(abstract_params, tok_abs, cache_abs),
                     in_shardings=(p_shard, tok_sh, c_shard), cfg=cfg)


def make_prefill_step(arch: str, mesh: Mesh, *,
                      shape: Optional[InputShape] = None,
                      policy: Optional[sh.ActivationPolicy] = None) -> BuiltStep:
    shape = shape or SHAPES["prefill_32k"]
    cfg = effective_config(arch, shape.name)
    policy = policy or sh.ActivationPolicy(kv_seq_shard=True)
    model = build_model(cfg)

    abstract_params, p_shard = _param_shardings(model, mesh, jnp.bfloat16,
                                                serve=True)
    batch_abs = train_input_specs(cfg, shape)
    del batch_abs["labels"]
    cache_abs = abstract_cache(cfg, shape)
    c_specs = sh.cache_specs(cache_abs, mesh, batch=shape.global_batch,
                             policy=policy)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = sh.batch_axes(mesh)
    b_shard = jax.tree.map(
        lambda a: NamedSharding(mesh, sh.resolve_spec(
            P(dp if len(dp) > 1 else (dp[0] if dp else None)), a.shape, mesh)),
        batch_abs)
    hints = policy.hints(mesh, batch=shape.global_batch)

    def prefill_step(params, batch, cache):
        logits, new_cache = model.prefill(params, batch, cache, shard=hints)
        return logits, new_cache

    fn = jax.jit(prefill_step,
                 in_shardings=(p_shard, b_shard, c_shard),
                 out_shardings=(None, c_shard),
                 donate_argnums=(2,))
    return BuiltStep(fn=fn, abstract_args=(abstract_params, batch_abs, cache_abs),
                     in_shardings=(p_shard, b_shard, c_shard), cfg=cfg)


def build_step(arch: str, shape_name: str, mesh: Mesh,
               policy: Optional[sh.ActivationPolicy] = None) -> BuiltStep:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return make_train_step(arch, mesh, shape=shape, policy=policy)
    if shape.kind == "prefill":
        return make_prefill_step(arch, mesh, shape=shape, policy=policy)
    return make_decode_step(arch, mesh, shape=shape, policy=policy)
