"""Training launcher.

CPU-scale real run (reduced config, real data pipeline + checkpoints):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 100

Production-mesh compile check for the full config (no allocation):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --dry-run
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the FULL config on the production mesh")
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_one
        from repro.launch.mesh import make_production_mesh
        rec = run_one(args.arch, "train_4k", make_production_mesh())
        print({k: rec[k] for k in ("arch", "compile_s", "fits_hbm", "dominant",
                                   "compute_s", "memory_s", "collective_s")})
        return

    from repro.configs import get_config, reduced
    from repro.training.loop import train
    cfg = reduced(get_config(args.arch), layers=2, d_model=256)
    report = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                   ckpt_dir=args.ckpt_dir)
    print(f"done: final loss {report.losses[-1]:.4f} "
          f"({report.tokens_per_s:,.0f} tok/s)")


if __name__ == "__main__":
    main()
