"""The four assigned input shapes and per-(arch x shape) applicability.

  train_4k      seq 4,096   global_batch 256   (training, train_step)
  prefill_32k   seq 32,768  global_batch 32    (inference prefill)
  decode_32k    seq 32,768  global_batch 128   (one decode token, KV=seq)
  long_500k     seq 524,288 global_batch 1     (long-context decode)

long_500k requires sub-quadratic attention: SSM/hybrid/SWA archs run it;
pure full-attention archs are skipped (DESIGN.md table).  qwen3-4b runs
it via the beyond-paper sliding-window variant (qwen3-4b-swa).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs import get_config
from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k":
        if arch == "qwen3-4b":
            return True          # via the SWA variant
        return cfg.subquadratic
    del shape
    return True


def effective_config(arch: str, shape_name: str) -> ArchConfig:
    """Arch config actually lowered for a shape (long-context variants)."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if arch == "qwen3-4b":
            cfg = get_config("qwen3-4b-swa")
        if cfg.shared_attn_every and cfg.sliding_window is None:
            # zamba2: shared attn block runs windowed at 500k (DESIGN.md)
            cfg = cfg.replace(sliding_window=4096)
    return cfg


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    if applicable(arch, shape_name):
        return None
    return ("pure full-attention arch: O(S) KV at 524k infeasible without a "
            "sub-quadratic variant (see DESIGN.md)")
