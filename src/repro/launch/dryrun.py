import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# 512 placeholder host devices back both the 16x16 single-pod mesh and the
# 2x16x16 multi-pod mesh.  Never set this globally (smoke tests see 1 dev).

"""Multi-pod dry-run: lower + compile EVERY (architecture x input shape)
combination on the production meshes, proving the distribution config is
coherent without real hardware.

For each combination this records:
  * memory_analysis()    — bytes per device (proves it fits)
  * cost_analysis()      — XLA's own flops/bytes (scan bodies counted once)
  * roofline terms       — from our trip-count-aware HLO analyzer
    (repro.profiling.hlo_analysis): compute / memory / collective seconds
    per step per device, dominant term, collective breakdown.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # full matrix
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 512-chip mesh
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicable, skip_reason
from repro.launch.steps import build_step
from repro.profiling import hlo_analysis as H
from repro.profiling.metrics import forward_flops
from repro.launch.shapes import effective_config

HBM_PER_CHIP = 16e9   # v5e


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N_active*D for inference."""
    cfg = effective_config(arch, shape_name)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def run_one(arch: str, shape_name: str, mesh, *, save_hlo: str | None = None):
    t0 = time.time()
    st = build_step(arch, shape_name, mesh)
    with mesh:
        lowered = st.fn.lower(*st.abstract_args)
        compiled = lowered.compile()
    elapsed = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(txt)
    r = H.roofline_from_hlo(txt)
    n_dev = mesh.devices.size
    mf = model_flops(arch, shape_name)
    hlo_flops_global = r.flops * n_dev
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
        "compile_s": round(elapsed, 1),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "fits_hbm": bool(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                         < HBM_PER_CHIP),
        "xla_cost_flops_per_dev": float(ca.get("flops", 0.0)),
        "hlo_flops_per_dev": r.flops,
        "hbm_bytes_per_dev": r.hbm_bytes,
        "collective_bytes_per_dev": r.collective_bytes,
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "dominant": r.dominant,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "per_collective": {k: v for k, v in r.per_collective.items()},
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo-dir", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    records = []
    for mesh in meshes:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            for shape_name in shapes:
                if not applicable(arch, shape_name):
                    records.append({
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "skip", "reason": skip_reason(arch, shape_name),
                    })
                    print(f"[skip] {arch} {shape_name}: "
                          f"{skip_reason(arch, shape_name)}", flush=True)
                    continue
                hlo_path = None
                if args.save_hlo_dir:
                    os.makedirs(args.save_hlo_dir, exist_ok=True)
                    hlo_path = os.path.join(
                        args.save_hlo_dir, f"{arch}_{shape_name}_{mesh_name}.hlo")
                try:
                    rec = run_one(arch, shape_name, mesh, save_hlo=hlo_path)
                    records.append(rec)
                    print(f"[ok]   {arch:18s} {shape_name:12s} {mesh_name:8s} "
                          f"compile={rec['compile_s']:6.1f}s "
                          f"mem={(rec['temp_bytes_per_dev']+rec['arg_bytes_per_dev'])/2**30:6.2f}GB "
                          f"fits={rec['fits_hbm']} dom={rec['dominant']:10s} "
                          f"c/m/i(ms)={1e3*rec['compute_s']:9.2f}/"
                          f"{1e3*rec['memory_s']:9.2f}/{1e3*rec['collective_s']:9.2f}",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    records.append({
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "fail", "error": f"{type(e).__name__}: {e}"})
                    print(f"[FAIL] {arch} {shape_name} {mesh_name}: {e}",
                          flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skip")
    n_fail = sum(1 for r in records if r["status"] == "fail")
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skip, {n_fail} fail ==")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
