"""Serving launcher: provision with iGniter, then serve.

Cluster-scale (simulator, paper's 12-workload study):
  PYTHONPATH=src python -m repro.launch.serve --mode cluster [--strategy iGniter]

Single-host JAX engine (reduced model, real batched inference on CPU):
  PYTHONPATH=src python -m repro.launch.serve --mode engine --arch qwen3-4b
"""
import argparse
import time


def cluster(strategy: str, duration: float, poisson: bool):
    from repro.core.experiments import all_plans, evaluate_plans, fitted_context
    from repro.serving.workload import specs_by_name
    ctx = fitted_context()
    plans = all_plans(ctx)
    if strategy not in plans:
        raise SystemExit(f"unknown strategy {strategy}; one of {list(plans)}")
    from repro.serving.simulator import simulate_plan
    from repro.serving.workload import models
    res = simulate_plan(plans[strategy], models(), ctx.hw,
                        duration_s=duration, shadow=(strategy == "iGniter"),
                        poisson=poisson)
    sb = specs_by_name()
    print(plans[strategy].summary())
    print(f"devices={plans[strategy].n_gpus} "
          f"cost=${plans[strategy].cost_per_hour():.2f}/h "
          f"arrivals={'poisson' if poisson else 'constant'}")
    for w, m in sorted(res.per_workload.items(), key=lambda kv: int(kv[0][1:])):
        s = sb[w]
        flag = "VIOLATION" if (m["p99_ms"] > s.slo_ms
                               or m["rps"] < 0.95 * s.rate_rps) else "ok"
        print(f"  {w:4s} p99={m['p99_ms']:7.1f}/{s.slo_ms:5.0f} ms "
              f"rps={m['rps']:6.1f}/{s.rate_rps:5.0f} {flag}")


def engine(arch: str, n_requests: int):
    import numpy as np
    from repro.configs import REGISTRY, reduced
    from repro.serving.engine import Request, ServingEngine
    cfg = reduced(REGISTRY[arch], layers=2, d_model=256)
    eng = ServingEngine(cfg, batch_size=4, prompt_len=32)
    rng = np.random.default_rng(0)
    done = []
    for i in range(n_requests):
        eng.submit(Request(rid=i, tokens=rng.integers(
            3, cfg.vocab_size, size=32).astype(np.int32),
            arrival_s=time.time()))
        if (i + 1) % 4 == 0:
            done.extend(eng.pump())
    done.extend(eng.pump())
    lats = np.array([c.latency_ms for c in done])
    print(f"{arch}: served {len(done)} requests, "
          f"p50={np.percentile(lats, 50):.1f} ms "
          f"p99={np.percentile(lats, 99):.1f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("cluster", "engine"), default="cluster")
    ap.add_argument("--strategy", default="iGniter")
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--poisson", action="store_true")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=32)
    args = ap.parse_args()
    if args.mode == "cluster":
        cluster(args.strategy, args.duration, args.poisson)
    else:
        engine(args.arch, args.requests)


if __name__ == "__main__":
    main()
