"""RWKV6 "Finch" block — data-dependent decay linear attention.
[arXiv:2404.05892]

Time-mix: data-dependent token-shift (ddlerp with low-rank adjustments),
per-channel decay w_t = exp(-exp(w0 + lora(x))) and bonus u; recurrence

    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t),   S_t = diag(w_t) S_{t-1} + k_t^T v_t

computed in chunks: intra-chunk via a stable (Q,Q,hd) decay-ratio
contraction in f32, inter-chunk via a `lax.scan` carrying the (hd,hd)
state per head.  Channel-mix: squared-ReLU MLP with token shift.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init

LORA_R = 32
DECAY_R = 64


def init_rwkv6(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    H = d // hd
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((6, d), jnp.float32),   # shift mixes: base,r,k,v,w,g
        "tm_w1": _dense_init(ks[0], (d, 5 * LORA_R)),
        "tm_w2": 0.01 * jax.random.normal(ks[1], (5, LORA_R, d), jnp.float32),
        "w0": -6.0 + jax.random.normal(ks[2], (d,), jnp.float32) * 0.3,
        "dw1": _dense_init(ks[3], (d, DECAY_R)),
        "dw2": 0.01 * jax.random.normal(ks[4], (DECAY_R, d), jnp.float32),
        "u": 0.1 * jax.random.normal(ks[5], (H, hd), jnp.float32),
        "wr": _dense_init(ks[6], (d, d)),
        "wk": _dense_init(ks[7], (d, d)),
        "wv": _dense_init(ks[8], (d, d)),
        "wg": _dense_init(ks[9], (d, d)),
        "wo": _dense_init(ks[10], (d, d)),
        "ln_x": jnp.ones((d,), jnp.float32),
        # channel-mix
        "mu_ck": 0.5 * jnp.ones((d,), jnp.float32),
        "mu_cr": 0.5 * jnp.ones((d,), jnp.float32),
        "cm_k": _dense_init(ks[11], (d, ff)),
        "cm_v": _dense_init(jax.random.fold_in(key, 99), (ff, d)),
        "cm_r": _dense_init(jax.random.fold_in(key, 98), (d, d)),
    }


def specs_rwkv6(cfg):
    del cfg
    return {
        "mu": P(None, None), "tm_w1": P("fsdp", None), "tm_w2": P(None, None, None),
        "w0": P(None), "dw1": P("fsdp", None), "dw2": P(None, None),
        "u": P(None, None),
        "wr": P("fsdp", "tp"), "wk": P("fsdp", "tp"), "wv": P("fsdp", "tp"),
        "wg": P("fsdp", "tp"), "wo": P("tp", "fsdp"), "ln_x": P(None),
        "mu_ck": P(None), "mu_cr": P(None),
        "cm_k": P("fsdp", "tp"), "cm_v": P("tp", "fsdp"), "cm_r": P("fsdp", "tp"),
    }


class RWKVCache(NamedTuple):
    x_tm: jax.Array    # (B, d) previous token input (time-mix shift)
    x_cm: jax.Array    # (B, d) previous token input (channel-mix shift)
    state: jax.Array   # (B, H, hd, hd) recurrent state (f32)


def init_rwkv_cache(batch, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return RWKVCache(
        x_tm=jnp.zeros((batch, d), dtype),
        x_cm=jnp.zeros((batch, d), dtype),
        state=jnp.zeros((batch, H, hd, hd), jnp.float32),
    )


def _shifted(x, x_prev):
    """(B,S,d) -> previous-token tensor, seeded with x_prev (B,d)."""
    return jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent token-shift for r,k,v,w,g. Returns 5 mixed tensors."""
    dx = xs - x
    base = x + dx * p["mu"][0]
    lora = jnp.tanh(base @ p["tm_w1"])                       # (B,S,5R)
    B_, S = x.shape[0], x.shape[1]
    lora = lora.reshape(B_, S, 5, LORA_R)
    adj = jnp.einsum("bsfr,frd->bsfd", lora, p["tm_w2"])     # (B,S,5,d)
    outs = []
    for i in range(5):
        m = p["mu"][i + 1] + adj[:, :, i, :]
        outs.append(x + dx * m)
    return outs                                              # xr, xk, xv, xw, xg


def _rkvwg(p, x, xs, cfg):
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    B_, S = x.shape[0], x.shape[1]
    r = (xr @ p["wr"]).reshape(B_, S, H, hd)
    k = (xk @ p["wk"]).reshape(B_, S, H, hd)
    v = (xv @ p["wv"]).reshape(B_, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    logw = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["dw1"]) @ p["dw2"])        # (B,S,d) < 0
    logw = jnp.maximum(logw, LOGW_CLAMP)   # shared decay floor (see wkv_chunked)
    logw = logw.reshape(B_, S, H, hd)
    return r, k, v, g, logw


LOGW_CLAMP = -2.0    # per-step decay floor inside a chunk: contributions
                     # below e^(CLAMP*Q) are numerically zero anyway, and the
                     # clamp keeps the factorized intra-chunk matmul in f32
                     # range (exp(|CLAMP|*Q) = e^64 << f32 max for Q=32).


def wkv_chunked(r, k, v, logw, u, *, q: int = 32, s0=None,
                remat_chunks: bool = True):
    """Chunked RWKV6 recurrence (factorized, matmul-friendly).

    r,k,v,logw: (B,S,H,hd) (logw = log decay, < 0); u: (H,hd).
    Returns (y (B,S,H,hd) f32, final state (B,H,hd,hd) f32).

    Intra-chunk scores use the exact factorization
        r_t.k_s * exp(cum_{t-1} - cum_s)
          = (r_t * exp(cum_{t-1} - cum_Q)) . (k_s * exp(cum_Q - cum_s))
    so the (Q,Q) score matrix comes from ONE (Q,hd)x(hd,Q) matmul per
    (batch, head) instead of materializing a (B,Q,Q,H,hd) tensor.  logw is
    clamped to LOGW_CLAMP to bound exp(cum_Q - cum_s).
    """
    B_, S, H, hd = r.shape
    nq = max(1, S // q)
    while S % nq:
        nq -= 1
    Q = S // nq

    def resh(t):
        return t.astype(jnp.float32).reshape((B_, nq, Q) + t.shape[2:]).swapaxes(0, 1)

    logw = jnp.maximum(logw.astype(jnp.float32), LOGW_CLAMP)  # idempotent guard
    rq, kq, vq, lwq = resh(r), resh(k), resh(v), resh(logw)
    uf = u.astype(jnp.float32)
    tri = (jnp.arange(Q)[:, None] > jnp.arange(Q)[None, :])[None, None]  # (1,1,t,s)

    def chunk(S0, inp):
        rc, kc, vc, lwc = inp                          # (B,Q,H,hd)
        cum = jnp.cumsum(lwc, axis=1)                  # cum_t = sum_{s<=t} lw_s
        cum_prev = cum - lwc
        tot = cum[:, -1:, :, :]                        # (B,1,H,hd)
        r_f = rc * jnp.exp(cum_prev - tot)             # <= r (decaying)
        k_f = kc * jnp.exp(tot - cum)                  # bounded by clamp
        scores = jnp.einsum("bthd,bshd->bhts", r_f, k_f)
        diag = jnp.einsum("bthd,bthd->bth", rc, uf[None, None] * kc)
        scores = jnp.where(tri, scores, 0.0)
        scores = scores + jnp.moveaxis(
            diag[:, :, None, :] * jnp.eye(Q)[None, :, :, None], 3, 1)
        y = jnp.einsum("bhts,bshe->bthe", scores, vc)
        # carried-state contribution: r_t * exp(cum_prev_t) @ S0
        y = y + jnp.einsum("bthd,bhde->bthe", rc * jnp.exp(cum_prev), S0)
        # state update: S = diag(exp(cum_Q)) S0 + sum_s exp(cum_Q - cum_s) k (x) v
        S_new = S0 * jnp.exp(tot[:, 0])[..., None] + jnp.einsum(
            "bshd,bshe->bhde", k_f, vc)
        return S_new, y

    if remat_chunks:
        chunk = jax.checkpoint(chunk)
    if s0 is None:
        s0 = jnp.zeros((B_, H, hd, hd), jnp.float32)
    s_fin, yq = jax.lax.scan(chunk, s0, (rq, kq, vq, lwq))
    y = yq.swapaxes(0, 1).reshape(B_, S, H, hd)
    return y, s_fin


def _group_norm(y, scale, H, eps=64e-5):
    """Per-head group norm used by RWKV (ln_x)."""
    B_, S, _, hd = y.shape
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    return yn.reshape(B_, S, H * hd) * scale


def time_mix(p, x, cfg, *, x_prev=None, s0=None, chunk: int = 32):
    """Full-sequence time-mix. Returns (out, (last_x, state))."""
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    xs = _shifted(x, x_prev if x_prev is not None else jnp.zeros_like(x[:, 0]))
    r, k, v, g, logw = _rkvwg(p, x, xs, cfg)
    y, s_fin = wkv_chunked(r, k, v, logw, p["u"], q=chunk, s0=s0)
    y = _group_norm(y, p["ln_x"], H).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, (x[:, -1, :], s_fin)


def channel_mix(p, x, cfg, *, x_prev=None):
    xs = _shifted(x, x_prev if x_prev is not None else jnp.zeros_like(x[:, 0]))
    xk = x + (xs - x) * p["mu_ck"]
    xr = x + (xs - x) * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"]), x[:, -1, :]


def apply_rwkv6(p, x, cfg, *, chunk: int = 32):
    """Train forward for one block (time-mix + channel-mix, pre-norm handled
    by the caller)."""
    tm, _ = time_mix(p, x, cfg, chunk=chunk)
    return tm


def rwkv6_decode(p, x, cfg, cache: RWKVCache):
    """One-token decode for the time-mix half. x: (B,1,d)."""
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    xs = cache.x_tm[:, None, :].astype(x.dtype)
    r, k, v, g, logw = _rkvwg(p, x, xs, cfg)
    r1, k1, v1, lw1 = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]   # (B,H,hd)
    S0 = cache.state
    kv = jnp.einsum("bhd,bhe->bhde", k1.astype(jnp.float32), v1.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", r1.astype(jnp.float32),
                   S0 + p["u"][None, :, :, None] * kv)
    S_new = S0 * jnp.exp(lw1.astype(jnp.float32))[..., None] + kv
    y = _group_norm(y[:, None], p["ln_x"], H).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, cache._replace(x_tm=x[:, 0, :].astype(cache.x_tm.dtype), state=S_new)


def channel_mix_decode(p, x, cfg, cache: RWKVCache):
    xs = cache.x_cm[:, None, :].astype(x.dtype)
    xk = x + (xs - x) * p["mu_ck"]
    xr = x + (xs - x) * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"])
    return out, cache._replace(x_cm=x[:, 0, :].astype(cache.x_cm.dtype))
