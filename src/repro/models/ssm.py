"""Mamba2 (SSD) block — chunked state-space dual form.

Faithful to Mamba2 [arXiv:2405.21060] as used by Zamba2 [arXiv:2411.15242]:
in_proj -> [z | xBC | dt], causal depthwise conv over xBC, scalar-decay
SSD per head, gated RMSNorm, out_proj.

Train/prefill uses the chunked SSD algorithm: O(S*Q) intra-chunk matmuls
plus an O(S/Q) sequential inter-chunk state recurrence (`lax.scan`), so
no O(S * hd * N) state tensor ever materializes per time step.  Decode is
a single-step state update carrying (conv_state, ssm_state).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init, rms_norm


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or (d_in // cfg.ssm_head_dim)
    G, N = 1, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    return d_in, heads, G, N, conv_dim


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_in, H, G, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * G * N + H
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                 math.log(1e-3), math.log(1e-1)))
    return {
        "in_proj": _dense_init(ks[0], (d, proj_out)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.d_conv, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),   # inverse-softplus init
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": _dense_init(ks[3], (d_in, d)),
    }


def specs_mamba2(cfg):
    del cfg
    return {
        "in_proj": P("fsdp", "tp"),
        "conv_w": P(None, "tp"),
        "conv_b": P("tp"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": P("tp"),
        "out_proj": P("tp", "fsdp"),
    }


class MambaCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_dim) trailing inputs
    ssm: jax.Array     # (B, H, hd, N) state


def init_mamba_cache(batch, cfg, dtype=jnp.float32):
    d_in, H, G, N, conv_dim = _dims(cfg)
    hd = d_in // H
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, H, hd, N), jnp.float32),
    )


def _split_proj(p, x, cfg):
    d_in, H, G, N, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in: d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, prev=None):
    """Depthwise causal conv. xBC: (B, S, C); w: (taps, C)."""
    taps = w.shape[0]
    pad = xBC if prev is None else jnp.concatenate([prev.astype(xBC.dtype), xBC], axis=1)
    if prev is None:
        pad = jnp.pad(xBC, ((0, 0), (taps - 1, 0), (0, 0)))
    S = xBC.shape[1]
    y = sum(pad[:, i: i + S, :] * w[i] for i in range(taps))
    return jax.nn.silu(y + b)


def _ssm_inputs(p, xBC, dt, cfg):
    """Returns xh (B,S,H,hd); Bm, Cm in GROUP form (B,S,N) (G=1 — all heads
    share B/C; never broadcast to heads before the chunk scan)."""
    d_in, H, G, N, conv_dim = _dims(cfg)
    hd = d_in // H
    B_, S = xBC.shape[0], xBC.shape[1]
    xh = xBC[..., :d_in].reshape(B_, S, H, hd)
    Bm = xBC[..., d_in: d_in + G * N].reshape(B_, S, N)
    Cm = xBC[..., d_in + G * N:].reshape(B_, S, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # (B,S,H)
    A = -jnp.exp(p["A_log"])                                             # (H,)
    dA = dt * A                                                          # (B,S,H) <= 0
    return xh, Bm, Cm, dt, dA


def ssd_chunked(xh, Bm, Cm, dt, dA, D, *, q: int = 128, h0=None,
                remat_chunks: bool = True):
    """Chunked SSD scan.  Shapes: xh (B,S,H,hd); Bm,Cm (B,S,N) group form;
    dt,dA (B,S,H).  Returns (y (B,S,H,hd), h_final (B,H,hd,N))."""
    B_, S, H, hd = xh.shape
    N = Bm.shape[-1]
    nq = max(1, S // q)
    while S % nq:
        nq -= 1
    Q = S // nq

    def r(t, extra=()):  # (B,S,...) -> (nq,B,Q,...)
        return t.reshape((B_, nq, Q) + t.shape[2:]).swapaxes(0, 1)

    # keep the big stacked xs in their input dtype; upcast per chunk in VMEM
    xq, Bq, Cq = r(xh), r(Bm), r(Cm)
    dtq, dAq = r(dt), r(dA)

    def chunk(h, inp):
        xc, Bc, Cc, dtc, dAc = inp          # (B,Q,H,hd),(B,Q,N),(B,Q,H)
        # decay path in f32 (cumsum of logs); token tensors stay in their
        # storage dtype so the big matmuls read bf16 with f32 accumulation
        # (perf iteration, EXPERIMENTS.md §Perf zamba train)
        cdt = xc.dtype
        dAc = dAc.astype(jnp.float32)
        cum = jnp.cumsum(dAc, axis=1)                                    # (B,Q,H)
        # intra-chunk: L[t,s] = exp(cum_t - cum_s) * (s <= t)
        diff = cum[:, :, None, :] - cum[:, None, :, :]                   # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))[None, :, :, None]
        L = jnp.where(tri, jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))                          # group form
        scores = (cb[..., None] * L).astype(cdt)                         # (B,Q,Q,H)
        xdt = xc * dtc.astype(cdt)[..., None]                            # (B,Q,H,hd)
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, xdt,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state h (B,H,hd,N)
        decay_t = jnp.exp(cum)                                           # (B,Q,H)
        y_inter = jnp.einsum("btn,bhdn->bthd", Cc.astype(jnp.float32),
                             h) * decay_t[..., None]
        # new state: h * exp(total) + sum_s exp(total - cum_s) * xdt_s (x) B_s
        total = cum[:, -1:, :]                                           # (B,1,H)
        w = jnp.exp(total - cum)                                         # (B,Q,H)
        dh = jnp.einsum("bshd,bsn,bsh->bhdn",
                        xdt.astype(jnp.float32),
                        Bc.astype(jnp.float32), w)
        h_new = h * jnp.exp(total[:, 0, :])[..., None, None] + dh
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((B_, H, hd, N), jnp.float32)
    if remat_chunks:
        # chunk-level remat: keep only the (B,H,hd,N) carry per chunk
        chunk = jax.checkpoint(chunk)
    h_fin, yq = jax.lax.scan(chunk, h0, (xq, Bq, Cq, dtq, dAq))
    y = yq.swapaxes(0, 1).reshape(B_, S, H, hd)
    y = y + xh.astype(jnp.float32) * D[None, None, :, None]
    return y, h_fin


def apply_mamba2(p, x, cfg, *, chunk: int = 128):
    """Train/prefill forward. x: (B,S,D) -> (B,S,D)."""
    d_in, H, G, N, conv_dim = _dims(cfg)
    z, xBC, dt = _split_proj(p, x, cfg)
    xBC = _causal_conv(xBC, p["conv_w"].astype(xBC.dtype), p["conv_b"].astype(xBC.dtype))
    xh, Bm, Cm, dtf, dA = _ssm_inputs(p, xBC, dt, cfg)
    y, _ = ssd_chunked(xh, Bm, Cm, dtf, dA, p["D"], q=chunk)
    y = y.reshape(x.shape[0], x.shape[1], d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_prefill(p, x, cfg, cache: MambaCache, *, chunk: int = 128):
    """Prefill that also returns the final recurrent state + conv tail."""
    d_in, H, G, N, conv_dim = _dims(cfg)
    z, xBC, dt = _split_proj(p, x, cfg)
    tail = xBC[:, -(cfg.d_conv - 1):, :]
    xBC = _causal_conv(xBC, p["conv_w"].astype(xBC.dtype), p["conv_b"].astype(xBC.dtype))
    xh, Bm, Cm, dtf, dA = _ssm_inputs(p, xBC, dt, cfg)
    y, h_fin = ssd_chunked(xh, Bm, Cm, dtf, dA, p["D"], q=chunk, h0=cache.ssm)
    y = y.reshape(x.shape[0], x.shape[1], d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    new_cache = MambaCache(conv=tail.astype(cache.conv.dtype), ssm=h_fin)
    return y @ p["out_proj"], new_cache


def mamba2_decode(p, x, cfg, cache: MambaCache):
    """One-step decode. x: (B,1,D)."""
    d_in, H, G, N, conv_dim = _dims(cfg)
    hd = d_in // H
    z, xBC, dt = _split_proj(p, x, cfg)
    window = jnp.concatenate([cache.conv.astype(xBC.dtype), xBC], axis=1)  # (B,d_conv,C)
    w = p["conv_w"].astype(xBC.dtype)
    y_conv = jnp.einsum("btc,tc->bc", window, w) + p["conv_b"].astype(xBC.dtype)
    xBC1 = jax.nn.silu(y_conv)[:, None, :]                                # (B,1,C)
    xh, Bm, Cm, dtf, dA = _ssm_inputs(p, xBC1, dt, cfg)
    xdt = (xh * dtf[..., None])[:, 0]                                     # (B,H,hd)
    decay = jnp.exp(dA[:, 0])                                             # (B,H)
    h = cache.ssm * decay[..., None, None] + jnp.einsum(
        "bhd,bn->bhdn", xdt.astype(jnp.float32), Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhdn->bhd", Cm[:, 0].astype(jnp.float32), h)
    y = y + xh[:, 0].astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    new_cache = MambaCache(conv=window[:, 1:, :].astype(cache.conv.dtype), ssm=h)
    return y @ p["out_proj"], new_cache
