"""Model zoo: a uniform Model facade over the transformer assembly.

`build_model(cfg)` returns a `Model` with init / loss / prefill / decode
plus shape helpers used by the launcher's ``input_specs`` and the
profiler's workload metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params ---------------------------------------------------------
    def init(self, key):
        return T.init_params(self.cfg, key)

    def param_specs(self):
        return T.param_specs(self.cfg)

    def abstract_params(self, dtype=jnp.bfloat16):
        return T.abstract_params(self.cfg, dtype)

    # -- compute --------------------------------------------------------
    def loss(self, params, batch, *, shard=T.ShardingHints(), remat=True):
        return T.train_loss(params, self.cfg, batch, shard=shard, remat=remat)

    def forward(self, params, batch, *, shard=T.ShardingHints()):
        return T.forward(params, self.cfg, batch, shard=shard)

    def prefill(self, params, batch, cache, *, shard=T.ShardingHints()):
        return T.prefill(params, self.cfg, batch, cache, shard=shard)

    def decode_step(self, params, token, cache, *, shard=T.ShardingHints()):
        return T.decode_step(params, self.cfg, token, cache, shard=shard)

    def init_cache(self, batch_size, max_len, *, dtype=jnp.bfloat16,
                   window: Optional[int] = None):
        return T.init_cache(self.cfg, batch_size, max_len, dtype=dtype,
                            window=window)

    # -- input builders ---------------------------------------------------
    def make_train_batch(self, key, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        out = {
            "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size,
                                         jnp.int32),
        }
        if cfg.frontend == "audio":
            out["frames"] = jax.random.normal(
                ks[2], (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        if cfg.frontend == "vision":
            fd = cfg.frontend_dim or cfg.d_model
            out["patches"] = jax.random.normal(
                ks[2], (batch, min(cfg.vision_patches, seq), fd), jnp.float32)
        return out

    def train_batch_specs(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        out = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        if cfg.frontend == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq_len, cfg.d_model), dtype)
        if cfg.frontend == "vision":
            fd = cfg.frontend_dim or cfg.d_model
            out["patches"] = jax.ShapeDtypeStruct(
                (batch, min(cfg.vision_patches, seq), fd), dtype)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
