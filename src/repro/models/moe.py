"""Mixture-of-Experts layer (Mixtral/DBRX style top-k routing).

Design notes (TPU/GSPMD-aware):

* Dispatch is *per batch row* and *per sequence chunk*: we scan over the
  sequence in chunks and build a (B, S_c, E, C) dispatch tensor with
  capacity C = ceil(S_c * top_k * cf / E).  All dispatch tensors keep the
  batch dim leading, so GSPMD shards every intermediate over the batch
  axes and never all-gathers tokens.  Chunking bounds both the dispatch
  einsum FLOPs (~cf * top_k/E relative overhead) and its memory.
* Expert weights are (E, D, F) with F tensor-parallel over "tp" and D
  over "fsdp"; each device computes all experts on its batch shard with
  its F-slice (expert compute shards over tp exactly like a dense MLP).
* Dropping semantics: per-(row, chunk) capacity; dropped assignments
  contribute nothing (combine weights are zero), matching GShard/Switch.
* Aux load-balance loss (Switch style): E * sum_e f_e * p_e.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init


def init_moe(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    sh = cfg.expert_shards
    Ev, Fv = E * sh, ff // sh       # virtual experts (F-split; sh=1 = off)
    assert ff % sh == 0
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E)),
        "w_gate": _dense_init(ks[1], (Ev, d, Fv), in_axis=1),
        "w_up": _dense_init(ks[2], (Ev, d, Fv), in_axis=1),
        "w_down": _dense_init(ks[3], (Ev, Fv, d), in_axis=1),
    }


def specs_moe(cfg):
    del cfg
    return {
        "router": P(None, None),
        "w_gate": P("exp", "fsdp", "tp"),
        "w_up": P("exp", "fsdp", "tp"),
        "w_down": P("exp", "tp", "fsdp"),
    }


def _route(router_w, x, top_k: int):
    """x: (..., D) -> (top-k ids, normalized gates, full probs)."""
    logits = (x.astype(jnp.float32) @ router_w)               # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)                  # (..., K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return ids, gates, probs


def _dispatch_combine(ids, gates, E: int, C: int, ks: int = 1):
    """Build dispatch/combine tensors for one chunk.

    ids, gates: (B, S, K).  Returns dispatch (B,S,E*ks,C) bool-ish f32 and
    combine (B,S,E*ks,C) f32 (gate-weighted).  ks > 1 repeats every
    assignment across the ks F-split virtual shards of its expert (SwiGLU
    sums exactly over F, so gate-weighted shard outputs add to the full
    expert output).
    Position within expert = running count over (s, k) order per row.
    """
    B, S, K = ids.shape
    oh = jax.nn.one_hot(ids, E, dtype=jnp.float32)            # (B,S,K,E)
    if ks > 1:
        oh = jnp.repeat(oh, ks, axis=-1)                      # (B,S,K,E*ks)
        E = E * ks
    flat = oh.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                     # (B,S*K,E) position
    pos = jnp.sum(pos * flat, axis=-1)                        # (B,S*K)
    keep = pos < C
    posc = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # (B,S*K,E,C)
    dc = flat[..., :, None] * posc[..., None, :]
    dc = dc.reshape(B, S, K, E, C)
    dispatch = jnp.sum(dc, axis=2)                            # (B,S,E,C)
    combine = jnp.sum(dc * gates[..., None, None], axis=2)
    return dispatch, combine


def apply_moe_ep(p, x, cfg, *, mesh, ep_axis: str = "data",
                 batch_axes=("data",), tp_axis: str = "model",
                 chunk: int = 4096):
    """Expert-parallel MoE: tokens move (all-to-all), weights stay resident.

    Requires n_experts == mesh.shape[ep_axis] (e.g. dbrx's 16 experts on
    the 16-way data axis).  Layout (EXPERIMENTS.md §Perf pair 2 it. 6):

      * x arrives sequence-sharded over the tp axis (the residual's
        layout), so each (data, model) rank dispatches only its own
        S-chunk — no duplicated dispatch compute;
      * token blocks all-to-all over the ep axis to the expert owner;
      * the owner all-gathers tokens over tp, runs the F-tensor-parallel
        expert FFN, and psum_scatters the partial outputs back to each
        tp rank's own token chunk (one reduce, half an all-reduce);
      * blocks all-to-all back and combine locally.

    Per-step weight traffic of the FSDP path disappears entirely; the
    moved bytes are capacity-padded tokens instead.
    """
    from jax.sharding import PartitionSpec
    B, S, D = x.shape
    E, K, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    ksh = cfg.expert_shards
    Ev = E * ksh
    assert Ev == mesh.shape[ep_axis], (Ev, dict(mesh.shape))
    M = mesh.shape.get(tp_axis, 1)
    dtype = x.dtype

    ids_all, gates_all, probs_all = _route(p["router"], x, K)
    frac = jnp.mean(jax.nn.one_hot(ids_all[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    prob = jnp.mean(probs_all, axis=(0, 1))
    aux = E * jnp.sum(frac * prob) * cfg.router_aux_loss

    manual = tuple(dict.fromkeys((ep_axis, tp_axis) + tuple(batch_axes)))
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
    seq_ok = S % M == 0

    def local_fn(xb, idb, gtb, wg, wu, wd):
        # xb: (B_loc, S_loc, D); wg/wu: (1, D, F_loc); wd: (1, F_loc, D)
        Bl, Sl, _ = xb.shape
        C = max(K, int(math.ceil(Sl * K * cf / E)))
        dispatch, combine = _dispatch_combine(idb, gtb, E, C, ksh)
        send = jnp.einsum("bsd,bsec->ebcd", xb, dispatch.astype(xb.dtype))
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=True)              # (E_src,Bl,C,D)
        toks = jax.lax.all_gather(recv, tp_axis, axis=0, tiled=True)
        flat = toks.reshape(-1, D)                         # (M*E*Bl*C, D)
        h = jax.nn.silu(flat @ wg[0]) * (flat @ wu[0])     # F_loc columns
        out = (h @ wd[0]).reshape((M * Ev,) + recv.shape[1:])  # partial/tp
        red = jax.lax.psum_scatter(out, tp_axis, scatter_dimension=0,
                                   tiled=True)             # (E_src,Bl,C,D)
        back = jax.lax.all_to_all(red.astype(xb.dtype), ep_axis,
                                  split_axis=0, concat_axis=0, tiled=True)
        y = jnp.einsum("ebcd,bsec->bsd", back, combine.astype(xb.dtype))
        return y

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(PartitionSpec(bspec, tp_axis if seq_ok else None, None),
                  PartitionSpec(bspec, tp_axis if seq_ok else None, None),
                  PartitionSpec(bspec, tp_axis if seq_ok else None, None),
                  PartitionSpec(ep_axis, None, tp_axis),
                  PartitionSpec(ep_axis, None, tp_axis),
                  PartitionSpec(ep_axis, tp_axis, None)),
        out_specs=PartitionSpec(bspec, tp_axis if seq_ok else None, None),
        axis_names=set(manual),
    )
    y = fn(x, ids_all, gates_all,
           p["w_gate"].astype(dtype), p["w_up"].astype(dtype),
           p["w_down"].astype(dtype))
    return y, aux


def apply_moe(p, x, cfg, *, chunk: int = 512, w_specs=(None, None)):
    """x: (B, S, D) -> (y, aux_loss).

    w_specs: resolved PartitionSpecs for the bf16 expert weights after the
    explicit once-per-layer gather (perf iteration, EXPERIMENTS.md §Perf:
    without this, the chunk-rematted scan re-gathered the f32 master
    weights PER CHUNK, ~7 TB of ICI bytes per mixtral train step)."""
    B, S, D = x.shape
    E, K, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    ksh = cfg.expert_shards
    dtype = x.dtype
    # adaptive chunking (perf iteration, EXPERIMENTS.md §Perf): under
    # gradient accumulation the per-microbatch batch is small, so one
    # 4096-token chunk is affordable — and every extra chunk costs a
    # per-chunk all-reduce of the expert weight-gradient partials in the
    # backward pass (~1 TB/chunk/step for mixtral at 16 microbatches).
    chunk = max(chunk, min(S, 4096))

    ids_all, gates_all, probs_all = _route(p["router"], x, K)

    # Switch aux loss over the full sequence (f32)
    frac = jnp.mean(jax.nn.one_hot(ids_all[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    prob = jnp.mean(probs_all, axis=(0, 1))
    aux = E * jnp.sum(frac * prob) * cfg.router_aux_loss

    n = max(1, S // chunk)
    while S % n:
        n -= 1
    Sc = S // n
    C = max(K, int(math.ceil(Sc * K * cf / E)))

    xs = x.reshape(B, n, Sc, D).swapaxes(0, 1)                # (n,B,Sc,D)
    ids_c = ids_all.reshape(B, n, Sc, K).swapaxes(0, 1)
    gates_c = gates_all.reshape(B, n, Sc, K).swapaxes(0, 1)

    # cast the SHARD to bf16 first, then gather once per layer (fsdp axis
    # dropped by the hint spec); the chunk scan closes over gathered bf16
    wg = p["w_gate"].astype(dtype)
    wu = p["w_up"].astype(dtype)
    wd = p["w_down"].astype(dtype)
    w_in_spec, w_out_spec = w_specs
    if w_in_spec is not None:
        wg = jax.lax.with_sharding_constraint(wg, w_in_spec)
        wu = jax.lax.with_sharding_constraint(wu, w_in_spec)
    if w_out_spec is not None:
        wd = jax.lax.with_sharding_constraint(wd, w_out_spec)

    def body(carry, inp):
        xc, idc, gtc = inp                                    # (B,Sc,D),(B,Sc,K)
        dispatch, combine = _dispatch_combine(idc, gtc, E, C, ksh)
        xe = jnp.einsum("bsd,bsec->becd", xc, dispatch.astype(dtype))
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
        h = h * jnp.einsum("becd,edf->becf", xe, wu)
        oe = jnp.einsum("becf,efd->becd", h, wd)
        yc = jnp.einsum("becd,bsec->bsd", oe, combine.astype(dtype))
        return carry, yc

    if n == 1:
        one = jax.checkpoint(lambda inp: body(0, inp)[1])
        y = one((xs[0], ids_c[0], gates_c[0])).reshape(B, S, D)
        return y, aux
    # chunk-level remat: backward recomputes dispatch/expert activations
    # instead of saving (n, B, E, C, F) intermediates per chunk
    body = jax.checkpoint(body)
    _, ys = jax.lax.scan(body, 0, (xs, ids_c, gates_c))       # (n,B,Sc,D)
    y = ys.swapaxes(0, 1).reshape(B, S, D)
    return y, aux
