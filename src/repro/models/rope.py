"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim/2 frequency bands into (temporal, height,
width) sections; each section rotates by its own coordinate.  Text tokens
use t == h == w == position, so M-RoPE degrades gracefully to 1-D RoPE on
pure text [arXiv:2409.12191].
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    dim = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return theta ** (-2.0 * dim / head_dim)          # (hd/2,)


def _rotate(x, cos, sin):
    # x: (..., hd) with interleaved halves [x1; x2]
    hd = x.shape[-1]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    if theta <= 0:
        return x
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                          # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_m_rope(x, positions_thw, theta: float,
                 sections: Tuple[int, int, int]):
    """x: (B, S, H, hd); positions_thw: (B, S, 3) int32 (t, h, w coords).

    sections are frequency-band counts summing to hd/2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                             # (hd/2,)
    # section id per frequency band: 0 -> t, 1 -> h, 2 -> w
    sec = jnp.concatenate([
        jnp.full((sections[0],), 0), jnp.full((sections[1],), 1),
        jnp.full((sections[2],), 2)]).astype(jnp.int32)       # (hd/2,)
    coords = positions_thw.astype(jnp.float32)[..., sec]      # (B, S, hd/2)
    ang = coords * freqs                                       # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_positions_thw(positions):
    """Text tokens: t == h == w == pos. positions: (B, S) -> (B, S, 3)."""
    return jnp.stack([positions, positions, positions], axis=-1)


def vision_positions_thw(batch: int, n_patches: int, t0: int = 0):
    """Patch grid coordinates for the VLM stub: one frame, sqrt grid."""
    side = max(1, int(n_patches ** 0.5))
    idx = jnp.arange(n_patches)
    h = idx // side
    w = idx % side
    t = jnp.full((n_patches,), t0)
    thw = jnp.stack([t, h, w], axis=-1)                        # (P, 3)
    return jnp.broadcast_to(thw[None], (batch, n_patches, 3)).astype(jnp.int32)
