"""GQA attention: chunked (memory-efficient) prefill/train path + cached
decode path.

The train/prefill path scans over query chunks with an online-softmax
accumulator so (Sq, Skv) score matrices never materialize for long
sequences — the pure-jnp analogue of the Pallas ``flash_attention``
kernel (which `repro.kernels.flash_attention` provides for TPU).

Supports: GQA (n_kv < n_heads), optional QKV bias, qk_norm (per-head
RMSNorm on q/k as in Qwen3), causal or bidirectional masks, sliding
windows, cross-attention, and single-token decode against a KV cache
(optionally a rolling window buffer for SWA).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import rope as rope_lib
from repro.models.layers import _dense_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd)),
        "wk": _dense_init(ks[1], (d, KV * hd)),
        "wv": _dense_init(ks[2], (d, KV * hd)),
        "wo": _dense_init(ks[3], (H * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    del cross
    return p


def specs_attention(cfg, *, cross: bool = False):
    del cross
    p = {"wq": P("fsdp", "tp"), "wk": P("fsdp", "tp"), "wv": P("fsdp", "tp"),
         "wo": P("tp", "fsdp")}
    if cfg.qkv_bias:
        p.update(bq=P("tp"), bk=P("tp"), bv=P("tp"))
    if cfg.qk_norm:
        p.update(q_norm=P(None), k_norm=P(None))
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def _project_qkv(p, x, x_kv, cfg):
    B, Sq, _ = x.shape
    Skv = x_kv.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _apply_positions(q, k, positions, kv_positions, cfg, positions_thw=None,
                     kv_positions_thw=None):
    if cfg.rope_theta <= 0:
        return q, k
    if cfg.m_rope:
        if positions_thw is None:
            positions_thw = rope_lib.text_positions_thw(positions)
        if kv_positions_thw is None:
            kv_positions_thw = rope_lib.text_positions_thw(kv_positions)
        q = rope_lib.apply_m_rope(q, positions_thw, cfg.rope_theta, cfg.m_rope_sections)
        k = rope_lib.apply_m_rope(k, kv_positions_thw, cfg.rope_theta, cfg.m_rope_sections)
    else:
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k


# ---------------------------------------------------------------------------
# Chunked blockwise attention core (pure jnp oracle of the Pallas kernel)
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                        window: Optional[int], q_chunk: int = 1024):
    """Online-softmax attention scanning over query chunks.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); positions: (B, S*) int32.
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    qg = (q * scale).reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    n = max(1, Sq // q_chunk)
    while Sq % n:
        n -= 1
    C = Sq // n
    q_chunks = qg.reshape(B, n, C, KV, G, hd).swapaxes(0, 1)            # (n,B,C,KV,G,hd)
    qpos_chunks = q_positions.reshape(B, n, C).swapaxes(0, 1)           # (n,B,C)

    def one_chunk(carry, xc):
        qc, qp = xc                                                     # (B,C,KV,G,hd),(B,C)
        s = jnp.einsum("bckgd,bskd->bckgs", qc, kf)                     # (B,C,KV,G,Skv)
        mask = jnp.ones((), jnp.bool_)
        kvp = kv_positions[:, None, None, None, :]                      # (B,1,1,1,Skv)
        qpp = qp[:, :, None, None, None]                                # (B,C,1,1,1)
        if causal:
            mask = kvp <= qpp
        if window is not None:
            mask = mask & (kvp > qpp - window)
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.maximum(m, NEG_INF)                                     # guard all-masked rows
        e = jnp.exp(s - m)
        z = jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bckgs,bskd->bckgd", e / jnp.maximum(z, 1e-30), vf)
        return carry, o

    _, outs = jax.lax.scan(one_chunk, 0, (q_chunks, qpos_chunks))       # (n,B,C,KV,G,hd)
    out = outs.swapaxes(0, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def kv_blockwise_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                           window: Optional[int], kv_chunk: int = 1024,
                           seq_spec: Optional[P] = None):
    """Online-softmax attention scanning over KV chunks.

    Unlike q-chunking, the query (and all accumulators) keep their full
    sequence dim, so a sequence-sharded residual stays sharded through the
    scan under GSPMD — per-device score buffers are (B, Sq/shards, H, Ck).
    The jnp analogue of the Pallas flash kernel's kv-sequential axis.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, KV, G, hd).astype(jnp.float32)

    n = max(1, Skv // kv_chunk)
    while Skv % n:
        n -= 1
    Ck = Skv // n
    kc = k.astype(jnp.float32).reshape(B, n, Ck, KV, hd).swapaxes(0, 1)
    vc = v.astype(jnp.float32).reshape(B, n, Ck, KV, hd).swapaxes(0, 1)
    pc = kv_positions.reshape(B, n, Ck).swapaxes(0, 1)
    qpp = q_positions[:, :, None, None, None]                 # (B,Sq,1,1,1)

    # keep the (sharded) q sequence dim pinned through the scan carry
    bspec = seq_spec[0] if seq_spec is not None and len(seq_spec) else None
    sspec = seq_spec[1] if seq_spec is not None and len(seq_spec) > 1 else None
    spec4 = P(bspec, sspec, None, None) if seq_spec is not None else None
    spec5 = P(bspec, sspec, None, None, None) if seq_spec is not None else None

    def pin(m, l, acc):
        if seq_spec is None:
            return m, l, acc
        return (jax.lax.with_sharding_constraint(m, spec4),
                jax.lax.with_sharding_constraint(l, spec4),
                jax.lax.with_sharding_constraint(acc, spec5))

    def step(carry, xc):
        m, l, acc = carry
        kb, vb, pb = xc                                       # (B,Ck,KV,hd),(B,Ck)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kb)           # (B,Sq,KV,G,Ck)
        kvp = pb[:, None, None, None, :]
        mask = kvp >= 0
        if causal:
            mask &= kvp <= qpp
        if window is not None:
            mask &= kvp > qpp - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)[..., None]
        p = jnp.exp(s - m_new[..., None])
        l_new = alpha[..., 0] * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha + jnp.einsum("bqkgs,bskd->bqkgd", p, vb)
        return pin(m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, pin(m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def full_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                   window: Optional[int], kv_valid_len=None, seq_spec=None,
                   kv_heads_major: bool = False):
    """Un-chunked reference path (decode / short sequences).

    With seq_spec (the residual's (batch, seq, ...) spec), pins the
    canonical orientation: q stays sequence-sharded, k/v replicate over
    the sequence axis, scores shard on the q dim — prevents GSPMD from
    flip-flopping between q- and kv-sharded layouts inside scans.
    """
    B, Sq, H, hd = q.shape
    if kv_heads_major:
        _, KV, Skv, _ = k.shape
    else:
        _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kf, vf = k, v
    if seq_spec is not None and len(seq_spec) > 1:
        b, s = seq_spec[0], seq_spec[1]
        qg = jax.lax.with_sharding_constraint(qg, P(b, s, None, None, None))
        kf = jax.lax.with_sharding_constraint(kf, P(b, None, None, None))
        vf = jax.lax.with_sharding_constraint(vf, P(b, None, None, None))
    kv_eq = "bksd" if kv_heads_major else "bskd"
    # keep k in bf16 on the wire; accumulate in f32 (MXU-native on TPU)
    s = jnp.einsum(f"bqkgd,{kv_eq}->bqkgs", qg, kf,
                   preferred_element_type=jnp.float32)
    if seq_spec is not None and len(seq_spec) > 1:
        s = jax.lax.with_sharding_constraint(
            s, P(seq_spec[0], seq_spec[1], None, None, None))
    k, v = kf, vf
    kvp = kv_positions[:, None, None, None, :]
    qpp = q_positions[:, :, None, None, None]
    mask = jnp.ones(s.shape, jnp.bool_) & (kvp >= 0)   # -1 = unwritten slot
    if causal:
        mask = mask & (kvp <= qpp)
    if window is not None:
        mask = mask & (kvp > qpp - window)
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(Skv)[None, None, None, None, :]
                       < kv_valid_len[:, None, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # v upcast on the fly: XLA absorbs the convert into the value dot's
    # operand stream; feeding bf16 directly made layout assignment pick a
    # transposed layout for the cached v and re-copy the full carried cache
    # every layer (perf iteration #2b, EXPERIMENTS.md §Perf)
    o = jnp.einsum(f"bqkgs,{kv_eq}->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["k", "v", "pos"], meta_fields=["window"])
@dataclasses.dataclass
class KVCache:
    """Decode KV cache, stored HEADS-MAJOR: (B, KV, S_buf, hd).

    Layout note (perf iteration #1, EXPERIMENTS.md §Perf): with the naive
    (B, S, KV, hd) layout the decode layer-loop carried the cache in a
    layout that disagreed between the score contraction (wants hd
    innermost) and the value contraction (wants S second-to-last), and
    XLA inserted two full-cache layout copies PER LAYER per step.  With
    (B, KV, S, hd) both dots are layout-natural and the carry stays put.
    """
    k: jax.Array          # (B, KV, S_buf, hd)  [stacked (L, B, ...) across layers]
    v: jax.Array          # (B, KV, S_buf, hd)
    pos: jax.Array        # (B,) next absolute position to write
    window: int = 0       # 0 = linear buffer; >0 = rolling SWA buffer (static)

    @property
    def rolling(self) -> bool:
        return self.window > 0

    def _replace(self, **kw) -> "KVCache":
        return dataclasses.replace(self, **kw)


def init_kv_cache(batch, max_len, cfg, *, window: Optional[int] = None,
                  dtype=jnp.bfloat16):
    """window: cap the buffer at the sliding window (rolling writes)."""
    buf = max_len if window is None else min(max_len, window)
    shape = (batch, cfg.n_kv_heads, buf, cfg.hd)      # heads-major (see KVCache)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((batch,), jnp.int32),
        window=0 if window is None else buf,
    )


def update_kv_cache(cache: KVCache, k_new, v_new):
    """Append one token (decode step). k_new: (B, 1, KV, hd).

    All sequences decode in lockstep in our serving engine, so the write
    index is a single dynamic scalar — XLA SPMD partitions a scalar-start
    dynamic-update-slice along a sequence-sharded buffer in place, with no
    collectives (verified in the dry-run HLO)."""
    B, buf = cache.k.shape[0], cache.k.shape[2]
    pos0 = jnp.max(cache.pos)
    idx = pos0 % buf if cache.rolling else jnp.minimum(pos0, buf - 1)

    def write(bufarr, new):
        # new: (B, 1, KV, hd) -> heads-major (B, KV, 1, hd)
        return jax.lax.dynamic_update_slice(
            bufarr, new.swapaxes(1, 2).astype(bufarr.dtype), (0, 0, idx, 0))

    return cache._replace(k=write(cache.k, k_new),
                          v=write(cache.v, v_new),
                          pos=cache.pos + 1)


def cache_kv_positions(cache: KVCache):
    """Absolute position of every buffer slot (rolling-aware). (B, S_buf)."""
    B, buf = cache.k.shape[0], cache.k.shape[2]
    slots = jnp.arange(buf)[None, :]                                    # (1, buf)
    if not cache.rolling:
        return jnp.broadcast_to(slots, (B, buf))
    # slot s holds absolute position: the largest p < pos with p % buf == s
    pos = cache.pos[:, None]
    cand = pos - 1 - ((pos - 1 - slots) % buf)
    return jnp.where(cand >= 0, cand, -1)                               # -1 = never written


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attention_forward(p, x, cfg, *, positions=None, positions_thw=None,
                      causal=True, x_kv=None, kv_positions=None,
                      q_chunk: int = 1024, act_spec=None, seq_spec=None):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    cross = x_kv is not None
    xkv = x if x_kv is None else x_kv
    if kv_positions is None:
        kv_positions = (positions if not cross else jnp.broadcast_to(
            jnp.arange(xkv.shape[1])[None], (B, xkv.shape[1])).astype(jnp.int32))
    q, k, v = _project_qkv(p, x, xkv, cfg)
    if not cross:  # RoPE only applies to self-attention in our archs
        q, k = _apply_positions(q, k, positions, kv_positions, cfg,
                                positions_thw=positions_thw,
                                kv_positions_thw=positions_thw)
    window = cfg.sliding_window if (causal and not cross) else None
    if S <= 4096 and xkv.shape[1] <= 4096:
        o = full_attention(q, k, v, q_positions=positions,
                           kv_positions=kv_positions,
                           causal=causal and not cross, window=window,
                           seq_spec=seq_spec)
    else:
        # long sequences: kv-sequential online softmax keeps the (sharded)
        # q sequence dim intact (see kv_blockwise_attention)
        o = kv_blockwise_attention(q, k, v, q_positions=positions,
                                   kv_positions=kv_positions,
                                   causal=causal and not cross, window=window,
                                   kv_chunk=max(q_chunk, 512),
                                   seq_spec=seq_spec)
    if act_spec is not None:
        o = jax.lax.with_sharding_constraint(o, act_spec)
    return o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]


def attention_decode(p, x, cfg, cache: KVCache, *, positions_thw=None,
                     cross_kv=None):
    """One-token decode. x: (B, 1, d). Returns (y, new_cache)."""
    B = x.shape[0]
    positions = cache.pos[:, None]                                       # (B, 1)
    if cross_kv is not None:
        k, v = cross_kv
        q = (x @ p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        q = q.reshape(B, 1, cfg.n_heads, cfg.hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        kvp = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        o = full_attention(q, k, v, q_positions=positions, kv_positions=kvp,
                           causal=False, window=None)
        return o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"], cache, None
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    q, k_new = _apply_positions(q, k_new, positions, positions, cfg,
                                positions_thw=positions_thw,
                                kv_positions_thw=positions_thw)
    cache = update_kv_cache(cache, k_new, v_new)
    kv_pos = cache_kv_positions(cache)
    valid = None if cache.rolling else cache.pos
    o = full_attention(q, cache.k, cache.v, q_positions=positions,
                       kv_positions=kv_pos, causal=True,
                       window=cfg.sliding_window,
                       kv_valid_len=valid, kv_heads_major=True)
    out = o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    # expose the written token column so callers can write back just that
    # column into a stacked cache (heads-major (B, KV, 1, hd))
    token_kv = (k_new.swapaxes(1, 2), v_new.swapaxes(1, 2))
    return out, cache, token_kv


def attention_prefill(p, x, cfg, cache: KVCache, *, positions=None,
                      positions_thw=None, q_chunk: int = 1024, seq_spec=None):
    """Fused prompt pass: one set of QKV projections used both for the
    attention output and to fill the decode cache.  Returns (out, cache)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg)
    q, k = _apply_positions(q, k, positions, positions, cfg,
                            positions_thw=positions_thw,
                            kv_positions_thw=positions_thw)
    window = cfg.sliding_window
    if S <= 4096:
        o = full_attention(q, k, v, q_positions=positions,
                           kv_positions=positions, causal=True, window=window,
                           seq_spec=seq_spec)
    else:
        o = kv_blockwise_attention(q, k, v, q_positions=positions,
                                   kv_positions=positions, causal=True,
                                   window=window, kv_chunk=max(q_chunk, 512),
                                   seq_spec=seq_spec)
    out = o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    cache = _store_prefix_kv(cache, k, v, S)
    return out, cache


def _store_prefix_kv(cache: KVCache, k, v, S: int) -> KVCache:
    """Write a full prompt's (rotated) K/V into the cache buffer
    (heads-major layout)."""
    B = k.shape[0]
    buf = cache.k.shape[2]
    take = min(S, buf)
    kw = k[:, -take:].swapaxes(1, 2)     # (B, KV, take, hd)
    vw = v[:, -take:].swapaxes(1, 2)
    if buf > take:
        pad = ((0, 0), (0, 0), (0, buf - take), (0, 0))
        kw, vw = jnp.pad(kw, pad), jnp.pad(vw, pad)
    if cache.rolling and S > buf:
        kw = jnp.roll(kw, shift=S % buf, axis=2)
        vw = jnp.roll(vw, shift=S % buf, axis=2)
    return cache._replace(k=kw.astype(cache.k.dtype), v=vw.astype(cache.v.dtype),
                          pos=jnp.full((B,), S, jnp.int32))


def prefill_kv(p, x, cfg, cache: KVCache, *, positions=None,
               positions_thw=None):
    """Run projections over a prompt and fill the cache (no attention output).

    Used by serve prefill when only the cache (not hidden states) is needed
    downstream; the normal prefill path uses attention_forward and fills the
    cache with the same k/v.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    _, k, v = _project_qkv(p, x, x, cfg)
    _, k = _apply_positions(k, k, positions, positions, cfg,
                            positions_thw=positions_thw,
                            kv_positions_thw=positions_thw)
    del B
    return _store_prefix_kv(cache, k, v, S)
