"""Composable model assembly for all assigned architecture families.

Families -> assembly strategy:
  dense/moe/vlm ("attn" pattern)  : stacked params + lax.scan over layers
  ssm ("rwkv6" / "mamba2")        : stacked params + lax.scan
  hybrid (zamba2)                 : python loop (shared-attn interleave)
  encdec (whisper)                : encoder scan + decoder scan (w/ cross-attn)

Params are nested dicts; per-layer blocks are stacked along a leading L
axis.  ``param_specs`` mirrors the structure with logical PartitionSpecs
(stacked blocks get a leading None axis).

Entry points:
  init_params / param_specs / abstract_params
  train_loss(params, cfg, batch)                     -> scalar loss
  forward(params, cfg, batch)                        -> last hidden states
  init_cache(cfg, batch, max_len, dtype)             -> decode cache
  prefill(params, cfg, batch, cache)                 -> (logits_last, cache)
  decode_step(params, cfg, token, cache)             -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rope as rope_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache


@dataclasses.dataclass(frozen=True)
class ShardingHints:
    """Resolved PartitionSpecs injected by the launch layer (None on CPU)."""
    residual: Optional[P] = None      # (B, S, D)
    logits: Optional[P] = None        # (B, s_chunk, V)
    kv: Optional[P] = None            # (B, S, KV, hd)
    # MoE: specs for the per-layer bf16 expert weights AFTER the explicit
    # once-per-layer gather (fsdp dropped, tp kept) — see moe.apply_moe
    moe_w_in: Optional[P] = None      # (E, D, F)
    moe_w_out: Optional[P] = None     # (E, F, D)
    # expert parallelism (tokens move): (mesh, ep_axis, batch_axes) or None
    moe_ep: Optional[tuple] = None


def _c(x, spec):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Per-block init / specs
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, *, cross: bool = False):
    ks = jax.random.split(key, 8)
    ln_bias = cfg.family == "encdec"
    if kind == "attn":
        p = {
            "ln1": L.init_norm(ks[0], cfg.d_model, with_bias=ln_bias),
            "attn": attn_lib.init_attention(ks[1], cfg),
            "ln2": L.init_norm(ks[2], cfg.d_model, with_bias=ln_bias),
        }
        if cfg.is_moe:
            p["moe"] = moe_lib.init_moe(ks[3], cfg)
        else:
            p["ffn"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act_fn)
        if cross:
            p["ln_c"] = L.init_norm(ks[4], cfg.d_model, with_bias=ln_bias)
            p["cross"] = attn_lib.init_attention(ks[5], cfg, cross=True)
        return p
    if kind == "mamba2":
        return {"ln1": L.init_norm(ks[0], cfg.d_model),
                "mamba": ssm_lib.init_mamba2(ks[1], cfg)}
    if kind == "rwkv6":
        return {"ln1": L.init_norm(ks[0], cfg.d_model, with_bias=True),
                "ln2": L.init_norm(ks[1], cfg.d_model, with_bias=True),
                "rwkv": rwkv_lib.init_rwkv6(ks[2], cfg)}
    raise ValueError(kind)


def _specs_block(cfg: ArchConfig, kind: str, *, cross: bool = False):
    ln_bias = cfg.family == "encdec"
    if kind == "attn":
        p = {
            "ln1": L.specs_norm(with_bias=ln_bias),
            "attn": attn_lib.specs_attention(cfg),
            "ln2": L.specs_norm(with_bias=ln_bias),
        }
        if cfg.is_moe:
            p["moe"] = moe_lib.specs_moe(cfg)
        else:
            p["ffn"] = L.specs_mlp(cfg.act_fn)
        if cross:
            p["ln_c"] = L.specs_norm(with_bias=ln_bias)
            p["cross"] = attn_lib.specs_attention(cfg, cross=True)
        return p
    if kind == "mamba2":
        return {"ln1": L.specs_norm(), "mamba": ssm_lib.specs_mamba2(cfg)}
    if kind == "rwkv6":
        return {"ln1": L.specs_norm(with_bias=True),
                "ln2": L.specs_norm(with_bias=True),
                "rwkv": rwkv_lib.specs_rwkv6(cfg)}
    raise ValueError(kind)


def _stack_blocks(key, cfg, kind, n, **kw):
    blocks = [_init_block(k, cfg, kind, **kw) for k in jax.random.split(key, n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def _stacked_specs(cfg, kind, **kw):
    spec = _specs_block(cfg, kind, **kw)
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec,
                        is_leaf=lambda x: isinstance(x, P))


def _uniform_kind(cfg: ArchConfig) -> str:
    kinds = set(cfg.pattern)
    assert len(kinds) == 1, f"non-uniform pattern unsupported: {kinds}"
    return next(iter(kinds))


# ---------------------------------------------------------------------------
# Model init / specs
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    kind = _uniform_kind(cfg)
    p: Dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.init_norm(ks[1], cfg.d_model,
                                  with_bias=cfg.family == "encdec"),
        "blocks": _stack_blocks(ks[2], cfg, kind, cfg.n_layers,
                                cross=cfg.cross_attention),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.init_head(ks[3], cfg.d_model, cfg.vocab_size)
    if cfg.shared_attn_every:
        p["shared_attn"] = _init_block(ks[4], cfg, "attn")
    if cfg.encoder_layers:
        p["encoder"] = _stack_blocks(ks[5], cfg, "attn", cfg.encoder_layers)
        p["enc_norm"] = L.init_norm(ks[6], cfg.d_model, with_bias=True)
    if cfg.frontend == "vision" and cfg.frontend_dim:
        p["vis_proj"] = {"w": L._dense_init(ks[7], (cfg.frontend_dim, cfg.d_model)),
                         "b": jnp.zeros((cfg.d_model,), jnp.float32)}
    return p


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    kind = _uniform_kind(cfg)
    s: Dict[str, Any] = {
        "embed": L.specs_embedding(),
        "final_norm": L.specs_norm(with_bias=cfg.family == "encdec"),
        "blocks": _stacked_specs(cfg, kind, cross=cfg.cross_attention),
    }
    if not cfg.tie_embeddings:
        s["head"] = L.specs_head()
    if cfg.shared_attn_every:
        s["shared_attn"] = _specs_block(cfg, "attn")
    if cfg.encoder_layers:
        s["encoder"] = _stacked_specs(cfg, "attn")
        s["enc_norm"] = L.specs_norm(with_bias=True)
    if cfg.frontend == "vision" and cfg.frontend_dim:
        s["vis_proj"] = {"w": P("fsdp", "tp"), "b": P(None)}
    return s


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the params without allocating (for dry-run)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, dtype if a.ndim >= 2 else a.dtype),
        shapes)


def cast_params(params, dtype):
    """bf16 compute cast: matrices cast, vectors (norm scales etc.) stay f32."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.ndim >= 2 and a.dtype == jnp.float32 else a,
        params)


# ---------------------------------------------------------------------------
# Embedding / frontends
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ArchConfig, batch, shard: ShardingHints):
    """Token embeddings + modality-stub merge.  Returns (x, positions,
    positions_thw)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    positions_thw = None
    if cfg.frontend == "vision" and "patches" in batch:
        pe = batch["patches"]
        Pn = pe.shape[1]
        if "vis_proj" in params:
            pe = pe @ params["vis_proj"]["w"] + params["vis_proj"]["b"]
        pe = pe.astype(x.dtype)
        x = jnp.concatenate([pe, x[:, Pn:, :]], axis=1)
        if cfg.m_rope:
            vis = rope_lib.vision_positions_thw(B, Pn)
            side = max(1, int(Pn ** 0.5))
            txt_pos = positions[:, Pn:] - Pn + side  # text starts after grid
            txt = rope_lib.text_positions_thw(txt_pos)
            positions_thw = jnp.concatenate([vis, txt], axis=1)
    if cfg.family == "encdec":  # whisper: sinusoidal absolute positions
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    x = _c(x, shard.residual)
    return x, positions, positions_thw


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_block_fwd(bp, x, cfg, *, positions, positions_thw, shard,
                    enc_out=None, causal=True):
    h = attn_lib.attention_forward(
        bp["attn"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, positions_thw=positions_thw, causal=causal,
        seq_spec=shard.residual)
    x = _c(x + h, shard.residual)
    if enc_out is not None:
        h = attn_lib.attention_forward(
            bp["cross"], L.apply_norm(bp["ln_c"], x, cfg.norm_eps), cfg,
            positions=positions, causal=False, x_kv=enc_out,
            seq_spec=shard.residual)
        x = _c(x + h, shard.residual)
    aux = jnp.zeros((), jnp.float32)
    xin = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        if shard.moe_ep is not None:
            mesh, ep_axis, baxes = shard.moe_ep
            h, aux = moe_lib.apply_moe_ep(bp["moe"], xin, cfg, mesh=mesh,
                                          ep_axis=ep_axis, batch_axes=baxes)
        else:
            h, aux = moe_lib.apply_moe(
                bp["moe"], xin, cfg,
                w_specs=(shard.moe_w_in, shard.moe_w_out))
    else:
        h = L.apply_mlp(bp["ffn"], xin, cfg.act_fn)
    x = _c(x + h, shard.residual)
    return x, aux


def _rwkv_block_fwd(bp, x, cfg, shard):
    h, _ = rwkv_lib.time_mix(bp["rwkv"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg)
    x = _c(x + h, shard.residual)
    h, _ = rwkv_lib.channel_mix(bp["rwkv"], L.apply_norm(bp["ln2"], x, cfg.norm_eps), cfg)
    return _c(x + h, shard.residual)


def _mamba_block_fwd(bp, x, cfg, shard):
    h = ssm_lib.apply_mamba2(bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg)
    return _c(x + h, shard.residual)


def _encoder_forward(params, cfg, frames, shard: ShardingHints, remat: bool):
    """Whisper encoder over precomputed frame embeddings (B, S_enc, D)."""
    x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model
                                        ).astype(frames.dtype)[None]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    def body(x, bp):
        y, _ = _attn_block_fwd(bp, x, cfg, positions=positions,
                               positions_thw=None, shard=shard, causal=False)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ArchConfig, batch, *, shard: ShardingHints = ShardingHints(),
            remat: bool = False):
    """Full-sequence decoder forward -> (hidden (B,S,D), aux_loss)."""
    x, positions, positions_thw = _embed_inputs(params, cfg, batch, shard)
    kind = _uniform_kind(cfg)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder_forward(params, cfg, batch["frames"].astype(x.dtype),
                                   shard, remat)

    if cfg.shared_attn_every:  # zamba2: scan over [shared-attn + k mamba] groups
        every = cfg.shared_attn_every
        assert cfg.n_layers % every == 0, "shared_attn_every must divide n_layers"
        groups = cfg.n_layers // every
        gp = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]),
                          params["blocks"])
        shared = params["shared_attn"]

        def gbody(x, bp_g):
            x = _attn_block_fwd(shared, x, cfg, positions=positions,
                                positions_thw=None, shard=shard)[0]
            for i in range(every):
                bp = jax.tree.map(lambda a, i=i: a[i], bp_g)
                blk = lambda bp_, x_: _mamba_block_fwd(bp_, x_, cfg, shard)
                if remat:  # nested: one mamba layer live at a time in bwd
                    blk = jax.checkpoint(blk)
                x = blk(bp, x)
            return x, None

        if remat:
            gbody = jax.checkpoint(gbody)
        x, _ = jax.lax.scan(gbody, x, gp)
        hidden = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
        return hidden, jnp.zeros((), jnp.float32)

    def body(carry, bp):
        x, aux = carry
        if kind == "attn":
            x, a = _attn_block_fwd(bp, x, cfg, positions=positions,
                                   positions_thw=positions_thw, shard=shard,
                                   enc_out=enc_out)
            aux = aux + a
        elif kind == "rwkv6":
            x = _rwkv_block_fwd(bp, x, cfg, shard)
        elif kind == "mamba2":
            x = _mamba_block_fwd(bp, x, cfg, shard)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return L.apply_norm(params["final_norm"], x, cfg.norm_eps), aux


def train_loss(params, cfg: ArchConfig, batch, *,
               shard: ShardingHints = ShardingHints(), remat: bool = True):
    hidden, aux = forward(params, cfg, batch, shard=shard, remat=remat)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["w"].T)
    ce = L.chunked_cross_entropy(hidden, table, batch["labels"],
                                 logits_spec=shard.logits)
    return ce + aux


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, *,
               dtype=jnp.bfloat16, window: Optional[int] = None):
    """Build the (abstract-friendly) decode cache for an arch."""
    kind = _uniform_kind(cfg)
    window = window if window is not None else cfg.sliding_window
    cache: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if cfg.m_rope:
        # M-RoPE text-position offset set at prefill (vision grid compression)
        cache["mrope_delta"] = jnp.zeros((), jnp.int32)
    if kind == "attn":
        one = attn_lib.init_kv_cache(batch_size, max_len, cfg, window=window,
                                     dtype=dtype)
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)
    elif kind == "mamba2":
        one = ssm_lib.init_mamba_cache(batch_size, cfg, dtype=dtype)
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)
    elif kind == "rwkv6":
        one = rwkv_lib.init_rwkv_cache(batch_size, cfg, dtype=dtype)
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)
    if cfg.shared_attn_every:
        n_app = (cfg.n_layers + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        # hybrid long-context: shared attn block runs windowed (DESIGN.md)
        w = window if window is not None else (4096 if max_len > 65536 else None)
        sa = attn_lib.init_kv_cache(batch_size, max_len, cfg, window=w, dtype=dtype)
        cache["shared"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_app,) + a.shape), sa)
    if cfg.encoder_layers:
        # cross-attention K/V per decoder layer, computed at prefill
        shape = (cfg.n_layers, batch_size, cfg.encoder_seq_len,
                 cfg.n_kv_heads, cfg.hd)
        cache["cross_k"] = jnp.zeros(shape, dtype)
        cache["cross_v"] = jnp.zeros(shape, dtype)
    return cache


# ---------------------------------------------------------------------------
# Decode step (and prefill)
# ---------------------------------------------------------------------------

def _attn_block_decode(bp, x, cfg, kv: KVCache, *, positions_thw=None,
                       cross_kv=None):
    h, kv, token_kv = attn_lib.attention_decode(
        bp["attn"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg, kv,
        positions_thw=positions_thw)
    x = x + h
    if cross_kv is not None:
        h, _, _ = attn_lib.attention_decode(
            bp["cross"], L.apply_norm(bp["ln_c"], x, cfg.norm_eps), cfg, kv,
            cross_kv=cross_kv)
        x = x + h
    xin = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        h, _ = moe_lib.apply_moe(bp["moe"], xin, cfg, chunk=1)
    else:
        h = L.apply_mlp(bp["ffn"], xin, cfg.act_fn)
    return x + h, kv, token_kv


def decode_step(params, cfg: ArchConfig, token, cache, *,
                shard: ShardingHints = ShardingHints()):
    """token: (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    B = token.shape[0]
    x = L.embed(params["embed"], token)
    kind = _uniform_kind(cfg)
    step = cache["step"]
    positions_thw = None
    if cfg.m_rope:
        p_eff = step + cache.get("mrope_delta", jnp.zeros((), jnp.int32))
        pos = jnp.broadcast_to(p_eff[None, None], (B, 1)).astype(jnp.int32)
        positions_thw = rope_lib.text_positions_thw(pos)
    if cfg.family == "encdec":
        dim = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)
        inv = jnp.exp(-jnp.log(10_000.0) * dim / max(cfg.d_model // 2 - 1, 1))
        ang = step.astype(jnp.float32) * inv
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
        x = x + pe.astype(x.dtype)

    new_cache = dict(cache)
    if cfg.shared_attn_every:  # zamba2: scan over [shared-attn + k mamba] groups
        every = cfg.shared_attn_every
        groups = cfg.n_layers // every
        gp = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]),
                          params["blocks"])
        gc = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]),
                          cache["layers"])
        shared = params["shared_attn"]

        def gbody(x, xs):
            bp_g, lc_g, sc = xs
            h, sc, _ = attn_lib.attention_decode(
                shared["attn"], L.apply_norm(shared["ln1"], x, cfg.norm_eps),
                cfg, sc)
            x = x + h
            x = x + L.apply_mlp(shared["ffn"],
                                L.apply_norm(shared["ln2"], x, cfg.norm_eps),
                                cfg.act_fn)
            new_lcs = []
            for i in range(every):
                bp = jax.tree.map(lambda a, i=i: a[i], bp_g)
                lc = jax.tree.map(lambda a, i=i: a[i], lc_g)
                h, lc = ssm_lib.mamba2_decode(
                    bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_eps),
                    cfg, lc)
                x = x + h
                new_lcs.append(lc)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_lcs)
            return x, (stacked, sc)

        x, (new_g, new_shared) = jax.lax.scan(
            gbody, x, (gp, gc, cache["shared"]))
        new_cache["layers"] = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_g)
        new_cache["shared"] = new_shared
    else:
        cross = cfg.encoder_layers > 0

        # The stacked per-layer cache rides in the scan CARRY and is updated
        # via dynamic_update_index_in_dim at the loop counter — XLA keeps the
        # while-loop state in place, so the multi-GB KV buffers are never
        # double-buffered per step (cf. xs/ys scan which allocates a fresh
        # stacked output).
        def body(carry, xs):
            x, layers, i = carry
            if cross:
                bp, ck, cv = xs
            else:
                bp = xs
            lc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                layers)
            token_kv = None
            if kind == "attn":
                x, lc, token_kv = _attn_block_decode(
                    bp, x, cfg, lc, positions_thw=positions_thw,
                    cross_kv=(ck, cv) if cross else None)
            elif kind == "mamba2":
                h, lc = ssm_lib.mamba2_decode(
                    bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg, lc)
                x = x + h
            elif kind == "rwkv6":
                h, lc = rwkv_lib.rwkv6_decode(
                    bp["rwkv"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg, lc)
                x = x + h
                h, lc = rwkv_lib.channel_mix_decode(
                    bp["rwkv"], L.apply_norm(bp["ln2"], x, cfg.norm_eps), cfg, lc)
                x = x + h
            # NOTE (perf iteration #2, REFUTED — see EXPERIMENTS.md §Perf):
            # writing only the new token column into the stacked cache via a
            # doubly-dynamic DUS (layer i + sharded position idx) makes the
            # SPMD partitioner fall back to a masked full-buffer rewrite
            # (~27 GB/layer).  The full-slice write-back at a static layer
            # axis stays in place and is the fastest variant measured.
            del token_kv
            layers = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0), layers, lc)
            return (x, layers, i + 1), None

        xs = ((params["blocks"], cache["cross_k"], cache["cross_v"])
              if cross else params["blocks"])
        (x, new_layers, _), _ = jax.lax.scan(
            body, (x, cache["layers"], jnp.zeros((), jnp.int32)), xs)
        new_cache["layers"] = new_layers

    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["w"].T)
    logits = (x.astype(jnp.float32) @ table.T.astype(jnp.float32))
    logits = _c(logits, shard.logits)
    new_cache["step"] = step + 1
    return logits, new_cache


def prefill(params, cfg: ArchConfig, batch, cache, *,
            shard: ShardingHints = ShardingHints()):
    """Run the prompt through the model in ONE pass, producing both the
    last-token logits and the filled decode cache (QKV projections are
    shared between the attention output and the cache write)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    kind = _uniform_kind(cfg)
    x, positions, positions_thw = _embed_inputs(params, cfg, batch, shard)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder_forward(params, cfg, batch["frames"].astype(x.dtype),
                                   shard, False)
    new_cache = dict(cache)
    if cfg.shared_attn_every:
        every = cfg.shared_attn_every
        groups = cfg.n_layers // every
        gp = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]),
                          params["blocks"])
        gc = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]),
                          cache["layers"])
        shared = params["shared_attn"]

        def gbody(x, xs):
            bp_g, lc_g, sc = xs
            xin = L.apply_norm(shared["ln1"], x, cfg.norm_eps)
            h, sc = attn_lib.attention_prefill(shared["attn"], xin, cfg, sc,
                                               positions=positions)
            x = x + h
            x = x + L.apply_mlp(shared["ffn"],
                                L.apply_norm(shared["ln2"], x, cfg.norm_eps),
                                cfg.act_fn)
            new_lcs = []
            for i in range(every):
                bp = jax.tree.map(lambda a, i=i: a[i], bp_g)
                lc = jax.tree.map(lambda a, i=i: a[i], lc_g)
                h, lc = ssm_lib.mamba2_prefill(
                    bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_eps),
                    cfg, lc)
                x = x + h
                new_lcs.append(lc)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_lcs)
            return x, (stacked, sc)

        x, (new_g, new_shared) = jax.lax.scan(
            gbody, x, (gp, gc, cache["shared"]))
        new_cache["layers"] = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_g)
        new_cache["shared"] = new_shared
    else:
        def body(x, xs):
            bp, lc = xs
            if kind == "attn":
                xin = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
                h, lc = attn_lib.attention_prefill(
                    bp["attn"], xin, cfg, lc, positions=positions,
                    positions_thw=positions_thw, seq_spec=shard.residual)
                x = _c(x + h, shard.residual)
                if cfg.encoder_layers:
                    xc = L.apply_norm(bp["ln_c"], x, cfg.norm_eps)
                    x = x + attn_lib.attention_forward(
                        bp["cross"], xc, cfg, positions=positions, causal=False,
                        x_kv=enc_out, seq_spec=shard.residual)
                xin2 = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
                if cfg.is_moe:
                    h, _ = moe_lib.apply_moe(bp["moe"], xin2, cfg)
                else:
                    h = L.apply_mlp(bp["ffn"], xin2, cfg.act_fn)
                x = _c(x + h, shard.residual)
            elif kind == "mamba2":
                h, lc = ssm_lib.mamba2_prefill(
                    bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_eps), cfg, lc)
                x = _c(x + h, shard.residual)
            elif kind == "rwkv6":
                xin = L.apply_norm(bp["ln1"], x, cfg.norm_eps)
                h, (last_x, s_fin) = rwkv_lib.time_mix(
                    bp["rwkv"], xin, cfg, s0=lc.state)
                x = _c(x + h, shard.residual)
                xin2 = L.apply_norm(bp["ln2"], x, cfg.norm_eps)
                h, last_cm = rwkv_lib.channel_mix(bp["rwkv"], xin2, cfg)
                x = _c(x + h, shard.residual)
                lc = rwkv_lib.RWKVCache(
                    x_tm=last_x.astype(lc.x_tm.dtype),
                    x_cm=last_cm.astype(lc.x_cm.dtype), state=s_fin)
            return x, lc

        # whisper: also fill cross K/V from encoder output
        if cfg.encoder_layers:
            def fill_cross(bp):
                k = enc_out @ bp["cross"]["wk"]
                v = enc_out @ bp["cross"]["wv"]
                if cfg.qkv_bias:
                    k, v = k + bp["cross"]["bk"], v + bp["cross"]["bv"]
                Se = enc_out.shape[1]
                k = k.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
                v = v.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
                return k, v
            ck, cv = jax.lax.map(fill_cross, params["blocks"])
            new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
            new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)

        x, new_layers = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        new_cache["layers"] = new_layers

    new_cache["step"] = jnp.asarray(S, jnp.int32)
    if cfg.m_rope and "patches" in batch:
        Pn = batch["patches"].shape[1]
        side = max(1, int(Pn ** 0.5))
        new_cache["mrope_delta"] = jnp.asarray(side - Pn, jnp.int32)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["w"].T)
    hidden = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    last = hidden[:, -1, :]
    logits = last.astype(jnp.float32) @ table.T.astype(jnp.float32)
    return logits, new_cache
