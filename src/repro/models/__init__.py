from repro.models import transformer, zoo  # noqa: F401
