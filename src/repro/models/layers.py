"""Core layers: norms, MLPs, embeddings, parameter init.

Parameters are plain nested dicts of jnp arrays.  Every ``init_*`` has a
``specs_*`` twin that returns an identically-structured tree of
*logical-axis* PartitionSpecs (resolved to mesh axes by
``repro.distributed.sharding``).  Tests assert the trees stay in sync.

Logical axes used for params:
  "fsdp"  -- sharded over the data axis (ZeRO-style)
  "tp"    -- tensor-parallel over the model axis
  "exp"   -- expert dimension (resolved to the model axis when divisible)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def linear(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(key, d, *, with_bias=False):
    del key
    p = {"scale": jnp.zeros((d,), jnp.float32) if not with_bias else jnp.ones((d,), jnp.float32)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def specs_norm(*, with_bias=False):
    p = {"scale": P(None)}
    if with_bias:
        p["bias"] = P(None)
    return p


def apply_norm(p, x, eps=1e-5):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d, ff, act_fn: str = "silu"):
    ks = jax.random.split(key, 3)
    if act_fn == "silu":
        return {
            "w_gate": _dense_init(ks[0], (d, ff)),
            "w_up": _dense_init(ks[1], (d, ff)),
            "w_down": _dense_init(ks[2], (ff, d), in_axis=0),
        }
    return {
        "w_up": _dense_init(ks[0], (d, ff)),
        "b_up": jnp.zeros((ff,), jnp.float32),
        "w_down": _dense_init(ks[1], (ff, d)),
        "b_down": jnp.zeros((d,), jnp.float32),
    }


def specs_mlp(act_fn: str = "silu"):
    if act_fn == "silu":
        return {"w_gate": P("fsdp", "tp"), "w_up": P("fsdp", "tp"),
                "w_down": P("tp", "fsdp")}
    return {"w_up": P("fsdp", "tp"), "b_up": P("tp"),
            "w_down": P("tp", "fsdp"), "b_down": P(None)}


def apply_mlp(p, x, act_fn: str = "silu"):
    if act_fn == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"table": _dense_init(key, (vocab, d), in_axis=1)}


def specs_embedding():
    return {"table": P("tp", "fsdp")}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Logits via the (possibly tied) embedding table: (..., d) -> (..., V)."""
    return x @ p["table"].T


def init_head(key, d, vocab):
    return {"w": _dense_init(key, (d, vocab))}


def specs_head():
    return {"w": P("fsdp", "tp")}


# ---------------------------------------------------------------------------
# Positional encodings (whisper-style sinusoidal)
# ---------------------------------------------------------------------------

def sinusoidal_positions(n_pos: int, d: int, offset=0):
    pos = jnp.arange(n_pos, dtype=jnp.float32) + offset
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10_000.0) * dim / max(d // 2 - 1, 1))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (avoids materializing (B,S,V) logits in full)
# ---------------------------------------------------------------------------

def chunked_cross_entropy(x, table, labels, *, chunk: int = 512,
                          logits_spec: Optional[P] = None):
    """Mean token cross-entropy computed over sequence chunks.

    x: (B, S, D) final hidden states; table: (V, D) unembedding;
    labels: (B, S) int32.  Returns scalar mean loss (f32).
    """
    B, S, D = x.shape
    n = max(1, S // chunk)
    while S % n:
        n -= 1
    xs = x.reshape(B, n, S // n, D).swapaxes(0, 1)       # (n, B, s, D)
    ls = labels.reshape(B, n, S // n).swapaxes(0, 1)

    def body(carry, xl):
        xc, lc = xl
        logits = (xc.astype(jnp.float32) @ table.T.astype(jnp.float32))
        if logits_spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (B * S)
