"""Data pipeline: tokenized-document stream -> packed training batches.

Offline container => the corpus source is synthetic-but-structured: a
Zipfian n-gram "language" with document boundaries, so cross-entropy is
meaningfully learnable (tests assert loss decreases).  Real deployments
swap `DocumentSource` for a file-backed source; everything downstream
(packing, batching, modality stubs) is production-shaped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig

BOS = 1
EOS = 2


class DocumentSource:
    """Synthetic Zipfian bigram documents (learnable structure)."""

    def __init__(self, vocab_size: int, seed: int = 0, *,
                 mean_len: int = 256, n_states: int = 64):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        self.mean_len = mean_len
        # a sparse bigram transition structure to learn
        self.n_states = n_states
        self.state_tokens = self.rng.integers(
            3, vocab_size, size=(n_states, 32))
        self.transitions = self.rng.integers(0, n_states, size=(n_states, 4))

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            length = max(8, int(self.rng.exponential(self.mean_len)))
            state = int(self.rng.integers(0, self.n_states))
            toks = [BOS]
            for _ in range(length):
                toks.append(int(self.state_tokens[
                    state, self.rng.integers(0, 32)]))
                state = int(self.transitions[
                    state, self.rng.integers(0, 4)])
            toks.append(EOS)
            yield np.asarray(toks, np.int32)


class PackedBatcher:
    """Packs documents into fixed (batch, seq) token blocks with next-token
    labels; documents are concatenated, EOS-delimited (GPT-style packing)."""

    def __init__(self, source: Iterator[np.ndarray], batch: int, seq: int):
        self.source = iter(source)
        self.batch = batch
        self.seq = seq
        self._buf = np.zeros((0,), np.int32)

    def _fill(self, n: int) -> np.ndarray:
        while self._buf.shape[0] < n:
            self._buf = np.concatenate([self._buf, next(self.source)])
        out, self._buf = self._buf[:n], self._buf[n:]
        return out

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        n = self.batch * (self.seq + 1)
        block = self._fill(n).reshape(self.batch, self.seq + 1)
        return {"tokens": block[:, :-1].copy(), "labels": block[:, 1:].copy()}


def make_pipeline(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                  rng: Optional[np.random.Generator] = None):
    """Batches for any arch (adds modality-stub arrays where required)."""
    rng = rng or np.random.default_rng(seed + 1)
    base = PackedBatcher(DocumentSource(cfg.vocab_size, seed), batch, seq)

    def gen():
        for b in base:
            if cfg.frontend == "audio":
                b["frames"] = rng.standard_normal(
                    (batch, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)
            if cfg.frontend == "vision":
                fd = cfg.frontend_dim or cfg.d_model
                b["patches"] = rng.standard_normal(
                    (batch, min(cfg.vision_patches, seq), fd)).astype(np.float32)
            yield b

    return gen()
