"""Logical-axis -> mesh-axis resolution and activation sharding policies.

Model code annotates params with *logical* axes ("fsdp", "tp", "exp");
this module resolves them against a concrete mesh:

  fsdp -> "data"   (ZeRO-style parameter/optimizer sharding)
  tp   -> "model"  (tensor parallelism)
  exp  -> "pod"    (expert parallelism across pods, when divisible)

Any axis that does not divide the corresponding dim is dropped
(replicated) rather than erroring — e.g. 4 KV heads never shard over a
16-way model axis.  Activation policies are per input shape (see
`repro.launch.shapes`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LOGICAL_TO_MESH = {
    "fsdp": "data",
    "tp": "model",
    "exp": "pod",
}


def _mesh_axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_mesh_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 0


def resolve_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh,
                 drop: frozenset = frozenset()) -> P:
    """Translate one logical PartitionSpec for an array of `shape`."""
    out = []
    used = set()
    for dim, name in enumerate(spec):
        if name is None:
            out.append(None)
            continue
        names = name if isinstance(name, tuple) else (name,)
        resolved = []
        for n in names:
            if n in drop:
                continue
            m = LOGICAL_TO_MESH.get(n, n)
            if m in used or m not in mesh.shape:
                continue
            resolved.append(m)
        size = int(np.prod([mesh.shape[m] for m in resolved])) if resolved else 1
        if resolved and dim < len(shape) and shape[dim] % size == 0 and size > 1:
            out.append(tuple(resolved) if len(resolved) > 1 else resolved[0])
            used.update(resolved)
        else:
            out.append(None)
    return P(*out)


def resolve_tree(spec_tree, abstract_tree, mesh: Mesh,
                 drop: frozenset = frozenset()):
    """Resolve a tree of logical specs against matching abstract arrays."""
    def f(spec, arr):
        spec = spec if isinstance(spec, P) else P()
        # pad spec to array rank
        padded = tuple(spec) + (None,) * (len(arr.shape) - len(spec))
        return resolve_spec(P(*padded), arr.shape, mesh, drop)
    return jax.tree.map(f, spec_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shardings_for(spec_tree, abstract_tree, mesh: Mesh):
    resolved = resolve_tree(spec_tree, abstract_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), resolved,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


@dataclasses.dataclass(frozen=True)
class ActivationPolicy:
    """Per-shape activation sharding knobs (hillclimb levers)."""
    shard_batch: bool = True
    seq_shard_residual: bool = True     # sequence-parallel residuals over model
    vocab_shard_logits: bool = True
    kv_seq_shard: bool = False          # decode KV cache: shard S over model

    def hints(self, mesh: Mesh, *, batch: int, decode: bool = False):
        """Build transformer.ShardingHints with resolved specs."""
        from repro.models.transformer import ShardingHints
        dp = batch_axes(mesh)
        bspec = dp if (self.shard_batch and batch % max(
            1, _mesh_axis_size(mesh, dp)) == 0) else None
        seq = "model" if (self.seq_shard_residual and not decode
                          and "model" in mesh.shape) else None
        resid = P(bspec, seq, None)
        logits = P(bspec, None,
                   "model" if self.vocab_shard_logits and "model" in mesh.shape
                   else None)
        tp = "model" if "model" in mesh.shape else None
        return ShardingHints(residual=resid, logits=logits, kv=None,
                             moe_w_in=P(None, None, tp),
                             moe_w_out=P(None, tp, None))


def cache_specs(cache_abstract, mesh: Mesh, *, batch: int,
                policy: ActivationPolicy) -> Any:
    """Logical->resolved specs for a decode cache tree.

    Rules by rank/shape:
      KV k/v   (L, B, S, KV, hd): batch over dp; S over model if kv_seq_shard
      pos      (L, B)           : batch over dp
      mamba ssm (L, B, H, hd, N): batch over dp, heads over model
      rwkv state (L, B, H, hd, hd): batch over dp, heads over model
      conv/x prev (L, B, *, d)  : batch over dp
      cross k/v (L, B, Se, KV, hd): batch over dp
    """
    dp = batch_axes(mesh)
    dp_size = _mesh_axis_size(mesh, dp)
    b_ok = batch % max(dp_size, 1) == 0 and policy.shard_batch

    def leaf_spec(a):
        shape = a.shape
        if len(shape) == 0 or shape == ():
            return P()
        spec = [None] * len(shape)
        # find the batch dim: stacked caches have leading L, batch second —
        # prefer dim 1 (dim 0 is the layer stack and may collide with batch)
        bdim = None
        if len(shape) >= 2 and shape[1] == batch:
            bdim = 1
        else:
            for d, s in enumerate(shape):
                if s == batch:
                    bdim = d
                    break
        if bdim is not None and b_ok:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
        if "model" in mesh.shape:
            m = mesh.shape["model"]
            if len(shape) == 5 and bdim == 1:
                # KV cache (L, B, KV, S, hd) heads-major, or SSM state
                # (L, B, H, hd, N): the seq dim is the largest of dims 2/3
                sdim = 2 if shape[2] >= shape[3] else 3
                if (policy.kv_seq_shard and shape[sdim] % m == 0
                        and shape[sdim] >= 2048):
                    spec[sdim] = "model"
            if not b_ok and len(shape) >= 3 and bdim == 1:
                # long_500k: batch=1 -> shard the longest remaining dim
                sizes = [(s, d) for d, s in enumerate(shape) if d > 1]
                s, d = max(sizes)
                if s % m == 0 and s >= m:
                    spec[d] = "model"
        return resolve_spec(P(*spec), shape, mesh)

    return jax.tree.map(leaf_spec, cache_abstract)
