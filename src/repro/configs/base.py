"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the model zoo
(`repro.models.zoo`) builds a concrete JAX model from it.  Configs carry
citations to their source paper / model card in ``source``.

Block kinds (``block_pattern`` entries):
  "attn"    -- self-attention + MLP (dense or MoE depending on n_experts)
  "mamba2"  -- Mamba2 / SSD block (used by zamba2, standalone ssm archs)
  "rwkv6"   -- RWKV6 time-mix + channel-mix block
A hybrid arch interleaves kinds via ``block_pattern``; homogeneous archs
use a single entry that is repeated ``n_layers`` times.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ------------------------------------------------------
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    source: str                     # citation (arXiv id / model card)

    # -- transformer backbone ------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # -- block layout ---------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)

    # -- attention details ----------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # None = full causal attention
    rope_theta: float = 10_000.0
    m_rope: bool = False                   # Qwen2-VL multimodal RoPE
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2

    # -- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # virtual-expert F-split: store expert FFNs as (E*ks, D, F/ks) so E*ks
    # matches a mesh axis for expert parallelism (SwiGLU decomposes exactly
    # over F).  1 = off.
    expert_shards: int = 1

    # -- SSM (Mamba2 / SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0             # number of SSD heads (0 -> derived)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    d_conv: int = 4

    # -- RWKV6 -------------------------------------------------------------
    rwkv_head_dim: int = 64

    # -- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0
    encoder_seq_len: int = 1500    # whisper: 30 s of audio at 50 Hz
    cross_attention: bool = False

    # -- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0     # apply the weight-tied shared attn block every k layers

    # -- modality frontend (STUB per brief: precomputed embeddings) ----------
    frontend: Optional[str] = None   # None | "audio" | "vision"
    vision_patches: int = 256        # patches prepended for the VLM stub
    frontend_dim: int = 0            # raw embedding dim fed by the stub (0 = d_model)

    # -- misc -------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act_fn: str = "silu"            # silu (swiglu) | gelu (plain 2-layer MLP)
    dtype: str = "bfloat16"
    # use Pallas kernels for attention/scan hot spots (CPU tests keep False)
    use_pallas: bool = False

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length == n_layers."""
        if len(self.block_pattern) == self.n_layers:
            return self.block_pattern
        reps = (self.n_layers + len(self.block_pattern) - 1) // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def attention_free(self) -> bool:
        return all(k in ("mamba2", "rwkv6") for k in self.pattern) and self.shared_attn_every == 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context without O(S) full-attn KV?"""
        if self.attention_free:
            return True
        if self.shared_attn_every > 0:
            # hybrid: shared attn block runs windowed at long context
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d          # wq, wk, wv, wo
        if self.qkv_bias:
            attn += q + 2 * kv
        mlp_dense = (3 if self.act_fn == "silu" else 2) * d * ff
        mlp_moe = self.n_experts * mlp_dense + d * self.n_experts
        n = V * d                                   # token embedding
        if not self.tie_embeddings:
            n += V * d                              # lm head
        for kind in self.pattern:
            if kind == "attn":
                n += attn + (mlp_moe if self.is_moe else mlp_dense)
                n += 2 * d                          # two rmsnorm scales
            elif kind == "mamba2":
                d_in = self.ssm_expand * d
                heads = self.ssm_heads or (d_in // self.ssm_head_dim)
                n += d * (2 * d_in + 2 * heads * self.ssm_state + heads)  # in/x/B/C/dt proj
                n += d_in * self.d_conv + d_in      # conv + bias
                n += d_in * d + d                   # out proj + norm
            elif kind == "rwkv6":
                # time-mix: r,k,v,g,w projections + output, channel-mix: 2 mats
                n += 6 * d * d + 2 * d * ff + 2 * d
        if self.shared_attn_every:
            n += attn + mlp_dense                   # one shared, weight-tied block
        if self.encoder_layers:
            n += self.encoder_layers * (attn + mlp_dense + 2 * d)
            # decoder cross-attention per layer
            n += self.n_layers * (attn + 2 * d)
        if self.frontend == "vision":
            n += (self.frontend_dim or d) * d       # projector
        return n

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.is_moe:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        per_expert = 3 * d * ff
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return self.n_params() - inactive


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ArchConfig:
    """CPU-smoke-test variant of the same family (per brief: 2 layers,
    d_model<=512, <=4 experts)."""
    hd = 32
    n_heads = max(2, d_model // 64)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep the GQA ratio flavor
    if cfg.n_kv_heads < cfg.n_heads:
        n_kv = max(1, n_heads // max(1, cfg.n_heads // cfg.n_kv_heads))
    else:
        n_kv = n_heads
    pat = cfg.block_pattern
    kw = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=2 * d_model,
        vocab_size=vocab,
        block_pattern=pat if len(pat) <= layers else pat[:layers],
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        expert_shards=1,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state or "mamba2" in pat or "rwkv6" in pat else cfg.ssm_head_dim,
        rwkv_head_dim=32,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 32),
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        vision_patches=min(cfg.vision_patches, 8),
        m_rope_sections=(hd // 2 - 2 * (3 * hd // 16), 3 * hd // 16, 3 * hd // 16)
        if cfg.m_rope else cfg.m_rope_sections,
        frontend_dim=min(cfg.frontend_dim, d_model) if cfg.frontend_dim else 0,
        dtype="float32",
    )
    return cfg.replace(**kw)
