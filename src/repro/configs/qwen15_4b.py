"""qwen1.5-4b [dense] — QKV-bias llama-style decoder.

40L, d_model=2560, 20H (GQA kv=20, i.e. MHA), d_ff=6912, vocab=151936.
[hf:Qwen/Qwen1.5-0.5B family]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
