"""whisper-large-v3 [audio] — encoder-decoder transformer backbone.

32 decoder layers (and 32 encoder layers per the model card), d_model=1280,
20 heads (GQA kv=20, i.e. MHA), d_ff=5120, vocab=51866.  The mel-spectrogram
+ conv feature extractor frontend is a STUB per the brief: ``input_specs``
feeds precomputed 1280-d frame embeddings.  [arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    source="arXiv:2212.04356",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    encoder_layers=32,
    encoder_seq_len=1500,
    cross_attention=True,
    frontend="audio",
    act_fn="gelu",
    rope_theta=0.0,        # whisper uses learned/sinusoidal abs positions
    qkv_bias=True,
)
