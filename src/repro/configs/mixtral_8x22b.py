"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L, d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=32768. [arXiv:2401.04088]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    expert_shards=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
)
