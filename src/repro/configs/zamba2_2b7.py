"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L, d_model=2560, 32H (GQA kv=32), d_ff=10240, ssm_state=64.  One
weight-tied attention(+MLP) block is applied every 6 Mamba2 layers per the
Zamba2 design. [arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba2",),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    # at 500k decode the shared attn block runs sliding-window (see DESIGN.md)
    sliding_window=None,
)
