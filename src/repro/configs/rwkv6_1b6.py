"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

24L, d_model=2048, d_ff=7168, vocab=65536. [arXiv:2404.05892]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / 64 time-mix heads
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    rwkv_head_dim=64,
)
