"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution.

28L, d_model=3584, 28H (GQA kv=4), d_ff=18944, vocab=152064.  The ViT
vision encoder + projector is a STUB per the brief: ``input_specs`` feeds
precomputed patch embeddings.  [arXiv:2409.12191]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    m_rope=True,
    m_rope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
    vision_patches=256,
    frontend_dim=1280,     # ViT output dim before projector
)
