"""Config registry: ``--arch <id>`` lookup for every assigned architecture."""
from __future__ import annotations

from repro.configs.base import ArchConfig, reduced
from repro.configs import (
    whisper_large_v3,
    yi_6b,
    qwen15_4b,
    minitron_4b,
    rwkv6_1b6,
    qwen2_vl_7b,
    zamba2_2b7,
    qwen3_4b,
    mixtral_8x22b,
    dbrx_132b,
)

REGISTRY: dict[str, ArchConfig] = {
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "yi-6b": yi_6b.CONFIG,
    "qwen1.5-4b": qwen15_4b.CONFIG,
    "minitron-4b": minitron_4b.CONFIG,
    "rwkv6-1.6b": rwkv6_1b6.CONFIG,
    "qwen2-vl-7b": qwen2_vl_7b.CONFIG,
    "zamba2-2.7b": zamba2_2b7.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "qwen3-4b-swa": qwen3_4b.CONFIG_SWA,   # beyond-paper long-context variant
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
}

# The 10 assigned architectures (qwen3-4b-swa is a variant, not an assignment).
ASSIGNED = [
    "whisper-large-v3",
    "yi-6b",
    "qwen1.5-4b",
    "minitron-4b",
    "rwkv6-1.6b",
    "qwen2-vl-7b",
    "zamba2-2.7b",
    "qwen3-4b",
    "mixtral-8x22b",
    "dbrx-132b",
]


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ArchConfig", "REGISTRY", "ASSIGNED", "get_config", "reduced"]
