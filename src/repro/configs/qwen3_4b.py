"""qwen3-4b [dense] — qk_norm, GQA.

36L, d_model=2560, 32H (GQA kv=8), d_ff=9728, vocab=151936. [hf:Qwen/Qwen3-8B]

``long_500k`` for this arch uses the beyond-paper sliding-window variant
(``CONFIG_SWA``); the faithful full-attention CONFIG is used elsewhere.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

# Beyond-paper block-sparse/sliding-window variant (unlocks long_500k).
CONFIG_SWA = CONFIG.replace(name="qwen3-4b-swa", sliding_window=4096)
