"""Flash-decode attention — Pallas TPU kernel.

One query token per sequence against a long KV cache:
grid = (batch, q_heads, kv_blocks); the kv axis is sequential and
carries the online-softmax state (m, l, acc) in VMEM scratch.  Supports
GQA, a per-batch valid length (linear caches) and absolute kv position
masking for rolling SWA buffers.

The (1, hd) query row stays resident in VMEM; each grid step streams one
(bk, hd) KV tile from HBM — the kernel is purely memory-bound, as decode
attention should be.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, qpos_ref, kvpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, sm_scale: float,
                   window: int | None, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale           # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T)                                            # (1, bk)

    qp = qpos_ref[0]                                         # () int32
    kp = kvpos_ref[0][None, :]                               # (1, bk)
    mask = (kp >= 0) & (kp <= qp)
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, q_positions, kv_positions, *,
                     window: int | None = None, bk: int = 512,
                     interpret: bool = False):
    """q: (B, 1, H, hd); k, v: (B, S, KV, hd);
    q_positions: (B,) int32; kv_positions: (B, S) int32 (absolute positions,
    -1 for never-written rolling slots).  Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    sm_scale = 1.0 / math.sqrt(hd)

    qt = q.reshape(B, H, 1, hd)
    kt = k.swapaxes(1, 2)                                    # (B, KV, S, hd)
    vt = v.swapaxes(1, 2)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=sm_scale, window=window,
                          bk=bk, nk=nk),
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((1, bk), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, q_positions, kv_positions)
    return out.reshape(B, 1, H, hd)
