"""Flash attention (prefill/train) — Pallas TPU kernel.

Blockwise online-softmax attention with explicit VMEM tiling:
grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks axis is
sequential ("arbitrary") and carries running (max, sum, acc) in VMEM
scratch.  Supports GQA (kv head = q head // group), causal masks and
sliding windows; fully-masked kv blocks are skipped via the grid
index_map so SWA costs O(S * window).

TPU adaptation (DESIGN.md): block shapes are multiples of the 128-lane
MXU tiling; the f32 accumulator lives in VMEM scratch across the
sequential kv axis; HBM->VMEM streaming is expressed by the BlockSpecs.
Validated in interpret mode on CPU against `repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale: float, causal: bool, window: int | None,
                 bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = q @ k.T                                             # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                     # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    sm_scale = 1.0 / math.sqrt(hd)

    # (B, S, H, hd) -> blocked (1, 1, bq, hd) per (b, h, qi)
    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0))
    o_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0))

    kernel = functools.partial(_attn_kernel, sm_scale=sm_scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    qt = q.swapaxes(1, 2)        # (B, H, S, hd)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)
