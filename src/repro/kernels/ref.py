"""Pure-jnp oracles for every Pallas kernel (exact, unchunked)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """Naive quadratic attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32))
    qi = jnp.arange(S)[:, None]
    si = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), jnp.bool_)
    if causal:
        mask &= si <= qi
    if window is not None:
        mask &= si > qi - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, q_positions, kv_positions, *,
                         window: int | None = None):
    """q: (B,1,H,hd); k,v: (B,S,KV,hd); positions as in the kernel."""
    B, _, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32))
    kp = kv_positions[:, None, None, None, :]
    qp = q_positions[:, None, None, None, None]
    mask = (kp >= 0) & (kp <= qp)
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def rwkv6_ref(r, k, v, logw, u):
    """Exact sequential RWKV6 recurrence (per-step lax.scan).
    r,k,v,logw: (B,S,H,hd); u: (H,hd) -> (y (B,S,H,hd) f32, S (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    rf = r.astype(jnp.float32).swapaxes(0, 1)        # (S,B,H,hd)
    kf = k.astype(jnp.float32).swapaxes(0, 1)
    vf = v.astype(jnp.float32).swapaxes(0, 1)
    wf = jnp.exp(logw.astype(jnp.float32)).swapaxes(0, 1)
    uf = u.astype(jnp.float32)

    def step(S0, xs):
        rt, kt, vt, wt = xs                          # (B,H,hd)
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt, S0 + uf[None, :, :, None] * kv)
        S1 = S0 * wt[..., None] + kv
        return S1, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_fin, ys = jax.lax.scan(step, S0, (rf, kf, vf, wf))
    return ys.swapaxes(0, 1), S_fin


def ssd_ref(xdt, Bm, Cm, dA):
    """Exact sequential SSD recurrence.
    xdt: (B,S,H,hd); Bm,Cm: (B,S,H,N); dA: (B,S,H) <= 0."""
    B, S, H, hd = xdt.shape
    xf = xdt.astype(jnp.float32).swapaxes(0, 1)
    bf = Bm.astype(jnp.float32).swapaxes(0, 1)
    cf = Cm.astype(jnp.float32).swapaxes(0, 1)
    af = jnp.exp(dA.astype(jnp.float32)).swapaxes(0, 1)   # (S,B,H)

    def step(h, xs):
        xt, bt, ct, at = xs
        h = h * at[..., None, None] + jnp.einsum("bhd,bhn->bhdn", xt, bt)
        y = jnp.einsum("bhn,bhdn->bhd", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, hd, Bm.shape[-1]), jnp.float32)
    h_fin, ys = jax.lax.scan(step, h0, (xf, bf, cf, af))
    return ys.swapaxes(0, 1), h_fin
