"""Mamba2 SSD chunked scan — Pallas TPU kernel.

grid = (batch, heads, chunks); sequential chunk axis carries the
(hd, N) SSD state in VMEM scratch.  Per chunk (all in VMEM):

    L[t,s]   = exp(cum_t - cum_s) * (s <= t)          (scalar decay/head)
    y_intra  = ((C B^T) * L) @ xdt
    y_inter  = (C @ S0) * exp(cum_t)
    S        = S0 * exp(cum_Q) + B'^T @ xdt           (B' decay-weighted)

Inputs are per-head tensors after conv/projection; dA = dt * A <= 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, da_ref, y_ref, sfin_ref, s_scr,
                *, Q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, hd)  x * dt
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    dA = da_ref[0, 0].astype(jnp.float32)        # (Q, 1)

    cum = jnp.cumsum(dA[:, 0])                   # (Q,)
    diff = cum[:, None] - cum[None, :]           # (Q, Q)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ti >= si, jnp.exp(diff), 0.0)
    scores = (Cm @ Bm.T) * L                     # (Q, Q)
    S0 = s_scr[...]                              # (hd, N)
    y = scores @ x                               # (Q, hd)
    y = y + jnp.exp(cum)[:, None] * (Cm @ S0.T)  # (Q, hd)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    w = jnp.exp(cum[-1] - cum)[:, None]          # (Q, 1)
    s_scr[...] = S0 * jnp.exp(cum[-1]) + x.T @ (Bm * w)

    @pl.when(ci == nc - 1)
    def _finish():
        sfin_ref[0, 0] = s_scr[...].astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_chunk", "interpret"))
def ssd_scan(xdt, Bm, Cm, dA, *, q_chunk: int = 128, interpret: bool = False):
    """xdt: (B, S, H, hd) = x * dt; Bm, Cm: (B, S, H, N); dA: (B, S, H) <= 0.
    Returns (y (B,S,H,hd), final state (B,H,hd,N) f32)."""
    B, S, H, hd = xdt.shape
    N = Bm.shape[-1]
    Q = min(q_chunk, S)
    assert S % Q == 0
    nc = S // Q

    def t(x):
        return x.swapaxes(1, 2)                  # (B, H, S, ...)

    y, s_fin = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q, nc=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), xdt.dtype),
            jax.ShapeDtypeStruct((B, H, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(t(xdt), t(Bm), t(Cm), t(dA)[..., None])
    return y.swapaxes(1, 2), s_fin
