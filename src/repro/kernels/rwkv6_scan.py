"""RWKV6 chunked WKV recurrence — Pallas TPU kernel.

grid = (batch, heads, chunks); the chunk axis is sequential and carries
the (hd, hd) per-head state in VMEM scratch.  Each step computes the
exact factorized intra-chunk score matmul (see repro.models.rwkv) plus
the carried-state contribution, entirely in VMEM:

    y_t = r_t (S + diag(u) k_t^T v_t) ;  S <- diag(w_t) S + k_t^T v_t

Inputs are the post-projection per-head tensors; logw must already be
clamped (LOGW_CLAMP in repro.models.rwkv) so exp(cum_Q - cum_s) stays in
f32 range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sfin_ref, s_scr,
                *, Q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)          # (Q, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # (Q, hd), <= 0
    u = u_ref[0].astype(jnp.float32)             # (1, hd)

    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    tot = cum[-1:, :]                            # (1, hd)
    r_f = r * jnp.exp(cum_prev - tot)
    k_f = k * jnp.exp(tot - cum)
    scores = r_f @ k_f.T                         # (Q, Q) = r.k * exp ratios
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    scores = jnp.where(ti > si, scores, 0.0)
    diag = jnp.sum(r * (u * k), axis=1)          # (Q,)
    scores = scores + jnp.diag(diag)
    S0 = s_scr[...]                              # (hd, hd)
    y = scores @ v + (r * jnp.exp(cum_prev)) @ S0
    y_ref[0, 0] = y.astype(y_ref.dtype)
    s_scr[...] = S0 * jnp.exp(tot).T + k_f.T @ v

    @pl.when(ci == nc - 1)
    def _finish():
        sfin_ref[0, 0] = s_scr[...].astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, *, q_chunk: int = 32,
               interpret: bool = False):
    """r,k,v,logw: (B, S, H, hd); u: (H, hd).
    Returns (y (B,S,H,hd), final state (B,H,hd,hd) f32)."""
    B, S, H, hd = r.shape
    Q = min(q_chunk, S)
    assert S % Q == 0
    nc = S // Q

    def t(x):
        return x.swapaxes(1, 2)                  # (B, H, S, hd)

    y, s_fin = pl.pallas_call(
        functools.partial(_wkv_kernel, Q=Q, nc=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(t(r), t(k), t(v), t(logw), u)
    return y.swapaxes(1, 2), s_fin
