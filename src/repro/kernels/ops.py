"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on TPU the
same calls lower to Mosaic.  `INTERPRET` flips automatically.
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6
from repro.kernels.ssd_scan import ssd_scan as _ssd

INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=INTERPRET)


def decode_attention(q, k, v, q_positions, kv_positions, *, window=None,
                     bk=512):
    return _decode(q, k, v, q_positions, kv_positions, window=window, bk=bk,
                   interpret=INTERPRET)


def rwkv6_scan(r, k, v, logw, u, *, q_chunk=32):
    return _rwkv6(r, k, v, logw, u, q_chunk=q_chunk, interpret=INTERPRET)


def ssd_scan(xdt, Bm, Cm, dA, *, q_chunk=128):
    return _ssd(xdt, Bm, Cm, dA, q_chunk=q_chunk, interpret=INTERPRET)
