"""Workload characteristics of the served models.

Bridges the JAX model zoo and the iGniter provisioning study: every
served model gets a `ServedModelDesc` whose FLOPs / bytes / kernel-count
/ IO sizes are derived from the *actual architecture configs* (analytic
formulas cross-checked against ``compiled.cost_analysis()`` in tests).
These feed the ground-truth simulator physics AND the (separately fitted)
iGniter coefficients — the simulator adds contention/noise on top, so the
model-vs-measurement comparison stays honest.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs import REGISTRY, get_config
from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ServedModelDesc:
    """One inference 'query' type: a model + fixed request shape.

    A request item = prefill of `prompt_len` tokens (plus modality
    embeddings) producing one scored continuation token — the LLM-serving
    analogue of the paper's single CNN inference.
    """
    name: str
    arch: str
    prompt_len: int
    # derived:
    flops_per_item: float       # forward FLOPs for one request item
    weight_bytes: float         # bytes of (active) weights read per pass
    act_bytes_per_item: float   # activation traffic per item
    n_kernels: int              # fused-computation count per pass
    d_load_mb: float            # host->HBM input MB per item
    d_feedback_mb: float        # HBM->host output MB per item


def _attn_flops(cfg: ArchConfig, s: int) -> float:
    # projections + scores + values, per token
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * cfg.d_model * (H * hd + 2 * KV * hd) + 2 * (H * hd) * cfg.d_model
    win = min(s, cfg.sliding_window or s)
    scores = 2 * 2 * H * hd * win            # q.k and attn.v per token (avg)
    return proj + scores


def _block_flops_per_token(cfg: ArchConfig, kind: str, s: int) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    if kind == "attn":
        mlp = (6 if cfg.act_fn == "silu" else 4) * d * ff
        if cfg.is_moe:
            mlp *= cfg.top_k
            mlp += 2 * d * cfg.n_experts          # router
        return _attn_flops(cfg, s) + mlp
    if kind == "mamba2":
        d_in = cfg.ssm_expand * d
        H = cfg.ssm_heads or (d_in // cfg.ssm_head_dim)
        N = cfg.ssm_state
        proj = 2 * d * (2 * d_in + 2 * N + H) + 2 * d_in * d
        ssd = 2 * d_in * N * 2                     # state update + readout
        return proj + ssd
    if kind == "rwkv6":
        tm = 2 * 6 * d * d
        state = 2 * 2 * d * cfg.rwkv_head_dim      # (hd,hd) per-head update
        cm = 2 * 2 * d * ff
        return tm + state + cm
    raise ValueError(kind)


def forward_flops(cfg: ArchConfig, tokens: int, seq: int,
                  enc_frames: Optional[int] = None) -> float:
    """Total forward FLOPs for `tokens` tokens at context length `seq`."""
    per_tok = 0.0
    for kind in cfg.pattern:
        per_tok += _block_flops_per_token(cfg, kind, seq)
    if cfg.shared_attn_every:
        n_app = (cfg.n_layers + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        per_tok += n_app * (_attn_flops(cfg, seq)
                            + (6 if cfg.act_fn == "silu" else 4)
                            * cfg.d_model * cfg.d_ff)
    head = 2 * cfg.d_model * cfg.vocab_size
    total = (per_tok + head / max(seq, 1)) * tokens
    if cfg.encoder_layers:
        frames = enc_frames if enc_frames is not None else cfg.encoder_seq_len
        enc_per_tok = cfg.encoder_layers * (
            _attn_flops(cfg, frames) + 4 * cfg.d_model * cfg.d_ff)
        total += enc_per_tok * frames * (tokens / max(seq, 1))
    return total


def kernel_count(cfg: ArchConfig) -> int:
    """Fused-computation count per serving pass (XLA ~fuses each block into
    a handful of kernels; cross-checked against compiled HLO in tests)."""
    per_block = {"attn": 14 if not cfg.is_moe else 22, "mamba2": 16,
                 "rwkv6": 18}
    n = sum(per_block[k] for k in cfg.pattern)
    if cfg.shared_attn_every:
        n += 14 * ((cfg.n_layers + cfg.shared_attn_every - 1)
                   // cfg.shared_attn_every)
    if cfg.encoder_layers:
        n += 12 * cfg.encoder_layers
    return n + 12   # embed/head/norm/io


def make_served_desc(name: str, arch: str, prompt_len: int,
                     enc_frames: Optional[int] = None) -> ServedModelDesc:
    cfg = get_config(arch)
    flops = forward_flops(cfg, prompt_len, prompt_len, enc_frames)
    active = cfg.n_active_params()
    weight_bytes = 2.0 * active                       # bf16 weights per pass
    act_bytes = 2.0 * prompt_len * cfg.d_model * (len(cfg.pattern) * 4)
    d_load = prompt_len * 4 / 1e6                     # token ids
    if cfg.frontend == "audio":
        frames = enc_frames if enc_frames is not None else cfg.encoder_seq_len
        d_load += frames * cfg.d_model * 2 / 1e6
    if cfg.frontend == "vision":
        fd = cfg.frontend_dim or cfg.d_model
        d_load += cfg.vision_patches * fd * 2 / 1e6
    d_feedback = 8 * 4 / 1e6 + 32 * 4 / 1e6           # token + top-k logprobs
    return ServedModelDesc(
        name=name, arch=arch, prompt_len=prompt_len,
        flops_per_item=flops, weight_bytes=weight_bytes,
        act_bytes_per_item=act_bytes, n_kernels=kernel_count(cfg),
        d_load_mb=d_load, d_feedback_mb=d_feedback,
    )


# The serving-study model zoo (4 heterogeneous models, paper Table 3 analogue)
SERVING_MODELS: Dict[str, ServedModelDesc] = {}


def serving_models() -> Dict[str, ServedModelDesc]:
    global SERVING_MODELS
    if not SERVING_MODELS:
        SERVING_MODELS = {
            "rwkv6-1.6b": make_served_desc("rwkv6-1.6b", "rwkv6-1.6b", 64),
            "qwen1.5-4b": make_served_desc("qwen1.5-4b", "qwen1.5-4b", 64),
            "qwen2-vl-7b": make_served_desc("qwen2-vl-7b", "qwen2-vl-7b", 32),
            "whisper-large-v3": make_served_desc(
                "whisper-large-v3", "whisper-large-v3", 16, enc_frames=300),
        }
    return SERVING_MODELS
