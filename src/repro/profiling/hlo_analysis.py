"""Roofline-grade analysis of compiled (optimized) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any model
that `lax.scan`s over layers under-reports FLOPs/bytes by ~n_layers.
This module parses ``compiled.as_text()`` and walks the call graph
(entry -> while bodies x known_trip_count -> fusion bodies), computing:

  * flops            — 2 * prod(out dims) * prod(contracting dims) per dot
  * hbm_bytes        — per top-level op (fusion/dot/copy/collective):
                       sum(operand sizes) + output size; fused interiors
                       stay in VMEM/registers and are not counted
  * collective_bytes — effective ICI bytes per device with ring terms:
                       all-gather (g-1)/g * out ; all-reduce 2(g-1)/g * in;
                       reduce-scatter / all-to-all (g-1)/g * in ;
                       collective-permute in
  * per-collective breakdown for the §Perf iteration log

All numbers are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT )?(%[\w.\-]+|[\w.\-]+) = (\(.*?\)|\S+) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+|[\w.\-]+) \((.*)\) -> .* \{\s*$")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_elems_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the opening paren of operands

    def operands(self) -> List[str]:
        """Top-level operand names (skip nested parens)."""
        depth = 0
        out, cur = [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            if ch == "," and depth == 0:
                out.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        if cur:
            out.append("".join(cur).strip())
        names = []
        for o in out:
            o = o.split("(")[0].strip()
            if o.startswith("%") or re.match(r"^[\w.\-]+$", o):
                names.append(o.lstrip("%"))
        return names

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=([^,]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]              # param name -> type str
    ops: List[Op]
    by_name: Dict[str, Op]

    def type_of(self, operand: str) -> Optional[str]:
        operand = operand.lstrip("%")
        if operand in self.by_name:
            return self.by_name[operand].type_str
        return self.params.get(operand)


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            name = mc.group(2).lstrip("%")
            params = {}
            for pm in re.finditer(r"([\w.\-]+): (\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                                  mc.group(3)):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(name=name, params=params, ops=[], by_name={})
            comps[name] = cur
            if mc.group(1):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(name=mo.group(1).lstrip("%"), type_str=mo.group(2),
                    opcode=mo.group(3), rest=mo.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _group_size(rest: str, default: int = 1) -> int:
    m = _GROUPS_ITOTA_RE.search(rest)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_instances: List[Tuple[str, str, float, float]] = \
        dataclasses.field(default_factory=list)
    # (opcode, op name, raw bytes, effective ici bytes) x multiplier applied


def _dot_flops(comp: Computation, op: Op) -> float:
    out = _shape_dims(op.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    n_out = 1
    for d in out_dims:
        n_out *= d
    lhs_ops = op.operands()
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if m and lhs_ops:
        lt = comp.type_of(lhs_ops[0])
        if lt:
            ls = _shape_dims(lt)
            if ls:
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(ls[1]):
                        contract *= ls[1][idx]
    return 2.0 * n_out * contract


def analyze(txt: str) -> HloCosts:
    comps = parse_module(txt)
    entry = comps.get("__entry__")
    costs = HloCosts()
    if entry is None:
        return costs

    # computations that are "called" as fusions (interiors don't touch HBM
    # except dots still count flops)
    def walk(comp: Computation, mult: float, top_level: bool):
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = op.attr("body")
                cond = op.attr("condition")
                tm = _TRIP_RE.search(op.rest)
                trips = float(tm.group(1)) if tm else 1.0
                for cn in (body, cond):
                    if cn:
                        cn = cn.lstrip("%")
                        if cn in comps:
                            walk(comps[cn], mult * trips, top_level=True)
                continue
            if oc in ("fusion", "call", "custom-call", "conditional",
                      "async-start", "map", "reduce", "sort", "scatter",
                      "reduce-window", "select-and-scatter", "all-reduce"):
                for key in ("calls", "to_apply", "body",
                            "true_computation", "false_computation"):
                    a = op.attr(key)
                    if a:
                        cn = a.lstrip("%")
                        if cn in comps:
                            walk(comps[cn], mult, top_level=False)
            if oc == "dot":
                costs.flops += mult * _dot_flops(comp, op)
            if not top_level:
                continue
            # HBM traffic & collectives only for top-level ops
            if oc in _ZERO_TRAFFIC:
                continue
            out_b = _shape_elems_bytes(op.type_str)
            op_sizes = []
            for o in op.operands():
                t = comp.type_of(o)
                if t:
                    op_sizes.append(_shape_elems_bytes(t))
            is_dus = (oc == "dynamic-update-slice"
                      or (oc == "fusion" and "dynamic-update-slice" in op.name))
            if oc == "dynamic-slice" or (oc == "fusion"
                                         and "dynamic-slice" in op.name
                                         and not is_dus):
                # reads only the sliced window: in ~= out
                traffic = 2.0 * out_b
            elif is_dus:
                # in-place slice write (buffer aliased): traffic ~ 2x update
                update = sum(op_sizes) - (max(op_sizes) if op_sizes else 0)
                traffic = 2.0 * update
            else:
                # cap pathological operands (e.g. scan xs buffers feeding a
                # fused slice) at 4x the output size
                in_b = sum(min(s, 4 * max(out_b, 1)) for s in op_sizes)
                traffic = out_b + in_b
            costs.hbm_bytes += mult * traffic
            in_b = sum(op_sizes)
            if oc in _COLLECTIVES:
                g = _group_size(op.rest)
                ring = (g - 1) / g if g > 1 else 0.0
                if oc == "all-gather":
                    eff = ring * out_b
                elif oc == "all-reduce":
                    eff = 2.0 * ring * in_b
                elif oc == "reduce-scatter":
                    eff = ring * in_b
                elif oc == "all-to-all":
                    eff = ring * in_b
                else:  # collective-permute
                    eff = float(in_b)
                costs.collective_bytes += mult * eff
                costs.per_collective[oc] += mult * eff
                costs.collective_instances.append(
                    (oc, op.name, mult * in_b, mult * eff))
    walk(entry, 1.0, top_level=True)
    return costs


# ---------------------------------------------------------------------------
# Roofline terms (per device, TPU v5e constants per the brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (~3 links usable per axis hop)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    per_collective: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_from_hlo(txt: str, *, peak_flops: float = PEAK_FLOPS,
                      hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW
                      ) -> Roofline:
    c = analyze(txt)
    return Roofline(
        compute_s=c.flops / peak_flops,
        memory_s=c.hbm_bytes / hbm_bw,
        collective_s=c.collective_bytes / ici_bw,
        flops=c.flops, hbm_bytes=c.hbm_bytes,
        collective_bytes=c.collective_bytes,
        per_collective=dict(c.per_collective),
    )
