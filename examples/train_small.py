"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on the packed synthetic pipeline, with checkpointing.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import tempfile

import numpy as np

from repro.configs import get_config
from repro.training.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param variant of the chosen family
    cfg = get_config(args.arch).replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, dtype="float32")
    n = cfg.n_params()
    print(f"training {cfg.name} variant: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        report = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=ckpt_dir, ckpt_every=100)
    first = float(np.mean(report.losses[:20]))
    last = float(np.mean(report.losses[-20:]))
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({report.tokens_per_s:,.0f} tokens/s)")
    assert last < first - 0.5, "loss did not decrease as expected"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
