"""Serve a small model with batched requests through the JAX serving
engine, using the iGniter-configured batch size, and report latencies +
shadow-failover behavior.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

from repro.configs import REGISTRY, reduced
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = reduced(REGISTRY["qwen3-4b"], layers=4, d_model=256)
    engine = ServingEngine(cfg, batch_size=4, prompt_len=32, decode_tokens=4)
    rng = np.random.default_rng(0)

    print("serving 64 batched requests (batch=4, prompt=32, decode=4)...")
    completions = []
    rid = 0
    for wave in range(16):
        for _ in range(4):
            engine.submit(Request(
                rid=rid,
                tokens=rng.integers(3, cfg.vocab_size, size=32).astype(np.int32),
                arrival_s=time.time()))
            rid += 1
        completions.extend(engine.pump())
    lats = np.array([c.latency_ms for c in completions])
    print(f"served {len(completions)} requests: "
          f"p50={np.percentile(lats,50):.1f}ms p99={np.percentile(lats,99):.1f}ms")
    print(f"sample continuation tokens: {completions[0].tokens[:4]}")


if __name__ == "__main__":
    main()
