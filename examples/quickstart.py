"""Quickstart: the full iGniter pipeline in one script.

1. Profile the serving models against the ground-truth testbed
   (11 solo configs + pair runs each, per paper Sec. 3.1).
2. Provision GPU/TPU resources for the 12-workload App study with
   Algorithm 1 (iGniter) and the three baselines.
3. Validate SLOs in the discrete-event cluster simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.experiments import all_plans, evaluate_plans, fitted_context
from repro.core.provisioner import predicted_plan_metrics
from repro.serving.workload import specs_by_name


def main():
    print("== fitting coefficients from 11-config lightweight profiling ==")
    ctx = fitted_context()
    for name, c in ctx.profiles.items():
        print(f"  {name:18s} k_act=({c.k1:.3g} b^2 + {c.k2:.3g} b + {c.k3:.3g})"
              f"/(r + {c.k4:.3g}) + {c.k5:.3g}   alpha_cache={c.alpha_cache:.3f}")

    print("\n== provisioning plans (12 workloads, paper Table 3 analogue) ==")
    plans = all_plans(ctx)
    results = evaluate_plans(plans, ctx)
    sb = specs_by_name()
    for name, r in results.items():
        v = r["violations"]
        print(f"  {name:10s} devices={r['n_gpus']:2d} "
              f"cost=${r['cost_per_hour']:6.2f}/h  SLO violations={len(v)} {v}")

    ig = results["iGniter"]["cost_per_hour"]
    gl = results["gpu-lets+"]["cost_per_hour"]
    print(f"\n  iGniter saves {100 * (gl - ig) / gl:.0f}% vs gpu-lets+ "
          f"(paper: up to 25%)")

    print("\n== iGniter plan detail ==")
    print(results["iGniter"]["plan"].summary())
    pred = predicted_plan_metrics(results["iGniter"]["plan"], ctx.profiles,
                                  ctx.hw)
    print("\n== model-predicted vs simulator-observed latency ==")
    for w, m in sorted(results["iGniter"]["result"].per_workload.items(),
                       key=lambda kv: int(kv[0][1:])):
        s = sb[w]
        print(f"  {w:4s} predicted t_inf={pred[w].t_inf:7.2f} ms | observed "
              f"p99={m['p99_ms']:7.2f} ms | SLO {s.slo_ms:5.0f} ms | "
              f"rps {m['rps']:6.1f}/{s.rate_rps:.0f}")


if __name__ == "__main__":
    main()
