"""Demonstrate iGniter's shadow-instance failover (paper Sec. 4.2,
Fig. 17): deliberately under-provision one workload (simulating a
performance-prediction error), watch its P99 violate the SLO, and show
the monitor activating the pre-launched shadow process within ~1.5 s.

Run:  PYTHONPATH=src python examples/shadow_failover.py
"""
from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads


def main():
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)

    # inject a prediction error: shave 2 resource units off W1
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    print(f"under-provisioned W1 to {victim.r*100:.1f}% (simulated "
          f"prediction error)")

    res = simulate_plan(plan, models(), ctx.hw, duration_s=20.0,
                        shadow=True, record_timeline=True)
    m = res.per_workload["W1"]
    print(f"W1: p99={m['p99_ms']:.1f} ms (SLO "
          f"{specs_by_name()['W1'].slo_ms:.0f} ms), shadow activated: "
          f"{m['shadow_used']}")
    tl = [t for t in res.timeline if t["workload"] == "W1"]
    for t in tl[:8]:
        print(f"  t={t['t_s']:4.1f}s p99(1s)={t['p99_1s']:7.1f} ms "
              f"r={t['r']*100:4.1f}% shadow={t['shadow']}")
    assert m["shadow_used"], "shadow failover should have triggered"
    print("OK: shadow failover engaged and recovered the SLO")


if __name__ == "__main__":
    main()
