"""Calibration tier: the queueing-aware budget split against ground truth.

Pins the headline honesty metric of the repo — the predicted-vs-simulated
SLO-violation gap — at m=100 full-cluster scale on fixed seeds:

  * under Poisson arrivals the half-split plan (zero tail slack,
    utilization ~1 at the provisioned point) violates en masse while the
    queueing-aware plan stays within a pinned bound,
  * under the sweep's constant-rate arrivals the queueing-aware plan
    simulates clean while the half split shows the documented gap,
  * simulated violations stay inside the model's predicted set (no
    SURPRISE violations: the model over-approximates, never under), and
  * the measured per-request queueing delay is bracketed by the model's
    t_queue terms (expected is a conservative envelope of the measured
    mean; tail covers the measured p99 wait for almost every workload).

These are seeded, full-cluster discrete-event simulations — a few
hundred thousand events per case — kept fast by the vectorized engine.
"""
import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.core.queueing import QUEUEING, t_queue
from repro.core.types import PlannerConfig
from repro.serving.simulator import simulate_full
from repro.serving.workload import models, synthetic_workloads

M = 100
SEEDS = (0, 1)
POISSON_VIOLATION_BOUND = 25      # pinned: measured 16-18 at defaults
CONSTANT_VIOLATION_BOUND = 3      # pinned: measured 0 at defaults

# the whole calibration tier runs once per backend: jax-planned plans
# are bit-identical to numpy's, so every pinned bound must hold
# unchanged with the jitted planner in the loop (jax CI job only)
BACKENDS = ("numpy", pytest.param("jax", marks=pytest.mark.jax))


@pytest.fixture(scope="module", params=BACKENDS)
def plans(request):
    backend = request.param
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles = {ctx5.hw.name: ctx5.profiles, ctx4.hw.name: ctx4.profiles}
    hardware = [ctx5.hw, ctx4.hw]
    specs = synthetic_workloads(M, 0)
    out = {}
    for budget in ("half", "queueing"):
        cfg = PlannerConfig(budget=budget, backend=backend)
        plan, hw = prov.provision_cheapest(specs, profiles, hardware,
                                           config=cfg)
        pred = prov.predicted_violations(plan, profiles[hw.name], hw,
                                         config=cfg)
        out[budget] = (plan, hw, set(pred), profiles[hw.name])
    return specs, out


def test_queueing_plan_tightens_not_loosens(plans):
    """Same workloads, same batches, never-smaller allocations (and so
    never-fewer devices) than the half split."""
    specs, out = plans
    plan_h, _, _, _ = out["half"]
    plan_q, _, _, _ = out["queueing"]
    by_h = {p.workload.name: p for p in plan_h.placements}
    by_q = {p.workload.name: p for p in plan_q.placements}
    assert set(by_h) == set(by_q) == {s.name for s in specs}
    for name in by_h:
        assert by_q[name].batch <= by_h[name].batch
    assert plan_q.n_gpus >= plan_h.n_gpus


@pytest.mark.parametrize("seed", SEEDS)
def test_poisson_violation_gap_closed(plans, seed):
    """Poisson arrivals, 10 simulated seconds, every device: the
    queueing-aware plan's violations stay under the pinned bound and
    strictly below the half-split plan's."""
    specs, out = plans
    sb = {s.name: s for s in specs}
    mods = models()
    counts = {}
    for budget in ("half", "queueing"):
        plan, hw, _, _ = out[budget]
        res = simulate_full(plan, mods, hw, duration_s=10.0, seed=seed,
                            poisson=True)
        counts[budget] = len(res.violations(sb))
    assert counts["queueing"] <= POISSON_VIOLATION_BOUND, counts
    assert counts["queueing"] < counts["half"], counts


def test_constant_rate_gap_and_no_surprise_violations(plans):
    """The sweep's constant-rate scenario: the queueing-aware plan
    simulates within the pinned bound AND every simulated violation was
    predicted (the model over-approximates, never under); the half split
    reproduces the documented gap (0 predicted, dozens simulated)."""
    specs, out = plans
    sb = {s.name: s for s in specs}
    mods = models()
    plan_q, hw_q, pred_q, _ = out["queueing"]
    res_q = simulate_full(plan_q, mods, hw_q, duration_s=10.0, seed=0)
    sim_q = set(res_q.violations(sb))
    assert len(sim_q) <= CONSTANT_VIOLATION_BOUND
    assert sim_q <= pred_q      # no surprise violations

    plan_h, hw_h, pred_h, _ = out["half"]
    res_h = simulate_full(plan_h, mods, hw_h, duration_s=10.0, seed=0)
    sim_h = set(res_h.violations(sb))
    assert len(pred_h) == 0     # the half split PREDICTS clean...
    assert len(sim_h) >= 10     # ...and violates at scale (the gap)
    assert len(sim_q) < len(sim_h)


def test_measured_wait_within_model_tolerance(plans):
    """The model's t_queue terms bracket the measured queueing delay on
    the queueing-aware plan under Poisson arrivals: per workload, the
    tail term covers the measured p99 wait (>= 85% of workloads) and the
    expected term is a conservative envelope of the measured mean —
    never more than ~1.5x BELOW it, never more than ~15x above."""
    specs, out = plans
    mods = models()
    plan, hw, _, profiles = out["queueing"]
    res = simulate_full(plan, mods, hw, duration_s=10.0, seed=0,
                        poisson=True)
    pred = prov.predicted_plan_metrics(plan, profiles, hw)

    n_cover = n_finite = 0
    for p in plan.placements:
        s = p.workload
        t_inf = pred[s.name].t_inf
        qd = t_queue(p.batch, s.rate_rps, t_inf,
                     quantile=QUEUEING.quantile,
                     burstiness=QUEUEING.burstiness)
        w_mean = res.per_workload[s.name]["wait_avg_ms"]
        w_p99 = res.per_workload[s.name]["wait_p99_ms"]
        if not np.isfinite(qd.tail):
            continue            # clamped residual: model declares unstable
        n_finite += 1
        n_cover += w_p99 <= qd.tail + 1e-9
        assert w_mean <= 1.5 * qd.expected + 2.0, \
            (s.name, w_mean, qd.expected)
        assert qd.expected <= 15.0 * w_mean + 5.0, \
            (s.name, w_mean, qd.expected)
    assert n_finite >= 0.9 * len(plan.placements)
    assert n_cover >= 0.85 * n_finite


def test_request_wait_accounting_consistent(plans):
    """wait + service decomposition: per-request waits are nonnegative,
    bounded by the end-to-end latency, and reported in stats."""
    specs, out = plans
    mods = models()
    plan, hw, _, _ = out["queueing"]
    res = simulate_full(plan, mods, hw, duration_s=5.0, seed=0,
                        poisson=True)
    assert set(res.request_waits) == set(res.request_latencies)
    for name, w in res.request_waits.items():
        lat = res.request_latencies[name]
        assert w.shape == lat.shape
        assert (w >= -1e-12).all()
        assert (w <= lat + 1e-12).all()
    for key in ("e2e_p50_ms", "e2e_p99_ms", "wait_mean_ms", "wait_p99_ms"):
        assert np.isfinite(res.stats[key])
    assert res.stats["e2e_p50_ms"] <= res.stats["e2e_p99_ms"]
