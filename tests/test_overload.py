"""Overload regime: device caps, priority admission, the shed /
readmit lifecycle, probe-based quarantine readmission, and replica
lifecycle edges.

Pins the PR's acceptance behavior:

  * `provision` / `add_workload` / `resize_workload` raise STRUCTURED
    errors under a device cap (`DeviceCapError.per_hw`), and Theorem-1
    infeasibility carries ``per_hw`` through the workload-edit paths;
  * a slack cap is a byte-identical no-op: controlled runs with
    ``max_devices`` far above the fleet match cap-less runs exactly
    (streams AND stats — no overload keys appear);
  * the admission layer preempts strictly-lower-priority groups, the
    shed workload is NOT mistaken for a departure while its arrivals
    continue, and readmission restores it from live estimator priors;
  * a preempt-then-readmit controlled run is byte-identical across
    simulator engines;
  * quarantine readmission is an ACTIVE probe: a permanently slow
    device stays quarantined forever, a recovered device is readmitted
    at probation expiry;
  * replica lifecycle edges: `merge_workload` renormalizes unequal
    survivor shares, and a zero-share park / re-activate round-trip
    loses no requests and never counts as shedding.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core import replication
from repro.core.experiments import fitted_context
from repro.core.types import Placement, PlannerConfig, WorkloadSpec
from repro.serving import faults, traces
from repro.serving.controller import (ArrivalEstimator, Controller,
                                      ControllerConfig, Reconciler)
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, twelve_workloads

WINDOW_MS = 1000.0
_WALL_KEYS = ("wall_s", "events_per_s", "reconfig_latency_ms")


@pytest.fixture(scope="module")
def ctx12():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    return ctx, plan


@pytest.fixture(scope="module")
def prio12():
    """twelve_workloads with W1 promoted to priority 1 (the high tier)."""
    ctx = fitted_context()
    specs = [dataclasses.replace(s, priority=1) if s.name == "W1" else s
             for s in twelve_workloads()]
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    return ctx, specs, plan


def _det_window(rate_rps, window_ms=WINDOW_MS, t0=0.0):
    period = 1000.0 / rate_rps
    return t0 + np.arange(period / 2.0, window_ms, period)


def _estimators(plan, cfg=None):
    return {p.workload.name: ArrivalEstimator(p.workload.rate_rps, cfg)
            for p in plan.placements}


def _identical(a, b, *, stats=True):
    assert set(a.request_latencies) == set(b.request_latencies)
    for k in a.request_latencies:
        assert np.array_equal(a.request_latencies[k],
                              b.request_latencies[k]), k
        assert np.array_equal(a.request_waits[k], b.request_waits[k]), k
    assert a.per_workload == b.per_workload
    if stats:
        sa = {k: v for k, v in a.stats.items() if k not in _WALL_KEYS}
        sb = {k: v for k, v in b.stats.items() if k not in _WALL_KEYS}
        assert sa == sb


# ---------------------------------------------------------------------------
# Structured capacity errors
# ---------------------------------------------------------------------------

def test_provision_device_cap_raises_structured(ctx12):
    ctx, plan = ctx12
    with pytest.raises(prov.DeviceCapError) as ei:
        prov.provision(twelve_workloads(), ctx.profiles, ctx.hw,
                       max_devices=max(1, plan.n_gpus - 1))
    err = ei.value
    assert isinstance(err, prov.InfeasibleError)   # catchable as before
    assert err.per_hw and ctx.hw.name in err.per_hw
    # a slack cap changes nothing at all
    capped = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw,
                            max_devices=plan.n_gpus)
    assert capped == plan


def test_add_workload_cap_and_per_hw(ctx12):
    ctx, plan = ctx12
    template = twelve_workloads()[0]
    hog = dataclasses.replace(template, name="HOG",
                              rate_rps=template.rate_rps * 3.0)
    # cap frozen at the current fleet: the add needs a fresh device
    with pytest.raises(prov.DeviceCapError) as ei:
        prov.add_workload(plan, hog, ctx.profiles, ctx.hw,
                          max_devices=plan.n_gpus)
    assert ei.value.per_hw and ctx.hw.name in ei.value.per_hw
    # Theorem-1 infeasibility (SLO below the floor) also carries per_hw
    doomed = dataclasses.replace(template, name="DOOMED", slo_ms=1e-3)
    with pytest.raises(prov.InfeasibleError) as ei:
        prov.add_workload(plan, doomed, ctx.profiles, ctx.hw)
    assert ei.value.per_hw and ctx.hw.name in ei.value.per_hw


def test_resize_workload_infeasible_carries_per_hw(ctx12):
    ctx, plan = ctx12
    spec = plan.placements[0].workload
    doomed = dataclasses.replace(spec, slo_ms=1e-3)
    with pytest.raises(prov.InfeasibleError) as ei:
        prov.resize_workload(plan, doomed, ctx.profiles, ctx.hw)
    assert ei.value.per_hw and ctx.hw.name in ei.value.per_hw


def test_provision_cheapest_cap_aggregates_per_hw(ctx12):
    ctx, _ = ctx12
    with pytest.raises(prov.InfeasibleError) as ei:
        prov.provision_cheapest(twelve_workloads(),
                                {ctx.hw.name: ctx.profiles}, [ctx.hw],
                                max_devices=1)
    assert ctx.hw.name in ei.value.per_hw


# ---------------------------------------------------------------------------
# Priority vocabulary
# ---------------------------------------------------------------------------

def test_preemption_order_priority_then_footprint():
    def grp(name, pr, rs):
        spec = WorkloadSpec(name=name, model="m", slo_ms=50.0,
                            rate_rps=100.0, priority=pr)
        return [Placement(workload=spec, gpu=i, r=r, batch=4)
                for i, r in enumerate(rs)]
    groups = {
        "hi":   grp("hi", 1, [1.0, 1.0]),       # high class: last
        "big":  grp("big", 0, [1.0, 0.8]),      # largest footprint first
        "mid":  grp("mid", 0, [0.9]),
        "tie":  grp("tie", 0, [0.9]),           # same footprint: by name
    }
    assert replication.preemption_order(groups) == \
        ["big", "mid", "tie", "hi"]


# ---------------------------------------------------------------------------
# Slack cap == byte-identical no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("scalar", "vec"))
def test_cap_slack_controlled_run_byte_identical(ctx12, engine):
    ctx, plan = ctx12
    mods = models()
    names = [p.workload.name for p in plan.placements]
    tr = traces.diurnal(names, 8000.0, peak=1.6)
    kw = dict(duration_s=8.0, poisson=True, seed=3, trace=tr,
              adjust_scope="cluster", adjust_period_s=1.0, engine=engine)
    ctl_a = Controller(plan, ctx.profiles, ctx.hw,
                       config=PlannerConfig(batch="joint"))
    a = simulate_plan(plan, mods, ctx.hw, adjust_fn=ctl_a, **kw)
    cfg = ControllerConfig(max_devices=plan.n_gpus * 10)
    ctl_b = Controller(plan, ctx.profiles, ctx.hw,
                       config=PlannerConfig(batch="joint"), cfg=cfg)
    b = simulate_plan(plan, mods, ctx.hw, adjust_fn=ctl_b, **kw)
    assert ctl_a.edits, "ramp should reconfigure (else this tests nothing)"
    _identical(a, b)
    assert "shed_requests" not in b.stats
    assert not any(k.startswith("class") for k in b.stats)
    assert ctl_b.overload_stats() == {}
    assert ctl_b.reconciler.admission_log == []


# ---------------------------------------------------------------------------
# Admission lifecycle: preempt -> shed (not departed) -> readmit
# ---------------------------------------------------------------------------

def test_preempt_shed_readmit_lifecycle(prio12):
    ctx, specs, plan = prio12
    cfg = ControllerConfig(max_devices=plan.n_gpus, headroom=0.35,
                           readmit_backoff_s=2.0)
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=cfg)
    ests = _estimators(plan, cfg)
    rate0 = {n: rec.targets[replication.base_name(n)].rate_rps
             for n in ests}
    hi = "W1"

    def tick(k, surge):
        for n, est in ests.items():
            r = rate0[n] * (surge if replication.base_name(n) == hi
                            else 1.0)
            est.observe(_det_window(r, t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)

    k = 0
    # phase 1: the high tier surges far past the capped fleet's slack
    while k < 8 and not rec.shed:
        tick(k, 3.0)
        k += 1
    assert rec.shed, "surge under a tight cap must preempt someone"
    assert rec._adm["preempt"] >= 1
    victims = list(rec.shed)
    assert all(s.priority == 0 for s in rec.shed.values())
    assert hi not in rec.shed

    # phase 2: victims' arrivals CONTINUE while shed — many windows of
    # real traffic must never flip them to "departed" (their silence on
    # the served side is policy, not drift), and the estimator keeps
    # tracking true demand
    for _ in range(10):
        tick(k, 3.0)
        k += 1
    for v in victims:
        assert v not in rec.departed
        assert v in rec.shed
        assert ests[v].rate_rps == pytest.approx(rate0[v], rel=0.1)

    # phase 3: the surge ends; the downsize frees capacity and the
    # shed workloads are readmitted from live estimator priors
    for _ in range(12):
        tick(k, 1.0)
        k += 1
    assert not rec.shed
    for v in victims:
        assert v in rec.targets
        assert rec.targets[v].rate_rps == pytest.approx(rate0[v], rel=0.2)
        group = replication.group_placements(rec.plan.placements)[v]
        assert sum(p.workload.rate_rps for p in group) == \
            pytest.approx(rec.targets[v].rate_rps)
    stats = rec.overload_stats()
    assert stats["admission_preemptions"] >= 1
    assert stats["admission_readmits"] >= len(victims)
    assert stats["shed_workloads_final"] == 0.0
    assert any(e.action == "preempt" for e in rec.edits)
    assert any(e.action == "admit" for e in rec.edits)


def test_preempt_then_readmit_engine_identical(prio12):
    """The whole preempt -> shed -> readmit arc, closed-loop in the
    simulator, byte-identical scalar vs vec (fresh controllers each)."""
    ctx, specs, plan = prio12
    mods = models()
    names = [p.workload.name for p in plan.placements]
    edges = np.array([0.0, 5000.0, 12000.0])
    scales = {n: np.array([3.0, 1.0]) if n == "W1"
              else np.array([1.0, 1.0]) for n in names}
    tr = traces.Trace(edges=edges, scales=scales)
    kw = dict(duration_s=12.0, poisson=False, seed=7, trace=tr,
              adjust_scope="cluster", adjust_period_s=1.0)
    runs = {}
    for engine in ("scalar", "vec"):
        cfg = ControllerConfig(max_devices=plan.n_gpus, headroom=0.35,
                               readmit_backoff_s=2.0)
        ctl = Controller(plan, ctx.profiles, ctx.hw,
                         config=PlannerConfig(batch="joint"), cfg=cfg)
        runs[engine] = (ctl, simulate_plan(plan, mods, ctx.hw,
                                           adjust_fn=ctl, engine=engine,
                                           **kw))
    a, b = runs["scalar"][1], runs["vec"][1]
    assert a.stats.get("admission_preemptions", 0) >= 1
    assert a.stats.get("shed_requests", 0) > 0
    _identical(a, b)
    assert runs["scalar"][0].reconciler.admission_log == \
        runs["vec"][0].reconciler.admission_log


# ---------------------------------------------------------------------------
# Probe-based quarantine readmission
# ---------------------------------------------------------------------------

def _health_run(ctx, plan, fs, duration_s=14.0):
    cfg = ControllerConfig(health_readmit_s=2.0)
    ctl = Controller(plan, ctx.profiles, ctx.hw,
                     config=PlannerConfig(batch="joint"), cfg=cfg)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=duration_s,
                        poisson=True, seed=0, faults=fs, adjust_fn=ctl,
                        adjust_scope="cluster", adjust_period_s=1.0)
    return ctl, res


def test_permanent_straggler_never_readmitted(ctx12):
    """Regression: readmission is an ACTIVE canary probe, not a timer.
    A device that is still slow at every probation expiry stays
    quarantined forever — the old time-based probation would have
    readmitted it after health_readmit_s and re-victimized the
    workloads placed back onto it."""
    ctx, plan = ctx12
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(slow={g: 2.5})
    ctl, _ = _health_run(ctx, plan, fs)
    # quarantined early, probation (2 s) expired many times over the
    # 12 s run, yet every probe saw the 2.5x residual and refused
    assert g in ctl.reconciler.quarantined
    assert g in ctl.health.quarantined
    assert not any(e.action == "readmit" for e in ctl.reconciler.edits)


def test_recovered_device_readmitted_by_probe(ctx12):
    """The counterpart: a device whose outage ENDS passes the canary at
    probation expiry and rejoins the placement pool."""
    ctx, plan = ctx12
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(down={g: [[2000.0, 5000.0]]})
    ctl, _ = _health_run(ctx, plan, fs)
    assert any(e.action == "readmit" and e.workload == f"device:{g}"
               for e in ctl.reconciler.edits)
    assert g not in ctl.reconciler.quarantined
    assert g not in ctl.health.quarantined


# ---------------------------------------------------------------------------
# Replica lifecycle edges
# ---------------------------------------------------------------------------

def test_merge_renormalizes_unequal_shares(ctx12):
    """Survivor shares after a merge sum to the base rate even when the
    pre-merge group carried unequal (capacity-proportional) shares."""
    ctx, plan = ctx12
    spec = plan.placements[0].workload
    plan3 = prov.split_workload(plan, spec, 3, ctx.profiles, ctx.hw)
    # skew the shares the way the controller's capacity-proportional
    # re-home would (0.5 / 0.3 / 0.2 of the base rate)
    shares = [0.5, 0.3, 0.2]
    skewed = []
    for p in plan3.placements:
        if replication.base_name(p.workload.name) == spec.name:
            j = replication.replica_index(p.workload.name)
            p = dataclasses.replace(p, workload=dataclasses.replace(
                p.workload, rate_rps=spec.rate_rps * shares[j]))
        skewed.append(p)
    plan3 = dataclasses.replace(plan3, placements=skewed)
    merged = prov.merge_workload(plan3, spec, 2, ctx.profiles, ctx.hw)
    group = replication.group_placements(merged.placements)[spec.name]
    assert len(group) == 2
    assert sum(p.workload.rate_rps for p in group) == \
        pytest.approx(spec.rate_rps)
    # merge-to-one returns the plain unreplicated name at the full rate
    plain = prov.merge_workload(merged, spec, 1, ctx.profiles, ctx.hw)
    back = [p for p in plain.placements if p.workload.name == spec.name]
    assert len(back) == 1
    assert back[0].workload.rate_rps == pytest.approx(spec.rate_rps)


def test_zero_share_park_reactivate_roundtrip(ctx12):
    """Split -> merge parks the extra replica at a zero rate share;
    a later re-split re-activates (adopts) it.  The round trip loses no
    requests and must never be accounted as shedding."""
    ctx, plan = ctx12
    mods = models()
    names = [p.workload.name for p in plan.placements]
    target = plan.placements[0].workload.name
    edges = np.array([0.0, 5000.0, 10000.0, 15000.0])
    scales = {n: (np.array([2.6, 1.0, 2.6]) if n == target
                  else np.array([1.0, 1.0, 1.0])) for n in names}
    tr = traces.Trace(edges=edges, scales=scales)
    ctl = Controller(plan, ctx.profiles, ctx.hw,
                     config=PlannerConfig(batch="joint"))
    res = simulate_plan(plan, mods, ctx.hw, duration_s=15.0,
                        poisson=False, seed=0, trace=tr, adjust_fn=ctl,
                        adjust_scope="cluster", adjust_period_s=1.0)
    acts = [e.action for e in ctl.edits if e.workload == target]
    assert "split" in acts and "merge" in acts
    assert acts.index("merge") < len(acts) - 1 \
        and "split" in acts[acts.index("merge"):], \
        "needs a re-split after the merge to exercise re-activation"
    # parking is not shedding: nothing dropped, no admission stats
    assert "shed_requests" not in res.stats
    assert res.stats.get("lost_requests", 0) == 0
    # every arrival that entered the (finite) run was eventually served
    # or still queued — the parked replica drained, none vanished
    assert res.per_workload[target]["rps"] > 0.0
