"""Discrete-event simulator + end-to-end provisioning study behaviour."""
import numpy as np
import pytest

from repro.core.experiments import all_plans, evaluate_plans, fitted_context
from repro.core import provisioner as prov
from repro.serving import physics
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads
from repro.core.types import V5E


def test_fig3_colocation_slowdown():
    """Latency grows with the number of co-located workloads (Fig. 3)."""
    d = list(models().values())[1]
    prev = 0.0
    for n in range(1, 6):
        sts = physics.device_state([(d, 8, 0.2)] * n, V5E)
        assert sts[0].t_inf >= prev - 1e-9
        prev = sts[0].t_inf
    # and the 5-way slowdown is material (paper: up to ~35%)
    solo = physics.device_state([(d, 8, 0.2)], V5E)[0].t_inf
    assert prev / solo > 1.10


def test_oversubscription_penalty():
    d = list(models().values())[1]
    ok = physics.device_state([(d, 8, 0.5), (d, 8, 0.5)], V5E)[0]
    over = physics.device_state([(d, 8, 0.8), (d, 8, 0.8)], V5E)[0]
    assert over.t_inf > ok.t_inf


@pytest.fixture(scope="module")
def study():
    ctx = fitted_context()
    plans = all_plans(ctx)
    return ctx, plans, evaluate_plans(plans, ctx)


def test_igniter_zero_violations(study):
    ctx, plans, results = study
    assert results["iGniter"]["violations"] == []


def test_ffd_violates(study):
    ctx, plans, results = study
    assert len(results["FFD+"]["violations"]) >= 3


def test_cost_ordering(study):
    """Paper headline: iGniter saves up to ~25% vs gpu-lets+."""
    ctx, plans, results = study
    ig = results["iGniter"]["cost_per_hour"]
    gl = results["gpu-lets+"]["cost_per_hour"]
    ffd = results["FFD+"]["cost_per_hour"]
    assert ig < gl                      # cheaper than gpu-lets+
    assert ig >= ffd                    # FFD+ under-provisions (and violates)
    assert (gl - ig) / gl >= 0.15       # material saving


def test_shadow_failover_recovers():
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=15.0, shadow=True)
    assert res.per_workload["W1"]["shadow_used"]


def test_simulator_throughput_accounting():
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=10.0)
    sb = specs_by_name()
    for w, m in res.per_workload.items():
        # served rate can't exceed the arrival rate
        assert m["rps"] <= sb[w].rate_rps * 1.05
