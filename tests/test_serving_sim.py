"""Discrete-event simulator + end-to-end provisioning study behaviour."""
import numpy as np
import pytest

from repro.core.experiments import all_plans, evaluate_plans, fitted_context
from repro.core import provisioner as prov
from repro.serving import physics
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads
from repro.core.types import V5E


def test_fig3_colocation_slowdown():
    """Latency grows with the number of co-located workloads (Fig. 3)."""
    d = list(models().values())[1]
    prev = 0.0
    for n in range(1, 6):
        sts = physics.device_state([(d, 8, 0.2)] * n, V5E)
        assert sts[0].t_inf >= prev - 1e-9
        prev = sts[0].t_inf
    # and the 5-way slowdown is material (paper: up to ~35%)
    solo = physics.device_state([(d, 8, 0.2)], V5E)[0].t_inf
    assert prev / solo > 1.10


def test_oversubscription_penalty():
    d = list(models().values())[1]
    ok = physics.device_state([(d, 8, 0.5), (d, 8, 0.5)], V5E)[0]
    over = physics.device_state([(d, 8, 0.8), (d, 8, 0.8)], V5E)[0]
    assert over.t_inf > ok.t_inf


@pytest.fixture(scope="module")
def study():
    ctx = fitted_context()
    plans = all_plans(ctx)
    return ctx, plans, evaluate_plans(plans, ctx)


def test_igniter_zero_violations(study):
    ctx, plans, results = study
    assert results["iGniter"]["violations"] == []


def test_ffd_violates(study):
    ctx, plans, results = study
    assert len(results["FFD+"]["violations"]) >= 3


def test_cost_ordering(study):
    """Paper headline: iGniter saves up to ~25% vs gpu-lets+."""
    ctx, plans, results = study
    ig = results["iGniter"]["cost_per_hour"]
    gl = results["gpu-lets+"]["cost_per_hour"]
    ffd = results["FFD+"]["cost_per_hour"]
    assert ig < gl                      # cheaper than gpu-lets+
    assert ig >= ffd                    # FFD+ under-provisions (and violates)
    assert (gl - ig) / gl >= 0.15       # material saving


def test_shadow_failover_recovers():
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=15.0, shadow=True)
    assert res.per_workload["W1"]["shadow_used"]


def test_simulator_throughput_accounting():
    ctx = fitted_context()
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=10.0)
    sb = specs_by_name()
    for w, m in res.per_workload.items():
        # served rate can't exceed the arrival rate
        assert m["rps"] <= sb[w].rate_rps * 1.05


# ---------------------------------------------------------------------------
# SimResult violation accounting: p99 (default), mean, quantile, rates
# ---------------------------------------------------------------------------

def test_violation_accounting_metrics():
    """`violations()` supports p99 (default), mean-latency and arbitrary-
    quantile accounting; p99 accounting is stronger than mean (a tail-
    only violator escapes mean accounting entirely — the failure mode of
    counting only mean latency against the SLO), and `violation_rates`
    reports per-request violation fractions."""
    from repro.core.types import Placement, ProvisioningPlan, WorkloadSpec
    ctx = fitted_context()
    mods = models()
    # one comfortable workload + one under-provisioned (deep backlog)
    s_ok = WorkloadSpec("OK", "rwkv6-1.6b", 400.0, 30.0)
    s_bad = WorkloadSpec("BAD", "qwen2-vl-7b", 60.0, 60.0)
    plan = ProvisioningPlan(hardware=ctx.hw, n_gpus=2, placements=[
        Placement(workload=s_ok, gpu=0, r=0.5, batch=2),
        Placement(workload=s_bad, gpu=1, r=0.25, batch=4),
    ])
    res = simulate_plan(plan, mods, ctx.hw, duration_s=10.0, poisson=True,
                        seed=3)
    # latency-metric accounting, rate check off (Poisson realizes fewer
    # arrivals than nominal for low-rate workloads on short horizons)
    sb = {"OK": s_ok, "BAD": s_bad}
    v_p99 = set(res.violations(sb, check_rate=False))
    v_avg = set(res.violations(sb, metric="avg", check_rate=False))
    assert v_p99 == {"BAD"}
    assert v_avg <= v_p99           # mean accounting is the weaker check
    assert set(res.violations(sb, metric=0.99, check_rate=False)) == v_p99
    assert set(res.violations(sb, metric=0.50, check_rate=False)) <= v_p99
    # a TAIL-ONLY violator: slo between OK's mean and p99 latency —
    # p99 accounting flags it, mean accounting misses it
    m_ok = res.per_workload["OK"]
    assert m_ok["avg_ms"] < m_ok["p99_ms"]
    slo_tail = (m_ok["avg_ms"] + m_ok["p99_ms"]) / 2.0
    sb_tail = {"OK": WorkloadSpec("OK", s_ok.model, slo_tail, s_ok.rate_rps),
               "BAD": s_bad}
    assert "OK" in res.violations(sb_tail, check_rate=False)
    assert "OK" not in res.violations(sb_tail, metric="avg",
                                      check_rate=False)
    rates = res.violation_rates(sb_tail)
    assert set(rates) == {"OK", "BAD"}
    assert 0.0 < rates["OK"] < rates["BAD"] <= 1.0
    # the default accounting (p99 + rate check) includes the p99 set
    assert v_p99 <= set(res.violations(sb))


# ---------------------------------------------------------------------------
# Bounded monitor-window deque: window shorter than one batch accumulation
# ---------------------------------------------------------------------------

def _slowpoke_plan(ctx):
    """A pass takes ~7 s (qwen2-vl at r=0.025, b=32) against the 1 s
    monitor lookback: completions land in bursts far apart, so most
    monitor ticks see an EMPTY window — the window is shorter than one
    batch accumulation/service cycle."""
    from repro.core.types import Placement, ProvisioningPlan, WorkloadSpec
    s = WorkloadSpec("SLOWPOKE", "qwen2-vl-7b", 60000.0, 20.0)
    return s, ProvisioningPlan(hardware=ctx.hw, n_gpus=1, placements=[
        Placement(workload=s, gpu=0, r=0.025, batch=32)])


@pytest.mark.parametrize("engine", ["scalar", "vec"])
def test_monitor_window_shorter_than_batch_accumulation(engine):
    """Monitor ticks between bursts must report a clean empty window (no
    stale or still-in-flight entries, no percentile-of-empty crash), and
    the deque must stay bounded by one burst."""
    ctx = fitted_context()
    mods = models()
    s, plan = _slowpoke_plan(ctx)
    res = simulate_plan(plan, mods, ctx.hw, duration_s=20.0, engine=engine,
                        record_timeline=True, monitor_period_s=0.5)
    assert res.per_workload["SLOWPOKE"]["rps"] > 0
    # the window holds at most one completion burst (<= batch), never
    # the whole history and never in-flight passes
    assert 0 < res.stats["peak_window"] <= 32
    rows = [r for r in res.timeline if r["workload"] == "SLOWPOKE"]
    assert rows, "monitor ticks must still be recorded"
    empty = [r for r in rows if r["rps_1s"] == 0.0]
    assert len(empty) >= len(rows) // 2, \
        "most ticks see an empty window when a pass outlasts the lookback"
    for r in empty:
        assert r["p99_1s"] == 0.0 and r["avg_1s"] == 0.0


def test_monitor_window_edge_engines_agree():
    """The empty-window edge case is engine-identical (timeline included)."""
    import numpy as np
    ctx = fitted_context()
    mods = models()
    s, plan = _slowpoke_plan(ctx)
    a = simulate_plan(plan, mods, ctx.hw, duration_s=20.0, engine="scalar",
                      record_timeline=True, monitor_period_s=0.5)
    b = simulate_plan(plan, mods, ctx.hw, duration_s=20.0, engine="vec",
                      record_timeline=True, monitor_period_s=0.5)
    assert a.timeline == b.timeline
    assert a.per_workload == b.per_workload
    assert a.stats["peak_window"] == b.stats["peak_window"]
    assert np.array_equal(a.request_waits["SLOWPOKE"],
                          b.request_waits["SLOWPOKE"])
