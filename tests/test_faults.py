"""Fault injection layer: schedules, simulator semantics, self-healing.

Pins the three contracts the availability story rests on:

* `repro.serving.faults` schedules are validated, seeded, and
  deterministic data — device sub-streams independent of fleet size.
* The simulator implements the documented fault semantics IDENTICALLY
  in both engines: faults-off runs are byte-identical to pre-fault
  behavior (``faults=None`` == empty schedule), and a fixed-seed fault
  scenario produces byte-identical streams scalar vs vec.
* The controller's health layer turns faults into recoveries: failures
  are detected and migrated off, stragglers are caught from
  measured-vs-predicted residuals, and the controlled run strictly
  beats the uncontrolled one.
"""
import math

import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core import replication
from repro.core.experiments import fitted_context
from repro.core.types import PlannerConfig
from repro.serving import faults
from repro.serving.controller import Controller
from repro.serving.simulator import simulate_plan, subplan
from repro.serving.workload import (models, specs_by_name,
                                    synthetic_workloads, twelve_workloads)


@pytest.fixture(scope="module")
def setup():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    return ctx, plan, models()


_WALL_KEYS = ("wall_s", "events_per_s")


def _identical(a, b, *, stats=True):
    assert set(a.request_latencies) == set(b.request_latencies)
    for k in a.request_latencies:
        assert np.array_equal(a.request_latencies[k],
                              b.request_latencies[k]), k
        assert np.array_equal(a.request_waits[k], b.request_waits[k]), k
    assert a.per_workload == b.per_workload
    if stats:
        sa = {k: v for k, v in a.stats.items() if k not in _WALL_KEYS}
        sb = {k: v for k, v in b.stats.items() if k not in _WALL_KEYS}
        assert sa == sb


# ---------------------------------------------------------------------------
# Schedule validation and generators
# ---------------------------------------------------------------------------

def test_schedule_validation():
    with pytest.raises(ValueError):
        faults.FaultSchedule(down={0: [[-1.0, 5.0]]})
    with pytest.raises(ValueError):
        faults.FaultSchedule(down={0: [[5.0, 5.0]]})       # restart <= fail
    with pytest.raises(ValueError):
        faults.FaultSchedule(down={0: [[0.0, 10.0], [5.0, 20.0]]})
    with pytest.raises(ValueError):
        faults.FaultSchedule(slow={0: 0.0})
    # unity multipliers are dropped; intervals are sorted
    fs = faults.FaultSchedule(down={0: [[50.0, 60.0], [10.0, 20.0]]},
                              slow={0: 1.0, 1: 2.5})
    assert 0 not in fs.slow and fs.slow[1] == 2.5
    assert fs.down[0][0, 0] == 10.0
    assert fs.multiplier(0) == 1.0 and fs.multiplier(1) == 2.5


def test_schedule_lookups():
    fs = faults.FaultSchedule(down={3: [[100.0, 200.0], [500.0, math.inf]]})
    assert not fs.is_down(3, 99.9)
    assert fs.is_down(3, 100.0)          # half-open [fail, restart)
    assert not fs.is_down(3, 200.0)
    assert fs.is_down(3, 1e9)            # permanent
    assert fs.next_up(3, 150.0) == 200.0
    assert fs.next_up(3, 600.0) == math.inf
    assert fs.next_up(3, 50.0) == 50.0
    assert fs.n_failures(1000.0) == 2
    assert fs.n_failures(300.0) == 1
    assert fs.downtime_ms(1000.0) == 100.0 + 500.0
    bounds = fs.boundaries()             # inf restart has no up event
    assert bounds == [(100.0, 3, False), (200.0, 3, True), (500.0, 3, False)]


def test_generators_seeded_and_fleet_independent():
    a = faults.random_failures(8, 60_000.0, rate_per_min=2.0, mttr_ms=3000.0,
                               seed=5)
    b = faults.random_failures(8, 60_000.0, rate_per_min=2.0, mttr_ms=3000.0,
                               seed=5)
    small = faults.random_failures(4, 60_000.0, rate_per_min=2.0,
                                   mttr_ms=3000.0, seed=5)
    assert set(a.down) == set(b.down)
    for g in a.down:
        assert np.array_equal(a.down[g], b.down[g])
        if g in small.down:              # per-device default_rng([seed, g])
            assert np.array_equal(a.down[g], small.down[g])
    assert faults.random_failures(8, 60_000.0, rate_per_min=0.0,
                                  mttr_ms=1.0, seed=0).down == {}

    st = faults.stragglers(20, frac=0.25, multiplier=2.0, seed=1)
    assert len(st.slow) == 5
    assert all(m == 2.0 for m in st.slow.values())


def test_merge_unions_and_rejects_conflicts():
    fail = faults.FaultSchedule(down={0: [[10.0, 20.0]]})
    slow = faults.FaultSchedule(slow={1: 2.0})
    fs = faults.merge(fail, slow)
    assert fs.is_down(0, 15.0) and fs.multiplier(1) == 2.0
    with pytest.raises(ValueError):
        faults.merge(slow, faults.FaultSchedule(slow={1: 3.0}))


# ---------------------------------------------------------------------------
# Simulator semantics: identity and accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["scalar", "vec"])
def test_faults_none_equals_empty_schedule(setup, engine):
    """faults=None and an empty schedule leave every stream untouched —
    the faults-off byte-identity guarantee, per engine."""
    ctx, plan, mods = setup
    kw = dict(duration_s=4.0, poisson=True, seed=3, engine=engine)
    _identical(simulate_plan(plan, mods, ctx.hw, **kw),
               simulate_plan(plan, mods, ctx.hw,
                             faults=faults.FaultSchedule(), **kw))


@pytest.mark.jax
def test_faults_none_equals_empty_schedule_jax(setup):
    ctx, plan, mods = setup
    kw = dict(duration_s=4.0, poisson=True, seed=3, backend="jax")
    _identical(simulate_plan(plan, mods, ctx.hw, **kw),
               simulate_plan(plan, mods, ctx.hw,
                             faults=faults.FaultSchedule(), **kw))


def _scenario_schedule(plan):
    """One mid-run outage plus one straggler, on distinct devices."""
    g_w3 = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    g_w5 = next(p.gpu for p in plan.placements if p.workload.name == "W5")
    return faults.merge(
        faults.FaultSchedule(down={g_w3: [[1500.0, 4000.0]]}),
        faults.FaultSchedule(slow={g_w5: 2.0}))


def test_fault_scenario_engine_identity(setup):
    """Fixed-seed faulty runs are byte-identical scalar vs vec,
    including the fault accounting in SimResult.stats."""
    ctx, plan, mods = setup
    fs = _scenario_schedule(plan)
    kw = dict(duration_s=8.0, poisson=True, seed=11, faults=fs)
    a = simulate_plan(plan, mods, ctx.hw, engine="scalar", **kw)
    b = simulate_plan(plan, mods, ctx.hw, engine="vec", **kw)
    _identical(a, b)
    assert a.stats["n_failures"] == 1
    assert a.stats["downtime_ms"] == 2500.0


def test_outage_backlogs_then_recovers(setup):
    """A solo device outage: arrivals queue as backlog (nothing lost),
    completions stall during the window, and recovery is accounted."""
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(down={g: [[2000.0, 6000.0]]})
    res = simulate_plan(plan, mods, ctx.hw, duration_s=12.0, faults=fs)
    clean = simulate_plan(plan, mods, ctx.hw, duration_s=12.0)
    assert res.stats["n_failures"] == 1
    assert res.stats["lost_requests"] == 0
    assert res.stats["n_recoveries"] == 1
    assert res.stats["recovery_mean_ms"] > 0.0
    # the outage inflates W3's tail far past its clean value
    assert res.per_workload["W3"]["p99_ms"] \
        > 2.0 * clean.per_workload["W3"]["p99_ms"]


def test_permanent_failure_loses_backlog(setup):
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(down={g: [[2000.0, math.inf]]})
    res = simulate_plan(plan, mods, ctx.hw, duration_s=6.0, faults=fs)
    assert res.stats["lost_requests"] > 0
    assert res.stats["n_recoveries"] == 0


def test_straggler_inflates_measured_latency(setup):
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(slow={g: 2.5})
    res = simulate_plan(plan, mods, ctx.hw, duration_s=6.0, faults=fs)
    clean = simulate_plan(plan, mods, ctx.hw, duration_s=6.0)
    assert res.per_workload["W3"]["p99_ms"] \
        > 1.5 * clean.per_workload["W3"]["p99_ms"]
    # the straggler is invisible to the fault accounting (no downtime)
    assert res.stats["n_failures"] == 0
    assert res.stats["downtime_ms"] == 0.0


def test_shadow_activates_over_outage(setup):
    """With shadow=True a solo outage fails over to the shadow process
    instead of just backlogging."""
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(down={g: [[2000.0, 8000.0]]})
    res = simulate_plan(plan, mods, ctx.hw, duration_s=12.0, faults=fs,
                        shadow=True)
    assert res.per_workload["W3"]["shadow_used"]
    assert res.stats["lost_requests"] == 0


def test_replicas_absorb_failed_member():
    """A replica group keeps serving its base workload through one
    member's permanent failure — the runtime re-split hands the dead
    replica's share to the survivors (controller OFF)."""
    ctx = fitted_context()
    specs = synthetic_workloads(100, 0)
    plan = prov.provision(specs, ctx.profiles, ctx.hw, replicate=True)
    groups = {b: g for b, g in
              replication.group_placements(plan.placements).items()
              if len(g) >= 2 and len({p.gpu for p in g}) >= 2}
    assert groups, "expected at least one multi-device replica group"
    base = sorted(groups)[0]
    group = groups[base]
    gpus = sorted({p.gpu for p in group})
    sub = subplan(plan, gpus)
    fs = faults.FaultSchedule(down={gpus[0]: [[1000.0, math.inf]]})
    res = simulate_plan(sub, models(), ctx.hw, duration_s=6.0, faults=fs)
    total = sum(s.rate_rps for s in specs if s.name == base)
    # survivors absorb the share: >= ~5/6 of the full rate still served
    # (the first second ran at full membership; the dead replica's
    # backlog is the only loss)
    assert res.per_workload[base]["rps"] > 0.8 * total


# ---------------------------------------------------------------------------
# Self-healing: the controller closes the loop
# ---------------------------------------------------------------------------

def _controlled(plan, ctx, mods, fs, **kw):
    ctl = Controller(plan, ctx.profiles, ctx.hw,
                     config=PlannerConfig(batch="joint"))
    res = simulate_plan(plan, mods, ctx.hw, faults=fs, adjust_fn=ctl,
                        adjust_scope="cluster", adjust_period_s=1.0,
                        record_timeline=True, **kw)
    return ctl, res


def test_controller_heals_device_failure(setup):
    """Failure detection -> quarantine -> migration: the controlled run
    strictly beats the uncontrolled one on violations AND recovery."""
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(down={g: [[2000.0, 8000.0]]})
    kw = dict(duration_s=10.0, poisson=True, seed=0)
    off = simulate_plan(plan, mods, ctx.hw, faults=fs, **kw)
    ctl, on = _controlled(plan, ctx, mods, fs, **kw)
    spec_map = specs_by_name()
    assert any(e.action == "migrate" for e in ctl.edits)
    v_off = float(np.mean(list(off.violation_rates(spec_map).values())))
    v_on = float(np.mean(list(on.violation_rates(spec_map).values())))
    assert v_on < v_off
    assert on.stats["recovery_mean_ms"] < off.stats["recovery_mean_ms"]


def test_controller_migrates_straggler_and_recovers(setup):
    """Straggler detection from measured-vs-predicted residuals: the
    victim is migrated off and its post-migration tail returns under
    the SLO."""
    ctx, plan, mods = setup
    g = next(p.gpu for p in plan.placements if p.workload.name == "W3")
    fs = faults.FaultSchedule(slow={g: 2.5})
    ctl, on = _controlled(plan, ctx, mods, fs, duration_s=10.0,
                          poisson=True, seed=0)
    migrated = [e for e in ctl.edits if e.action == "migrate"]
    assert migrated and migrated[0].workload == "W3"
    slo = specs_by_name()["W3"].slo_ms
    tail = [t["p99_1s"] for t in on.timeline
            if replication.base_name(t["workload"]) == "W3"
            and t["t_s"] >= 7.0 and t["rps_1s"] > 0.0]
    assert tail and max(tail) <= slo
    # no collateral quarantines of healthy devices
    quarantined = set(ctl.reconciler.quarantined)
    assert quarantined == {g}
