"""Sharding resolution + HLO analyzer unit tests (single-device safe)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, REGISTRY
from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.profiling import hlo_analysis as H

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only


@pytest.fixture(scope="module")
def mesh():
    # single-device 1x1 mesh: resolution logic works the same way
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_resolve_drops_non_divisible(mesh):
    # with axis size 1, every spec resolves to replicated (divisible by 1 but
    # size 1 -> dropped)
    spec = sh.resolve_spec(P("fsdp", "tp"), (64, 64), mesh)
    assert spec == P(None, None)


def test_param_spec_trees_match_params():
    """Every arch: init tree structure == spec tree structure (no drift)."""
    for arch in ASSIGNED:
        cfg = REGISTRY[arch]
        abstract = T.abstract_params(cfg)
        specs = T.param_specs(cfg)
        s1 = jax.tree.structure(abstract)
        s2 = jax.tree.structure(
            jax.tree.map(lambda s: 0, specs,
                         is_leaf=lambda x: isinstance(x, P)))
        assert s1 == s2, arch


def test_hlo_analyzer_trip_count_multiplication():
    """flops inside a lax.scan body must be multiplied by trip count."""
    def f(a, b):
        def body(x, _):
            return x @ b, None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(a, a).compile()
    costs = H.analyze(compiled.as_text())
    expected = 10 * 2 * 256 ** 3
    assert costs.flops == pytest.approx(expected, rel=0.05)
    # XLA's own count misses the multiplier
    xla = compiled.cost_analysis()["flops"]
    assert xla < expected / 5


def test_hlo_analyzer_shape_parsing():
    assert H._shape_elems_bytes("bf16[4,8]{1,0}") == 64
    assert H._shape_elems_bytes("(f32[2,2], s32[3])") == 28
    assert H._shape_elems_bytes("pred[10]") == 10


def test_collective_byte_accounting():
    txt = """
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p), replica_groups=[16,4]<=[64], dimensions={0}
  ROOT %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups=[8,8]<=[64], to_apply=%add
}
"""
    c = H.analyze(txt)
    n = 64 * 64 * 4
    # all-gather: (g-1)/g * out with g=4 ; all-reduce: 2*(g-1)/g*in with g=8
    assert c.per_collective["all-gather"] == pytest.approx(0.75 * n)
    assert c.per_collective["all-reduce"] == pytest.approx(2 * 7 / 8 * n)


def test_cache_specs_batch_dim_detection(mesh):
    cfg = REGISTRY["whisper-large-v3"]
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, batch_size=32, max_len=64))
    specs = sh.cache_specs(cache, mesh, batch=32,
                           policy=sh.ActivationPolicy())
    # L == batch == 32 collision: dim 1 must be chosen as batch (axis size 1
    # here so spec is all-None, but resolution must not crash)
    assert jax.tree.leaves(specs) is not None
