"""Provisioning strategy invariants (Alg. 1 / Alg. 2) — unit + hypothesis."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # bare env: property tests skip, unit tests run
    from tests._hypothesis_stub import given, settings, st

from repro.core import baselines as B
from repro.core import perf_model as pm
from repro.core import provisioner as prov
from repro.core.types import V5E, WorkloadSpec
from tests.test_perf_model import make_coeffs


def _profiles():
    return {
        "light": make_coeffs(k1=0.002, k2=0.4, k3=0.8, k5=0.05),
        "mid": make_coeffs(k1=0.01, k2=2.0, k3=3.0),
        "heavy": make_coeffs(k1=0.02, k2=5.0, k3=8.0, k5=0.3),
    }


workload_st = st.lists(
    st.tuples(st.sampled_from(["light", "mid", "heavy"]),
              st.floats(60.0, 400.0), st.floats(5.0, 80.0)),
    min_size=1, max_size=8)


@settings(max_examples=40, deadline=None)
@given(ws=workload_st)
def test_provision_invariants(ws):
    specs = [WorkloadSpec(f"W{i}", m, slo, rate)
             for i, (m, slo, rate) in enumerate(ws)]
    profiles = _profiles()
    try:
        plan = prov.provision(specs, profiles, V5E)
    except prov.InfeasibleError:
        return
    # every workload placed exactly once (Eq. 16)
    placed = sorted(p.workload.name for p in plan.placements)
    assert placed == sorted(s.name for s in specs)
    # capacity constraint per device (Eq. 15)
    for g in range(plan.n_gpus):
        assert plan.total_allocated(g) <= 1.0 + 1e-9
    # allocations in r_unit grid, positive
    for p in plan.placements:
        assert p.r > 0
        assert abs(p.r / V5E.r_unit - round(p.r / V5E.r_unit)) < 1e-6
        assert p.batch >= 1
    # the analytical model predicts every SLO met (Constraint 14)
    for g, pls in plan.by_gpu().items():
        placed_w = [pm.PlacedWorkload(profiles[p.workload.model], p.batch, p.r)
                    for p in pls]
        pred = pm.predict_device(placed_w, V5E)
        for p, wp in zip(pls, pred.per_workload):
            assert wp.t_inf <= p.workload.slo_ms / 2.0 + 1e-6


@settings(max_examples=25, deadline=None)
@given(ws=workload_st)
def test_ffd_uses_fewer_or_equal_devices_than_singletons(ws):
    specs = [WorkloadSpec(f"W{i}", m, slo, rate)
             for i, (m, slo, rate) in enumerate(ws)]
    profiles = _profiles()
    try:
        plan = B.provision_ffd(specs, profiles, V5E)
    except prov.InfeasibleError:
        return
    assert plan.n_gpus <= len(specs)


def test_alloc_gpus_grows_on_violation():
    """Alg. 2: co-locating a heavy neighbor grants extra resources to the
    originally-placed workload when its SLO would be violated."""
    profiles = _profiles()
    hw = V5E
    s1 = WorkloadSpec("a", "mid", 100.0, 40.0)
    s2 = WorkloadSpec("b", "heavy", 150.0, 30.0)
    b1 = prov.appropriate_batch(s1, profiles["mid"], hw)
    r1 = prov.resource_lower_bound(s1, profiles["mid"], hw, b1)
    dev = prov._Dev(entries=[(s1, profiles["mid"], b1, r1)])
    b2 = prov.appropriate_batch(s2, profiles["heavy"], hw)
    r2 = prov.resource_lower_bound(s2, profiles["heavy"], hw, b2)
    r_a = prov.alloc_gpus(dev, s2, profiles["heavy"], b2, r2, hw)
    if r_a is not None:
        assert r_a[0] >= r1 - 1e-9          # never shrinks the original
        assert r_a[-1] >= r2 - 1e-9
        assert sum(r_a) <= 1.0 + 1e-9


def test_gpulets_at_most_two_per_device():
    specs = [WorkloadSpec(f"W{i}", "light", 120.0, 30.0) for i in range(7)]
    plan = B.provision_gpulets(specs, _profiles(), V5E)
    for g, pls in plan.by_gpu().items():
        assert len(pls) <= 2
        for p in pls:
            assert p.r in (0.2, 0.4, 0.5, 0.6, 0.8)


def test_heterogeneous_selection_picks_cheaper():
    from repro.core.types import V4
    specs = [WorkloadSpec("W0", "light", 150.0, 20.0),
             WorkloadSpec("W1", "mid", 200.0, 20.0)]
    profiles = {"tpu-v5e": _profiles(), "tpu-v4": _profiles()}
    plan, hw = prov.provision_cheapest(specs, profiles, [V5E, V4])
    # same coefficient surface on both -> cheaper per-device price must win
    assert hw.name == "tpu-v5e"


def test_sorted_descending_placement_order():
    """Alg. 1 line 3: larger r_lower placed first (ANYFIT constraint)."""
    profiles = _profiles()
    specs = [WorkloadSpec("small", "light", 300.0, 10.0),
             WorkloadSpec("big", "heavy", 80.0, 50.0)]
    try:
        plan = prov.provision(specs, profiles, V5E)
    except prov.InfeasibleError:
        return
    by = {p.workload.name: p for p in plan.placements}
    assert by["big"].gpu == 0     # the big workload anchored the first device


def test_online_add_workload_adjusts_originals():
    """Sec. 2.3's gpu-lets critique: iGniter must be able to grow the
    ORIGINALLY-placed workloads' allocations when a newcomer lands."""
    profiles = _profiles()
    base_specs = [WorkloadSpec("W0", "mid", 150.0, 40.0),
                  WorkloadSpec("W1", "light", 200.0, 30.0)]
    plan = prov.provision(base_specs, profiles, V5E)
    before = {p.workload.name: p.r for p in plan.placements}

    new = WorkloadSpec("W2", "heavy", 200.0, 30.0)
    plan2 = prov.add_workload(plan, new, profiles, V5E)
    names = sorted(p.workload.name for p in plan2.placements)
    assert names == ["W0", "W1", "W2"]
    # capacity + predicted SLOs still hold
    for g, pls in plan2.by_gpu().items():
        assert sum(p.r for p in pls) <= 1.0 + 1e-9
        placed = [pm.PlacedWorkload(profiles[p.workload.model], p.batch, p.r)
                  for p in pls]
        pred = pm.predict_device(placed, V5E)
        for p, wp in zip(pls, pred.per_workload):
            assert wp.t_inf <= p.workload.slo_ms / 2.0 + 1e-6
    # originals never shrink (Alg. 2 only grows)
    after = {p.workload.name: p.r for p in plan2.placements}
    for n in before:
        assert after[n] >= before[n] - 1e-9


def test_online_add_matches_batch_quality():
    """A stream of online arrivals should not use wildly more devices
    than provisioning the same set at once."""
    import numpy as np
    profiles = _profiles()
    rng = np.random.default_rng(1)
    specs = [WorkloadSpec(f"W{i}", ["light", "mid", "heavy"][i % 3],
                          float(rng.uniform(150, 350)),
                          float(rng.uniform(10, 40))) for i in range(8)]
    batch_plan = prov.provision(specs, profiles, V5E)
    online = prov.provision(specs[:1], profiles, V5E)
    for s in specs[1:]:
        online = prov.add_workload(online, s, profiles, V5E)
    assert online.n_gpus <= batch_plan.n_gpus + 2


# ---------------------------------------------------------------------------
# Fresh-device self-grant (beyond-paper fix for the Theorem-1 f/F
# throttling residual — see ROADMAP / ISSUE 2)
# ---------------------------------------------------------------------------

def test_self_grant_meets_half_slo_budget():
    """Every fresh-device anchor must satisfy Constraint 14 at its
    granted allocation (or honestly occupy the full device)."""
    from repro.core.experiments import fitted_context
    from repro.serving.workload import synthetic_workloads
    ctx = fitted_context()
    grants = 0
    for s in synthetic_workloads(30, seed=5):
        c = ctx.profiles[s.model]
        b = prov.appropriate_batch(s, c, ctx.hw)
        rl = prov.resource_lower_bound(s, c, ctx.hw, b)
        r = prov.self_grant(s, c, b, rl, ctx.hw)
        assert r >= rl - 1e-12
        assert abs(r / ctx.hw.r_unit - round(r / ctx.hw.r_unit)) < 1e-6
        pred = pm.predict_device(
            [pm.PlacedWorkload(coeffs=c, batch=b, r=r)], ctx.hw)
        assert (pred.per_workload[0].t_inf <= s.slo_ms / 2.0 + 1e-9
                or r == prov.R_MAX)
        grants += r > rl + 1e-12
    assert grants > 0     # the throttling residual is real for this mix


def test_self_grant_clears_predicted_violations_at_scale():
    """Pre-fix the m=100 synthetic sweep predicted 8 violations — all
    solo fresh-device anchors.  Post-fix the model predicts zero.
    (A half-budget regression test: the Theorem-1 throttling residual is
    defined against the paper's T_slo/2 split.)"""
    from repro.core.experiments import fitted_context
    from repro.serving.workload import synthetic_workloads
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    profiles = {ctx5.hw.name: ctx5.profiles, ctx4.hw.name: ctx4.profiles}
    specs = synthetic_workloads(100, 0)
    plan, hw = prov.provision_cheapest(specs, profiles, [ctx5.hw, ctx4.hw],
                                       budget="half")
    assert prov.predicted_violations(plan, profiles[hw.name], hw,
                                     budget="half") == []
    # both engines apply the identical self-grant
    oracle, hw_o = prov.provision_cheapest(specs, profiles,
                                           [ctx5.hw, ctx4.hw],
                                           engine="scalar", budget="half")
    assert hw_o.name == hw.name
    assert [(p.workload.name, p.gpu, round(p.r, 9)) for p in oracle.placements] \
        == [(p.workload.name, p.gpu, round(p.r, 9)) for p in plan.placements]


# ---------------------------------------------------------------------------
# Incremental plan edits (online control plane): resize / remove / migrate
# ---------------------------------------------------------------------------

def _mixed_plan():
    profiles = _profiles()
    specs = [WorkloadSpec(f"W{i}", m, slo, rate) for i, (m, slo, rate) in
             enumerate([("light", 80.0, 60.0), ("mid", 150.0, 40.0),
                        ("heavy", 240.0, 25.0), ("light", 120.0, 90.0),
                        ("mid", 200.0, 30.0), ("heavy", 300.0, 20.0)])]
    return specs, profiles, prov.provision(specs, profiles, V5E)


def _plan_key(plan):
    return [(p.workload.name, p.workload.rate_rps, p.gpu,
             round(p.r, 9), p.batch) for p in plan.placements]


def test_remove_workload_drops_exactly_one():
    specs, profiles, plan = _mixed_plan()
    out = prov.remove_workload(plan, "W2")
    assert len(out.placements) == len(plan.placements) - 1
    assert all(p.workload.name != "W2" for p in out.placements)
    assert out.n_gpus == len({p.gpu for p in out.placements})
    # survivors untouched (peers keep their grants)
    kept = {p.workload.name: (p.gpu, p.r, p.batch) for p in out.placements}
    for p in plan.placements:
        if p.workload.name != "W2":
            assert kept[p.workload.name] == (p.gpu, p.r, p.batch)
    with pytest.raises(KeyError):
        prov.remove_workload(plan, "nope")


@pytest.mark.parametrize("factor", [1.5, 0.5])
def test_resize_workload_engines_identical(factor):
    import dataclasses
    specs, profiles, plan = _mixed_plan()
    new = dataclasses.replace(specs[1], rate_rps=specs[1].rate_rps * factor)
    a = prov.resize_workload(plan, new, profiles, V5E, engine="vec")
    b = prov.resize_workload(plan, new, profiles, V5E, engine="scalar")
    assert _plan_key(a) == _plan_key(b)
    pa = next(p for p in a.placements if p.workload.name == new.name)
    assert pa.workload.rate_rps == new.rate_rps
    # Theorem 1 re-ran at the new rate
    bm = prov.resolve("queueing")
    assert pa.batch == prov.appropriate_batch(new, profiles["mid"], V5E,
                                              budget=bm)
    with pytest.raises(KeyError):
        prov.resize_workload(plan, dataclasses.replace(new, name="nope"),
                             profiles, V5E)


def test_resize_up_never_shrinks_peer_grants():
    import dataclasses
    specs, profiles, plan = _mixed_plan()
    cur = plan.placements[0]
    new = dataclasses.replace(cur.workload,
                              rate_rps=cur.workload.rate_rps * 1.4)
    out = prov.resize_workload(plan, new, profiles, V5E)
    before = {p.workload.name: p.r for p in plan.placements
              if p.gpu == cur.gpu}
    target = next(p for p in out.placements if p.workload.name == new.name)
    if target.gpu == cur.gpu:          # same-device fast path taken
        for p in out.placements:
            if p.gpu == cur.gpu and p.workload.name != new.name:
                assert p.r >= before[p.workload.name] - 1e-12


def test_migrate_workload_engines_identical():
    import dataclasses
    specs, profiles, plan = _mixed_plan()
    new = dataclasses.replace(specs[0], rate_rps=specs[0].rate_rps * 1.2)
    a = prov.migrate_workload(plan, new, profiles, V5E, engine="vec")
    b = prov.migrate_workload(plan, new, profiles, V5E, engine="scalar")
    assert _plan_key(a) == _plan_key(b)
    assert sum(1 for p in a.placements if p.workload.name == new.name) == 1


def test_resize_falls_back_to_migration_when_device_full():
    """Grow a workload until its current device cannot host it: the
    resize must land it elsewhere (or on a fresh device) instead of
    failing, and the result must match the scalar oracle."""
    import dataclasses
    specs, profiles, plan = _mixed_plan()
    cur = plan.placements[0]
    peers = [p for p in plan.placements if p.gpu == cur.gpu
             and p.workload.name != cur.workload.name]
    grown = None
    for f in (2.0, 3.0, 4.0, 6.0):
        new = dataclasses.replace(cur.workload,
                                  rate_rps=cur.workload.rate_rps * f)
        try:
            out = prov.resize_workload(plan, new, profiles, V5E)
        except prov.InfeasibleError:
            break
        tgt = next(p for p in out.placements
                   if p.workload.name == new.name)
        if peers and tgt.gpu != cur.gpu:
            grown = (new, out)
            break
    if grown is not None:
        new, out = grown
        oracle = prov.resize_workload(plan, new, profiles, V5E,
                                      engine="scalar")
        assert _plan_key(out) == _plan_key(oracle)


# ---------------------------------------------------------------------------
# Queueing-aware joint batch re-optimizer (batch="joint")
# ---------------------------------------------------------------------------

def test_joint_batch_never_needs_more_solo_resources():
    """For every feasible spec, r_lower at the joint batch is <= r_lower
    at Eq. 17's batch (never-worse by construction)."""
    import numpy as np
    profiles = _profiles()
    rng = np.random.default_rng(2)
    checked = 0
    for _ in range(150):
        m = str(rng.choice(["light", "mid", "heavy"]))
        s = WorkloadSpec("W", m, float(rng.uniform(60.0, 400.0)),
                         float(rng.uniform(5.0, 300.0)))
        c = profiles[m]
        try:
            b0 = prov.appropriate_batch(s, c, V5E)
            r0 = prov.resource_lower_bound(s, c, V5E, b0)
        except prov.InfeasibleError:
            continue
        b1 = prov.appropriate_batch(s, c, V5E, batch="joint")
        r1 = prov.resource_lower_bound(s, c, V5E, b1)
        assert r1 <= r0 + 1e-12, (s.slo_ms, s.rate_rps, b0, b1)
        checked += 1
    assert checked > 40


def test_joint_batch_rejects_unknown_mode():
    profiles = _profiles()
    s = WorkloadSpec("W", "mid", 150.0, 60.0)
    with pytest.raises(ValueError):
        prov.appropriate_batch(s, profiles["mid"], V5E, batch="auto")


def test_joint_batch_plan_never_worse_at_m100():
    """m=100 regression pin: the joint re-optimizer's full plan costs no
    more than the default and predicts no more violations (measured on
    this container: 72 vs 78 devices, 7 vs 13 predicted violations)."""
    from repro.core.experiments import fitted_context
    from repro.serving.workload import synthetic_workloads
    ctx = fitted_context()
    specs = synthetic_workloads(100, 0)
    dflt = prov.provision(specs, ctx.profiles, ctx.hw)
    joint = prov.provision(specs, ctx.profiles, ctx.hw, batch="joint")
    assert joint.cost_per_hour() <= dflt.cost_per_hour()
    v_d = prov.predicted_violations(dflt, ctx.profiles, ctx.hw)
    v_j = prov.predicted_violations(joint, ctx.profiles, ctx.hw)
    assert len(v_j) <= len(v_d)
    # engines agree on the joint plans too
    oracle = prov.provision(specs, ctx.profiles, ctx.hw, batch="joint",
                            engine="scalar")
    assert _plan_key(joint) == _plan_key(oracle)
