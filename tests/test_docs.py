"""Documentation integrity: links resolve, the README stays a
quickstart, and docs/ never drifts from the code it describes.

All checks are grep-driven over the file tree — no `repro` imports, so
the fast tier never touches jax-marked modules and the CI `docs` job
can run with pytest alone.  The symbol check is the `solo_terms`-style
drift guard: every ``module.symbol`` / ``Class.member`` reference in
docs/*.md (and README.md) must still exist in the named file, and
every call-looking bare reference must still appear somewhere under
src/ or benchmarks/.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

README_MAX_LINES = 120

# module-level references: `provisioner.alloc_gpus` etc.
MODULES = {
    "perf_model": "src/repro/core/perf_model.py",
    "perf_model_vec": "src/repro/core/perf_model_vec.py",
    "perf_model_jax": "src/repro/core/perf_model_jax.py",
    "physics_jax": "src/repro/serving/physics_jax.py",
    "provisioner": "src/repro/core/provisioner.py",
    "queueing": "src/repro/core/queueing.py",
    "replication": "src/repro/core/replication.py",
    "coefficients": "src/repro/core/coefficients.py",
    "baselines": "src/repro/core/baselines.py",
    "experiments": "src/repro/core/experiments.py",
    "types": "src/repro/core/types.py",
    "simulator": "src/repro/serving/simulator.py",
    "physics": "src/repro/serving/physics.py",
    "traces": "src/repro/serving/traces.py",
    "faults": "src/repro/serving/faults.py",
    "controller": "src/repro/serving/controller.py",
    "workload": "src/repro/serving/workload.py",
    "telemetry": "src/repro/serving/telemetry.py",
    "telemetry_report": "benchmarks/telemetry_report.py",
}

# class-level references: `VecCluster.alloc_all`, `SimResult.stats`, ...
CLASSES = {
    "WorkloadCoefficients": "src/repro/core/types.py",
    "HardwareSpec": "src/repro/core/types.py",
    "WorkloadSpec": "src/repro/core/types.py",
    "Placement": "src/repro/core/types.py",
    "ProvisioningPlan": "src/repro/core/types.py",
    "PlannerConfig": "src/repro/core/types.py",
    "ProbeCache": "src/repro/core/provisioner.py",
    "InfeasibleError": "src/repro/core/provisioner.py",
    "DeviceCapError": "src/repro/core/provisioner.py",
    "CoeffArrays": "src/repro/core/perf_model_vec.py",
    "VecCluster": "src/repro/core/perf_model_vec.py",
    "BudgetModel": "src/repro/core/queueing.py",
    "QueueingDelay": "src/repro/core/queueing.py",
    "SimResult": "src/repro/serving/simulator.py",
    "ServedInstance": "src/repro/serving/simulator.py",
    "SimTestbed": "src/repro/serving/simulator.py",
    "Trace": "src/repro/serving/traces.py",
    "ArrivalEstimator": "src/repro/serving/controller.py",
    "ControllerConfig": "src/repro/serving/controller.py",
    "Reconciler": "src/repro/serving/controller.py",
    "Controller": "src/repro/serving/controller.py",
    "PlanState": "src/repro/serving/controller.py",
    "PlanEdit": "src/repro/serving/controller.py",
    "Telemetry": "src/repro/serving/telemetry.py",
    "RingBuffer": "src/repro/serving/telemetry.py",
    "ControlEvent": "src/repro/serving/telemetry.py",
}

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TICK = re.compile(r"`([^`]+)`")
_DOTTED = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*)\.([A-Za-z_][A-Za-z0-9_]*)")
_CALL = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*)\(")
_PATHISH = re.compile(r"^[\w./-]+\.(py|md|json|yml|ini|txt)$")


def _defines(source: str, name: str) -> bool:
    """`name` is defined in `source` as a function, class, assignment,
    dataclass field, or method (grep-level check, no imports)."""
    return re.search(
        rf"(?m)^\s*(def\s+{name}\b|class\s+{name}\b|{name}\s*[=:])",
        source) is not None


@pytest.fixture(scope="module")
def all_source() -> str:
    chunks = []
    for root in ("src", "benchmarks"):
        for p in sorted((REPO / root).rglob("*.py")):
            chunks.append(p.read_text())
    return "\n".join(chunks)


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_links_resolve(doc):
    """Every non-http markdown link points at an existing file."""
    missing = []
    for target in _LINK.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue               # pure in-page anchor
        if not (doc.parent / path).exists() and not (REPO / path).exists():
            missing.append(target)
    assert not missing, f"{doc.name}: broken links {missing}"


def test_readme_stays_a_quickstart():
    """The deep dives live in docs/; the README is a <= 120-line
    quickstart (CI enforces the same bound)."""
    n = len((REPO / "README.md").read_text().splitlines())
    assert n <= README_MAX_LINES, \
        f"README.md has {n} lines > {README_MAX_LINES}; move content to docs/"


def test_docs_reference_only_existing_paths():
    """Backticked path-looking tokens must exist — either repo-relative
    (tests/..., benchmarks/...) or in the `core/x.py` / `serving/x.py`
    shorthand the docs use for src/repro modules."""
    missing = []
    for doc in DOC_FILES:
        for tok in _TICK.findall(doc.read_text()):
            if _PATHISH.match(tok) and "/" in tok:
                if not ((REPO / tok).exists()
                        or (REPO / "src" / "repro" / tok).exists()):
                    missing.append(f"{doc.name}: {tok}")
    assert not missing, f"docs reference nonexistent files: {missing}"


def test_docs_symbols_exist(all_source):
    """Every `module.symbol` / `Class.member` reference resolves against
    the named file, and every call-looking bare reference appears
    somewhere in the source tree — the docs-drift guard."""
    stale = []
    for doc in DOC_FILES:
        for tok in _TICK.findall(doc.read_text()):
            m = _DOTTED.match(tok)
            if m:
                owner, name = m.groups()
                path = MODULES.get(owner) or CLASSES.get(owner)
                if path is None:
                    continue       # not a tracked namespace (e.g. np.*)
                if not _defines((REPO / path).read_text(), name):
                    stale.append(f"{doc.name}: `{tok}` — no {name} in {path}")
                continue
            m = _CALL.match(tok)
            if m and not re.search(rf"\b{m.group(1)}\b", all_source):
                stale.append(f"{doc.name}: `{tok}` not found in source")
    assert not stale, "stale doc references:\n" + "\n".join(stale)


def test_module_map_is_current():
    """The maps above must themselves not rot."""
    for rel in list(MODULES.values()) + list(CLASSES.values()):
        assert (REPO / rel).exists(), f"tracked file missing: {rel}"
    for cls, rel in CLASSES.items():
        assert re.search(rf"(?m)^class\s+{cls}\b",
                         (REPO / rel).read_text()), \
            f"class {cls} not defined in {rel}"
