"""input_specs construction for every (arch x shape) — cheap (no mesh,
no compile), guards the dry-run entry API."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED
from repro.launch.shapes import SHAPES, applicable
from repro.launch.steps import input_specs

pytestmark = pytest.mark.jax  # full CI tier only


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_construct(arch, shape):
    if not applicable(arch, shape):
        pytest.skip("long_500k inapplicable (DESIGN.md)")
    specs = input_specs(arch, shape)
    leaves = jax.tree.leaves(specs)
    assert leaves, (arch, shape)
    for a in leaves:
        assert isinstance(a, jax.ShapeDtypeStruct)
        assert all(d >= 0 for d in a.shape)
    kind = SHAPES[shape].kind
    if kind == "train":
        assert specs["tokens"].shape == (SHAPES[shape].global_batch,
                                         SHAPES[shape].seq_len)
    elif kind == "decode":
        assert specs["token"].shape == (SHAPES[shape].global_batch, 1)
        assert "cache" in specs
    else:
        assert "batch" in specs and "cache" in specs


def test_decode_cache_is_heads_major():
    specs = input_specs("qwen3-4b", "decode_32k")
    k = specs["cache"]["layers"].k
    cfg_kv, S = 8, 32768
    assert k.shape == (36, 128, cfg_kv, S, 128)
