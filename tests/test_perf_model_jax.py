"""JAX backend vs the numpy oracle: model, solver, allocator, simulator.

The numerical contract (docs/reproduction-notes.md, deviation 5): the
jitted twins agree with the numpy hot path to <= 1e-6 relative — XLA
reassociates sums and fuses multiply-adds, so bitwise equality is out of
scope — while every plan-level DECISION (placements, batches, grid-
snapped allocations, device counts) is bit-identical, because Alg. 1/2
thresholds carry 1e-9 epsilons that dwarf the float divergence.
"""
import numpy as np
import pytest

from repro.core import perf_model_vec as pmv
from repro.core import provisioner as prov
from repro.core.queueing import resolve
from repro.core.types import V5E, PlannerConfig, WorkloadSpec
from tests.test_perf_model_vec import (
    _profiles, plan_key, random_device, random_specs)

pytestmark = pytest.mark.jax   # needs the JAX toolchain (jax CI job)

TOL = dict(rtol=1e-6, atol=1e-9)
FIELDS = ("t_load", "t_sch", "t_act", "t_gpu", "t_feedback", "t_inf",
          "throughput", "freq", "p_demand")


# ---------------------------------------------------------------------------
# Eqs. (1)-(11): jitted forward eval
# ---------------------------------------------------------------------------

def test_predict_device_batch_jax_matches_numpy():
    from repro.core import perf_model_jax as pmj
    rng = np.random.default_rng(0)
    devices = [random_device(rng) for _ in range(16)]
    a = pmv.predict_device_batch(devices, V5E)
    b = pmj.predict_device_batch_jax(devices, V5E)
    assert (a.mask == b.mask).all()
    for f in FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(b, f))[a.mask if getattr(a, f).ndim == 2
                                      else slice(None)],
            getattr(a, f)[a.mask if getattr(a, f).ndim == 2
                          else slice(None)],
            err_msg=f, **TOL)


# ---------------------------------------------------------------------------
# Queueing-aware budget split: jitted bisection
# ---------------------------------------------------------------------------

def test_budget_solver_jax_matches_numpy():
    from repro.core import perf_model_jax as pmj
    rng = np.random.default_rng(1)
    slo = rng.uniform(40.0, 500.0, size=500)
    rate = rng.uniform(0.0, 300.0, size=500)
    batch = rng.integers(1, 33, size=500).astype(float)
    for mode in ("queueing", "half"):
        bm = resolve(mode)
        ref = bm.budget_ms_vec(slo, rate, batch)
        got = pmj.budget_ms_vec_jax(bm, slo, rate, batch)
        np.testing.assert_allclose(got, ref, **TOL)


# ---------------------------------------------------------------------------
# Algorithm 2 against every open device: lax.while_loop twin
# ---------------------------------------------------------------------------

def test_alloc_all_jax_matches_numpy_randomized():
    """Same feasibility verdicts, grid-identical allocations, same
    Alg. 1 scores (to 1e-6) on randomized resident mixes."""
    profiles = _profiles()
    rng = np.random.default_rng(2)
    checked = 0
    for trial in range(40):
        cls = {be: pmv.VecCluster(V5E, budget="queueing", backend=be)
               for be in ("numpy", "jax")}
        for q in range(int(rng.integers(1, 5))):
            for cl in cls.values():
                cl.add_device()
            for i in range(int(rng.integers(0, 4))):
                m = str(rng.choice(["light", "mid", "heavy"]))
                s = WorkloadSpec(f"R{q}_{i}", m,
                                 float(rng.uniform(80, 400)), 30.0)
                b = int(rng.integers(1, 17))
                r = float(rng.choice([0.1, 0.2, 0.25]))
                for cl in cls.values():
                    cl.add_entry(q, s, profiles[m], b, r)
        m = str(rng.choice(["light", "mid", "heavy"]))
        s_new = WorkloadSpec("NEW", m, float(rng.uniform(80, 400)),
                             float(rng.uniform(5, 60)))
        try:
            b = prov.appropriate_batch(s_new, profiles[m], V5E)
            rl = prov.resource_lower_bound(s_new, profiles[m], V5E, b)
        except prov.InfeasibleError:
            continue
        fa, rra, rna, ia = cls["numpy"].alloc_all(s_new, profiles[m], b, rl)
        fb, rrb, rnb, ib = cls["jax"].alloc_all(s_new, profiles[m], b, rl)
        np.testing.assert_array_equal(fb, fa)
        # allocations are +r_unit grid points snapped by round(x, 10):
        # the backends must land on the SAME points, not just close ones
        np.testing.assert_array_equal(rrb[:, :rra.shape[1]][fa], rra[fa])
        np.testing.assert_array_equal(rnb[fa], rna[fa])
        np.testing.assert_allclose(ib[fa], ia[fa], **TOL)
        checked += 1
    assert checked > 10


# ---------------------------------------------------------------------------
# Plan identity: backend="jax" end to end
# ---------------------------------------------------------------------------

def test_provision_backend_jax_plans_identical_randomized():
    profiles = _profiles()
    rng = np.random.default_rng(3)
    compared = 0
    for _ in range(25):
        specs = random_specs(rng)
        try:
            ref = prov.provision(specs, profiles, V5E)
        except prov.InfeasibleError:
            continue
        jx = prov.provision(specs, profiles, V5E,
                            config=PlannerConfig(backend="jax"))
        assert plan_key(jx) == plan_key(ref)
        compared += 1
    assert compared > 8


@pytest.mark.parametrize("budget", ["half", "queueing"])
def test_provision_backend_jax_identical_on_paper_workload(budget):
    from repro.core.experiments import fitted_context
    from repro.serving.workload import twelve_workloads
    ctx = fitted_context()
    specs = twelve_workloads()
    ref = prov.provision(specs, ctx.profiles, ctx.hw, budget=budget)
    jx = prov.provision(specs, ctx.profiles, ctx.hw,
                        config=PlannerConfig(budget=budget, backend="jax"))
    assert plan_key(jx) == plan_key(ref)


def test_replicate_no_split_plan_identical_on_jax():
    """replicate=True on a feasible workload set must be a no-op (k=1
    everywhere) on BOTH backends, and both land on the same plan."""
    profiles = _profiles()
    specs = [WorkloadSpec("W0", "mid", 150.0, 40.0),
             WorkloadSpec("W1", "light", 200.0, 30.0),
             WorkloadSpec("W2", "heavy", 300.0, 10.0)]
    ref = prov.provision(specs, profiles, V5E)
    for backend in ("numpy", "jax"):
        p = prov.provision(specs, profiles, V5E,
                           config=PlannerConfig(replicate=True,
                                                backend=backend))
        assert plan_key(p) == plan_key(ref)
        assert all("#" not in pl.workload.name for pl in p.placements)


# ---------------------------------------------------------------------------
# Simulator backend="jax": bulk table build parity
# ---------------------------------------------------------------------------

def test_physics_table_values_match_numpy():
    from repro.serving import physics
    from repro.serving import physics_jax
    rng = np.random.default_rng(4)
    for n in (1, 2, 3, 5):
        R = int(rng.integers(4, 64))
        shape = (R, n)
        args = (rng.uniform(1e6, 1e8, shape),    # d_load
                rng.uniform(1e5, 1e7, shape),    # d_fb
                rng.uniform(1e9, 1e12, shape),   # flops_i
                rng.uniform(1e7, 1e9, shape),    # w_bytes
                rng.uniform(1e5, 1e7, shape),    # a_bytes
                rng.integers(20, 400, shape).astype(float))   # n_kern
        b = rng.integers(1, 33, shape).astype(float)
        r = rng.uniform(0.05, 0.6, shape)
        ref = physics.device_state_arrays(*args, b, r, n, V5E)
        got = physics_jax.table_values(*args, b, r, n, V5E)
        for name, a, g in zip(("t_load", "t_sched", "t_act", "t_feedback",
                               "freq"),
                              (ref.t_load, ref.t_sched, ref.t_act,
                               ref.t_feedback, ref.freq), got):
            np.testing.assert_allclose(g, a, err_msg=name, **TOL)


def test_simulate_full_backend_jax_matches_numpy():
    from repro.core.experiments import fitted_context
    from repro.serving.simulator import simulate_full
    from repro.serving.workload import models, synthetic_workloads
    ctx = fitted_context("tpu-v5e")
    specs = synthetic_workloads(30, 0)
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    mods = models()
    res_n = simulate_full(plan, mods, ctx.hw, duration_s=3.0, seed=0)
    res_j = simulate_full(plan, mods, ctx.hw, duration_s=3.0, seed=0,
                          backend="jax")
    sb = {s.name: s for s in specs}
    assert res_j.violations(sb) == res_n.violations(sb)
    assert set(res_j.request_latencies) == set(res_n.request_latencies)
    for name, lat_n in res_n.request_latencies.items():
        lat_j = res_j.request_latencies[name]
        assert lat_j.shape == lat_n.shape
        np.testing.assert_allclose(lat_j, lat_n, **TOL)


def test_simulator_scalar_engine_rejects_jax_backend():
    from repro.core.experiments import fitted_context
    from repro.serving.simulator import simulate_full
    from repro.serving.workload import models, synthetic_workloads
    ctx = fitted_context("tpu-v5e")
    specs = synthetic_workloads(5, 0)
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    with pytest.raises(ValueError):
        simulate_full(plan, models(), ctx.hw, duration_s=0.5,
                      engine="scalar", backend="jax")
    with pytest.raises(ValueError):
        simulate_full(plan, models(), ctx.hw, duration_s=0.5,
                      backend="tensorflow")
