"""Per-architecture smoke tests (brief requirement): a REDUCED variant of
each assigned family (2 layers, d_model<=512, <=4 experts) runs one
forward/train step on CPU; output shapes + no NaNs asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, reduced
from repro.models.zoo import build_model

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = reduced(REGISTRY[arch])
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = model.make_train_batch(key, 2, 32)

    hidden, aux = model.forward(params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))

    # one full train step (loss + grads + sgd-style apply)
    def loss_fn(p):
        return model.loss(p, batch, remat=True)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0.0
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = model.loss(new_params, batch, remat=False)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_shapes(arch):
    cfg = reduced(REGISTRY[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = model.make_train_batch(key, 2, 16)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    cache = model.init_cache(2, 48, dtype=jnp.float32)
    logits, cache = model.prefill(params, pb, cache)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        lg, cache = model.decode_step(params, tok, cache)
        assert lg.shape == (2, 1, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(lg)))
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
