"""Vectorized model/provisioner vs the scalar reference oracle.

Pure numpy randomization (seeded) — deliberately no hypothesis
dependency so the tier-1 consistency gate runs on bare environments.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import perf_model as pm
from repro.core import perf_model_vec as pmv
from repro.core import provisioner as prov
from repro.core.types import (PlannerConfig, V5E, WorkloadCoefficients,
                              WorkloadSpec)
from tests.test_perf_model import make_coeffs

TOL = dict(rtol=1e-9, atol=1e-9)
# the scalar-vs-vec suites also pin the jitted backend where it plugs in
# (plan identity / grid-identical allocations); jax params ride the
# jax-marked CI job, numpy params stay in tier 1
BACKENDS = ("numpy", pytest.param("jax", marks=pytest.mark.jax))
FIELDS = ("t_load", "t_sch", "t_act", "t_gpu", "t_feedback", "t_inf",
          "throughput")


def random_coeffs(rng):
    return make_coeffs(
        k1=rng.uniform(0.001, 0.03), k2=rng.uniform(0.2, 6.0),
        k3=rng.uniform(0.5, 9.0), k4=rng.uniform(0.01, 0.5),
        k5=rng.uniform(0.01, 0.5), alpha_cache=rng.uniform(0.0, 0.6))


def random_device(rng, n=None):
    n = int(rng.integers(1, 7)) if n is None else n
    return [pm.PlacedWorkload(random_coeffs(rng), int(rng.integers(1, 33)),
                              float(rng.uniform(0.05, 1.0)))
            for _ in range(n)]


def _profiles():
    return {
        "light": make_coeffs(k1=0.002, k2=0.4, k3=0.8, k5=0.05),
        "mid": make_coeffs(k1=0.01, k2=2.0, k3=3.0),
        "heavy": make_coeffs(k1=0.02, k2=5.0, k3=8.0, k5=0.3),
    }


def random_specs(rng, max_n=9):
    names = rng.choice(["light", "mid", "heavy"],
                       size=int(rng.integers(1, max_n)))
    return [WorkloadSpec(f"W{i}", m, float(rng.uniform(60.0, 400.0)),
                         float(rng.uniform(5.0, 80.0)))
            for i, m in enumerate(names)]


def plan_key(plan):
    return ([(p.workload.name, p.gpu, round(p.r, 9), p.batch)
             for p in plan.placements], plan.n_gpus)


# ---------------------------------------------------------------------------
# Eqs. (1)-(11): batched == scalar to 1e-9
# ---------------------------------------------------------------------------

def test_predict_device_vec_matches_scalar_randomized():
    """Randomized co-location mixes: every per-workload and per-device
    quantity agrees with the scalar Eqs. (1)-(11) to <= 1e-9."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        ws = random_device(rng)
        a = pm.predict_device(ws, V5E)
        b = pmv.predict_device_vec(ws, V5E)
        np.testing.assert_allclose(b.freq, a.freq, **TOL)
        np.testing.assert_allclose(b.p_demand, a.p_demand, **TOL)
        np.testing.assert_allclose(b.delta_sch, a.delta_sch, **TOL)
        assert len(a.per_workload) == len(b.per_workload)
        for wa, wb in zip(a.per_workload, b.per_workload):
            for f in FIELDS:
                np.testing.assert_allclose(getattr(wb, f), getattr(wa, f),
                                           err_msg=f, **TOL)


def test_predict_device_batch_matches_per_device():
    """Ragged device batches: one batched call == D scalar calls."""
    rng = np.random.default_rng(1)
    devices = [random_device(rng) for _ in range(12)]
    batch = pmv.predict_device_batch(devices, V5E)
    for q, ws in enumerate(devices):
        ref = pm.predict_device(ws, V5E)
        got = batch.device(q)
        np.testing.assert_allclose(got.freq, ref.freq, **TOL)
        np.testing.assert_allclose(got.p_demand, ref.p_demand, **TOL)
        for wa, wb in zip(ref.per_workload, got.per_workload):
            np.testing.assert_allclose(wb.t_inf, wa.t_inf, **TOL)
            np.testing.assert_allclose(wb.throughput, wa.throughput, **TOL)


def test_throttling_regime_matches_scalar():
    """Eq. (9) branch coverage: heavy mixes that exceed the power cap."""
    rng = np.random.default_rng(2)
    hit = 0
    for _ in range(100):
        ws = random_device(rng, n=6)
        a = pm.predict_device(ws, V5E)
        b = pmv.predict_device_vec(ws, V5E)
        hit += a.p_demand > V5E.power_cap
        np.testing.assert_allclose(b.freq, a.freq, **TOL)
        for wa, wb in zip(a.per_workload, b.per_workload):
            np.testing.assert_allclose(wb.t_inf, wa.t_inf, **TOL)
    assert hit > 0          # the sweep actually exercised the branch


# ---------------------------------------------------------------------------
# Incremental invariants (VecCluster caching)
# ---------------------------------------------------------------------------

def test_veccluster_incremental_matches_fresh():
    """After appends, grants (set_row_r) and device growth, the cached
    invariants give the same prediction as a fresh scalar evaluation."""
    rng = np.random.default_rng(3)
    profiles = _profiles()
    cl = pmv.VecCluster(V5E, cap_d=1, cap_n=1)   # force capacity growth
    devices = []
    for q in range(5):
        cl.add_device()
        devices.append([])
        for _ in range(int(rng.integers(1, 5))):
            m = str(rng.choice(["light", "mid", "heavy"]))
            s = WorkloadSpec(f"W{q}", m, 200.0, 30.0)
            b = int(rng.integers(1, 17))
            r = float(rng.choice([0.1, 0.2, 0.25, 0.4]))
            cl.add_entry(q, s, profiles[m], b, r)
            devices[q].append((profiles[m], b, r))
    # grant +r_unit to a couple of entries on device 2
    k = int(cl.n[2])
    new_r = cl.r[2, :k].copy()
    new_r[0] = round(new_r[0] + 2 * V5E.r_unit, 10)
    cl.set_row_r(2, new_r)
    devices[2][0] = (devices[2][0][0], devices[2][0][1], float(new_r[0]))
    for q in range(5):
        ref = pm.predict_device(
            [pm.PlacedWorkload(c, b, r) for (c, b, r) in devices[q]], V5E)
        got = cl.predict(q)
        np.testing.assert_allclose(got.p_demand, ref.p_demand, **TOL)
        for wa, wb in zip(ref.per_workload, got.per_workload):
            np.testing.assert_allclose(wb.t_inf, wa.t_inf, **TOL)


# ---------------------------------------------------------------------------
# Algorithm 2: batched == scalar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("budget", ["half", "queueing"])
def test_alloc_gpus_vec_matches_scalar_randomized(budget, backend):
    rng = np.random.default_rng(4)
    profiles = _profiles()
    checked = 0
    for _ in range(60):
        residents = []
        for i in range(int(rng.integers(0, 4))):
            m = str(rng.choice(["light", "mid", "heavy"]))
            s = WorkloadSpec(f"R{i}", m, float(rng.uniform(80, 400)), 30.0)
            residents.append((s, profiles[m], int(rng.integers(1, 17)),
                              float(rng.choice([0.1, 0.2, 0.25]))))
        m = str(rng.choice(["light", "mid", "heavy"]))
        s_new = WorkloadSpec("NEW", m, float(rng.uniform(80, 400)),
                             float(rng.uniform(5, 60)))
        try:
            b = prov.appropriate_batch(s_new, profiles[m], V5E, budget=budget)
            rl = prov.resource_lower_bound(s_new, profiles[m], V5E, b,
                                           budget=budget)
        except prov.InfeasibleError:
            continue
        dev = prov._Dev(entries=list(residents))
        ref = prov.alloc_gpus(dev, s_new, profiles[m], b, rl, V5E,
                              budget=budget)
        got = pmv.alloc_gpus_vec(residents, s_new, profiles[m], b, rl, V5E,
                                 budget=budget, backend=backend)
        assert (ref is None) == (got is None)
        if ref is not None:
            np.testing.assert_allclose(got, ref, **TOL)
            checked += 1
    assert checked > 10


# ---------------------------------------------------------------------------
# Algorithm 1: identical plans from both engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("budget", ["half", "queueing"])
def test_provision_engines_identical_randomized(budget, backend):
    rng = np.random.default_rng(5)
    profiles = _profiles()
    compared = 0
    for _ in range(40):
        specs = random_specs(rng)
        try:
            scalar = prov.provision(specs, profiles, V5E, engine="scalar",
                                    budget=budget)
        except prov.InfeasibleError:
            continue
        vec = prov.provision(specs, profiles, V5E,
                             config=PlannerConfig(engine="vec",
                                                  budget=budget,
                                                  backend=backend))
        assert plan_key(vec) == plan_key(scalar)
        compared += 1
    assert compared > 10


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("budget", ["half", "queueing"])
def test_provision_vec_identical_on_paper_workload(budget, backend):
    """The paper's 4-model 12-workload App study: the batched provisioner
    emits a plan identical to the scalar oracle under both budget
    splits."""
    from repro.core.experiments import fitted_context
    from repro.serving.workload import twelve_workloads
    ctx = fitted_context()
    specs = twelve_workloads()
    scalar = prov.provision(specs, ctx.profiles, ctx.hw, engine="scalar",
                            budget=budget)
    vec = prov.provision(specs, ctx.profiles, ctx.hw,
                         config=PlannerConfig(engine="vec", budget=budget,
                                              backend=backend))
    assert plan_key(vec) == plan_key(scalar)
    if budget == "queueing":
        # and the defaults are: vectorized engine, queueing budget
        assert plan_key(prov.provision(specs, ctx.profiles, ctx.hw)) \
            == plan_key(scalar)


def test_budget_terms_batched_matches_scalar_in_cluster():
    """The VecCluster's cached per-entry budget thresholds equal the
    scalar `BudgetModel.budget_ms` (and the batched `budget_ms_vec`)
    to <= 1e-9 for both modes."""
    from repro.core.queueing import resolve
    rng = np.random.default_rng(7)
    profiles = _profiles()
    for budget in ("half", "queueing"):
        bm = resolve(budget)
        cl = pmv.VecCluster(V5E, budget=budget)
        entries = []
        q = cl.add_device()
        for i in range(6):
            m = str(rng.choice(["light", "mid", "heavy"]))
            s = WorkloadSpec(f"W{i}", m, float(rng.uniform(60, 400)),
                             float(rng.uniform(5, 300)))
            b = int(rng.integers(1, 33))
            cl.add_entry(q, s, profiles[m], b, 0.2)
            entries.append((s, b))
        ref = np.array([bm.budget_ms(s.slo_ms, s.rate_rps, b)
                        for (s, b) in entries])
        got = cl.budget_ms[0, :len(entries)]
        np.testing.assert_allclose(got, ref, **TOL)
        vec = bm.budget_ms_vec(
            np.array([s.slo_ms for s, _ in entries]),
            np.array([s.rate_rps for s, _ in entries]),
            np.array([float(b) for _, b in entries]))
        np.testing.assert_allclose(vec, ref, **TOL)


@pytest.mark.parametrize("backend", BACKENDS)
def test_ffd_and_online_engines_identical(backend):
    rng = np.random.default_rng(6)
    profiles = _profiles()
    cfg = PlannerConfig(engine="vec", backend=backend)
    for _ in range(15):
        specs = random_specs(rng)
        try:
            a = B.provision_ffd(specs, profiles, V5E, use_alloc_gpus=True,
                                engine="scalar")
        except prov.InfeasibleError:
            continue
        b = B.provision_ffd(specs, profiles, V5E, use_alloc_gpus=True,
                            config=cfg)
        assert plan_key(b) == plan_key(a)
        # online arrival of one extra workload
        extra = WorkloadSpec("EXTRA", "mid", 250.0, 25.0)
        base = prov.provision(specs, profiles, V5E)
        pa = prov.add_workload(base, extra, profiles, V5E, engine="scalar")
        pb = prov.add_workload(base, extra, profiles, V5E, config=cfg)
        assert sorted(plan_key(pa)[0]) == sorted(plan_key(pb)[0])


def test_predicted_violations_consistent_with_metrics():
    profiles = _profiles()
    specs = [WorkloadSpec("W0", "mid", 150.0, 40.0),
             WorkloadSpec("W1", "light", 200.0, 30.0)]
    plan = prov.provision(specs, profiles, V5E)
    # Alg. 2 guarantees the non-throttled regime meets T_slo/2
    assert prov.predicted_violations(plan, profiles, V5E) == []
