"""Observability layer (`repro.serving.telemetry`): the hard contracts.

* ``telemetry=None`` is byte-identical to the pre-telemetry build;
* fixed seed => scalar and vec engines emit IDENTICAL event and
  timeline content (wall-time fields excepted);
* every controller placement mutation appears exactly once in the
  event log — the overflow-immune ``reconfig_events`` counter
  reconciles EXACTLY against ``SimResult.stats["n_reconfigs"]``;
* the `Controller.cost_series` deprecation shim returns the same
  tuples the old unbounded list held, off the new bounded ring;
* the JSONL / Prometheus exporters and the stdlib-only
  `benchmarks.telemetry_report` renderer round-trip the state.
"""
import os
import sys
import warnings

import numpy as np
import pytest

from repro.core import perf_model as pm
from repro.core import perf_model_vec as pmv
from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.core.types import PlannerConfig, WorkloadSpec
from repro.serving import faults, traces
from repro.serving.controller import Controller, ControllerConfig
from repro.serving.simulator import simulate_plan
from repro.serving.telemetry import (ControlEvent, RingBuffer, Telemetry,
                                     _p99)
from repro.serving.workload import models, synthetic_workloads

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "telemetry_fixture.jsonl")
DURATION_S = 4.0


@pytest.fixture(scope="module")
def ctx():
    return fitted_context("tpu-v5e")


@pytest.fixture(scope="module")
def setting(ctx):
    specs = synthetic_workloads(8, seed=0)
    cfg = PlannerConfig()
    plan, hw = prov.provision_cheapest(
        specs, {ctx.hw.name: ctx.profiles}, [ctx.hw], config=cfg)
    tr = traces.diurnal([s.name for s in specs], DURATION_S * 1000.0,
                        peak=2.0)
    return specs, cfg, plan, hw, tr


def _controlled(ctx, setting, *, engine, telemetry):
    """One controlled diurnal run with a FRESH controller (controllers
    mutate their plan, so every run gets its own)."""
    specs, cfg, plan, hw, tr = setting
    ctl = Controller(plan, ctx.profiles, hw,
                     config=cfg.replace(batch="joint"), telemetry=telemetry)
    res = simulate_plan(plan, models(), hw, duration_s=DURATION_S, seed=0,
                        trace=tr, adjust_fn=ctl, adjust_scope="cluster",
                        adjust_period_s=1.0, engine=engine,
                        telemetry=telemetry)
    return res, ctl


@pytest.fixture(scope="module")
def runs(ctx, setting):
    """{(engine, tel_on): (SimResult, Controller, Telemetry|None)} —
    the four controlled runs every contract test below reads from."""
    out = {}
    for engine in ("scalar", "vec"):
        for tel_on in (False, True):
            tel = Telemetry() if tel_on else None
            res, ctl = _controlled(ctx, setting, engine=engine,
                                   telemetry=tel)
            out[(engine, tel_on)] = (res, ctl, tel)
    return out


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def test_ring_buffer_bounds_and_accounting():
    rb = RingBuffer(3)
    for i in range(10):
        rb.append(i)
    assert rb.list() == [7, 8, 9]
    assert len(rb) == 3 and rb.capacity == 3
    assert rb.total == 10 and rb.dropped == 7
    assert rb[0] == 7 and list(rb) == [7, 8, 9]
    with pytest.raises(ValueError):
        RingBuffer(0)


def test_p99_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 50, 99, 100, 101, 500):
        w = rng.uniform(1.0, 100.0, size=n).tolist()
        assert _p99(w) == pytest.approx(float(np.percentile(w, 99)),
                                        rel=1e-12)
    assert _p99([]) == 0.0


def test_record_event_counts_kinds():
    tel = Telemetry(retention=2)
    for k in ("resize", "reconfig", "reconfig", "reconfig"):
        tel.record_event(ControlEvent(t_s=0.0, kind=k, workload="w"))
    # the ring dropped rows, the overflow-immune counter did not
    assert len(tel.events) == 2
    assert tel.counters["reconfig_events"] == 3
    assert tel.counters["events_reconfig"] == 3
    assert tel.counters["events_resize"] == 1


# ---------------------------------------------------------------------------
# Contract 1: telemetry=None is byte-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["scalar", "vec"])
def test_telemetry_off_vs_on_byte_identical(runs, engine):
    res_off, _, _ = runs[(engine, False)]
    res_on, _, _ = runs[(engine, True)]
    assert res_off.per_workload == res_on.per_workload
    assert res_off.stats["n_reconfigs"] == res_on.stats["n_reconfigs"]
    assert set(res_off.request_latencies) == set(res_on.request_latencies)
    for k in res_off.request_latencies:
        np.testing.assert_array_equal(res_off.request_latencies[k],
                                      res_on.request_latencies[k])


# ---------------------------------------------------------------------------
# Contract 2: engines emit identical telemetry content
# ---------------------------------------------------------------------------

def test_engines_emit_identical_events_and_timelines(runs):
    _, _, tel_s = runs[("scalar", True)]
    _, _, tel_v = runs[("vec", True)]
    ev_s = [dict(e.to_dict(), wall_ms=0.0) for e in tel_s.events]
    ev_v = [dict(e.to_dict(), wall_ms=0.0) for e in tel_v.events]
    assert ev_s == ev_v
    assert len(ev_s) > 0
    assert tel_s.workloads.list() == tel_v.workloads.list()
    assert tel_s.devices.list() == tel_v.devices.list()
    assert tel_s.drift.list() == tel_v.drift.list()
    assert len(tel_s.workloads) > 0 and len(tel_s.devices) > 0
    # dispatch_* counters are engine-specific BY DESIGN; the event-kind
    # counters are not
    for tel in (tel_s, tel_v):
        kinds = {k: v for k, v in tel.counters.items()
                 if k.startswith("events_")}
        assert kinds == {k: v for k, v in tel_s.counters.items()
                         if k.startswith("events_")}
    assert "dispatch_scalar" in tel_s.counters
    assert "dispatch_numpy" in tel_v.counters


# ---------------------------------------------------------------------------
# Contract 3: every placement mutation appears exactly once
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["scalar", "vec"])
def test_reconfig_events_reconcile_with_stats(runs, engine):
    res, _, tel = runs[(engine, True)]
    n = int(res.stats["n_reconfigs"])
    assert n > 0                     # the diurnal ramp must reconfigure
    assert tel.counters.get("reconfig_events", 0) == n
    assert tel.counters.get("events_reconfig", 0) == n
    assert sum(1 for e in tel.events if e.kind == "reconfig") == n


def test_events_carry_estimator_inputs_and_placements(runs):
    _, _, tel = runs[("vec", True)]
    drift_evs = [e for e in tel.events
                 if e.cause == "drift" and e.kind in ("resize", "split")]
    assert drift_evs
    for e in drift_evs:
        assert e.rate_rps > 0.0 and e.projected_rps > 0.0
        assert e.band_up > 0.0 and e.band_down > 0.0
        assert e.pre is not None     # the touched workload was placed
        for (gpu, batch, r) in e.pre:
            assert gpu >= 0 and batch >= 1 and r > 0.0


def test_device_rows_carry_true_interference_terms(runs, ctx):
    _, _, tel = runs[("vec", True)]
    hw = ctx.hw
    for row in tel.devices:
        n = row["n_colocated"]
        want = 0.0 if n <= 1 else hw.alpha_sch * n + hw.beta_sch  # Eq. 6
        assert row["delta_sch"] == pytest.approx(want)
        assert row["power_sum"] > 0.0
        assert 0.0 < row["freq"] <= hw.max_freq
        assert row["device_power"] >= hw.idle_power
        assert 0.0 < row["util"] <= 1.2    # r_eff sum (+shadow headroom)


def test_drift_series_recorded(runs):
    _, _, tel = runs[("vec", True)]
    rows = tel.drift.list()
    assert rows
    for row in rows:
        assert set(row) == {"t_s", "gpu", "raw", "score", "fleet"}
    # healthy fleet: raw measured/fitted ratios hover near 1
    raws = [r["raw"] for r in rows if r["raw"] > 0]
    assert raws and 0.5 < float(np.median(raws)) < 2.0


def test_controller_wall_phases_recorded(runs):
    _, _, tel = runs[("vec", True)]
    for phase in ("ctl_probe", "ctl_solve", "ctl_apply", "sim_adjust"):
        assert tel.walls.get(phase, 0.0) > 0.0
    assert "probe_hits" in tel.gauges and "probe_misses" in tel.gauges


# ---------------------------------------------------------------------------
# Satellite: cost_series ring + deprecation shim
# ---------------------------------------------------------------------------

def test_cost_series_shim_and_retention(runs):
    _, ctl, _ = runs[("vec", True)]
    assert len(ctl.costs) > 0
    with pytest.warns(DeprecationWarning):
        legacy = ctl.cost_series
    assert legacy == ctl.costs.list()
    assert all(isinstance(t, tuple) and len(t) == 2 for t in legacy)
    assert ctl.costs.capacity == ControllerConfig().cost_retention


def test_cost_retention_knob_bounds_the_ring(ctx, setting):
    specs, cfg, plan, hw, tr = setting
    ctl = Controller(plan, ctx.profiles, hw,
                     config=cfg.replace(batch="joint"),
                     cfg=ControllerConfig(cost_retention=2))
    simulate_plan(plan, models(), hw, duration_s=DURATION_S, seed=0,
                  trace=tr, adjust_fn=ctl, adjust_scope="cluster",
                  adjust_period_s=1.0)
    assert ctl.costs.capacity == 2
    assert len(ctl.costs) == 2 and ctl.costs.total > 2


# ---------------------------------------------------------------------------
# Exporters + report renderer
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_and_report_render(runs, tmp_path):
    from benchmarks import telemetry_report
    _, _, tel = runs[("vec", True)]
    log = tmp_path / "tel.jsonl"
    tel.to_jsonl(str(log))
    data = telemetry_report.load(str(log))
    assert telemetry_report.check(data) == []
    assert len(data["events"]) == len(tel.events)
    assert len(data["workloads"]) == len(tel.workloads)
    assert len(data["devices"]) == len(tel.devices)
    assert len(data["drift"]) == len(tel.drift)
    assert data["summary"]["counters"] == tel.counters
    text = telemetry_report.terminal_report(data)
    assert "telemetry report" in text and "reconfig" in text
    html_doc = telemetry_report.render_html(data)
    assert "<svg" in html_doc and "drift score" in html_doc


def test_prometheus_text_snapshot(runs):
    _, _, tel = runs[("vec", True)]
    text = tel.prometheus_text()
    assert '# TYPE repro_telemetry_count counter' in text
    assert 'repro_telemetry_count{name="reconfig_events"}' in text
    assert 'repro_telemetry_wall_ms{phase="ctl_solve"}' in text
    assert 'repro_telemetry_ring_rows{ring="events"}' in text


def test_committed_fixture_renders_clean():
    from benchmarks import telemetry_report
    data = telemetry_report.load(FIXTURE)
    assert telemetry_report.check(data) == []
    assert data["events"] and data["workloads"] and data["drift"]
    assert "<svg" in telemetry_report.render_html(data)


# ---------------------------------------------------------------------------
# Drift under real stragglers + planner-side snapshot
# ---------------------------------------------------------------------------

def test_straggler_drift_scores_stand_out(ctx):
    specs = synthetic_workloads(8, seed=1)
    cfg = PlannerConfig()
    plan, hw = prov.provision_cheapest(
        specs, {ctx.hw.name: ctx.profiles}, [ctx.hw], config=cfg)
    fs = faults.stragglers(plan.n_gpus, frac=0.2, multiplier=2.5, seed=1)
    tel = Telemetry()
    ctl = Controller(plan, ctx.profiles, hw,
                     config=cfg.replace(batch="joint"), telemetry=tel)
    simulate_plan(plan, models(), hw, duration_s=8.0, seed=1,
                  faults=fs, adjust_fn=ctl, adjust_scope="cluster",
                  adjust_period_s=1.0, telemetry=tel)
    slow = set(fs.slow)
    slow_raw = [r["raw"] for r in tel.drift
                if r["gpu"] in slow and r["raw"] > 0]
    ok_raw = [r["raw"] for r in tel.drift
              if r["gpu"] not in slow and r["raw"] > 0]
    assert slow_raw and ok_raw
    # the recorded residual series separates slow from healthy devices
    assert max(slow_raw) > 1.5 * float(np.median(ok_raw))
    quarantines = [e for e in tel.events if e.kind == "quarantine"]
    migrations = [e for e in tel.events if e.kind == "migrate"]
    assert quarantines and migrations
    assert all(e.cause == "health" for e in migrations)


def test_veccluster_interference_snapshot_matches_predict(ctx):
    rng = np.random.default_rng(5)
    profiles = ctx.profiles
    names = sorted(profiles)
    cl = pmv.VecCluster(ctx.hw)
    devices = []
    for q in range(4):
        cl.add_device()
        devices.append([])
        for _ in range(int(rng.integers(0, 4))):
            mname = names[int(rng.integers(len(names)))]
            s = WorkloadSpec(f"W{q}", mname, 200.0, 30.0)
            b = int(rng.integers(1, 17))
            r = float(rng.choice([0.1, 0.2, 0.25]))
            cl.add_entry(q, s, profiles[mname], b, r)
            devices[q].append((profiles[mname], b, r))
    snap = {row["device"]: row for row in cl.interference_snapshot()}
    assert set(snap) == {q for q in range(4) if devices[q]}
    for q, row in snap.items():
        ref = pm.predict_device(
            [pm.PlacedWorkload(c, b, r) for (c, b, r) in devices[q]],
            ctx.hw)
        assert row["p_demand"] == pytest.approx(ref.p_demand, rel=1e-9)
        assert row["n"] == len(devices[q])
        n = row["n"]
        want = 0.0 if n <= 1 else ctx.hw.alpha_sch * n + ctx.hw.beta_sch
        assert row["delta_sch"] == pytest.approx(want)


def test_provisioner_ops_count_into_telemetry(ctx, setting):
    """The provisioner edit ops accept (and count into) a telemetry
    recorder without changing the edit itself."""
    import dataclasses
    specs, cfg, plan, hw, tr = setting
    tel = Telemetry()
    spec = dataclasses.replace(plan.placements[0].workload,
                               rate_rps=plan.placements[0].workload.rate_rps
                               * 1.5)
    a = prov.resize_workload(plan, spec, ctx.profiles, hw, config=cfg)
    b = prov.resize_workload(plan, spec, ctx.profiles, hw, config=cfg,
                             telemetry=tel)
    assert tel.counters.get("prov_resize") == 1
    assert [(p.gpu, p.workload.name, p.batch, p.r) for p in a.placements] \
        == [(p.gpu, p.workload.name, p.batch, p.r) for p in b.placements]
