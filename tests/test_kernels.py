"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro.kernels.ref (brief requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ssd_scan import ssd_scan

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("S,H,KV,hd", [
    (128, 4, 4, 64),      # MHA
    (256, 8, 2, 64),      # GQA 4:1
    (256, 4, 1, 128),     # MQA, wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_attention(S, H, KV, hd, dtype, causal, window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64,
                        interpret=True)
    o_ref = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("S,H,KV,hd,bk", [
    (512, 4, 2, 64, 128),
    (1024, 8, 8, 64, 256),
    (256, 4, 1, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 128])
def test_decode_attention(S, H, KV, hd, bk, dtype, window):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    qpos = jnp.asarray([S // 2, S - 1], jnp.int32)
    kvpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    o = decode_attention(q, k, v, qpos, kvpos, window=window, bk=bk,
                         interpret=True)
    o_ref = ref.decode_attention_ref(q, k, v, qpos, kvpos, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_rolling_slots():
    """-1 (unwritten) rolling slots must be masked out."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    B, S, H, KV, hd = 1, 128, 2, 2, 64
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    kvpos = jnp.where(jnp.arange(S) < 100, jnp.arange(S), -1)[None]
    qpos = jnp.asarray([99], jnp.int32)
    o = decode_attention(q, k, v, qpos, kvpos.astype(jnp.int32), bk=64,
                         interpret=True)
    o_ref = ref.decode_attention_ref(q, k, v, qpos, kvpos.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


@pytest.mark.parametrize("S,H,hd,q_chunk", [
    (128, 2, 32, 32), (256, 4, 64, 64), (64, 2, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(S, H, hd, q_chunk, dtype):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    B = 2
    r = (0.5 * jax.random.normal(ks[0], (B, S, H, hd))).astype(dtype)
    k = (0.5 * jax.random.normal(ks[1], (B, S, H, hd))).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd)).astype(dtype)
    logw = jnp.maximum(
        -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 1.5),
        -2.0).astype(dtype)
    u = (0.3 * jax.random.normal(ks[4], (H, hd))).astype(dtype)
    y, sf = rwkv6_scan(r, k, v, logw, u, q_chunk=q_chunk, interpret=True)
    y_ref, sf_ref = ref.rwkv6_ref(r, k, v, logw, u)
    tol = 5 * _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("S,H,hd,N,q_chunk", [
    (128, 2, 32, 16, 32), (256, 4, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(S, H, hd, N, q_chunk, dtype):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    B = 2
    xdt = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    Bm = (0.5 * jax.random.normal(ks[1], (B, S, H, N))).astype(dtype)
    Cm = (0.5 * jax.random.normal(ks[2], (B, S, H, N))).astype(dtype)
    dA = -jnp.exp(jax.random.normal(ks[3], (B, S, H)) * 0.5 - 1.5)
    y, h = ssd_scan(xdt, Bm, Cm, dA, q_chunk=q_chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(xdt, Bm, Cm, dA)
    tol = 5 * _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=tol, rtol=tol)


def test_model_wkv_matches_kernel():
    """The model's jnp chunked WKV (factorized) == the Pallas kernel =="""
    from repro.models.rwkv import wkv_chunked
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 5)
    B, S, H, hd = 2, 128, 2, 32
    r = 0.5 * jax.random.normal(ks[0], (B, S, H, hd))
    k = 0.5 * jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) - 1.5),
                       -2.0)
    u = 0.3 * jax.random.normal(ks[4], (H, hd))
    y1, s1 = wkv_chunked(r, k, v, logw, u, q=32)
    y2, s2 = rwkv6_scan(r, k, v, logw, u, q_chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
