"""Golden-schema pins: the EXACT key sets of the JSON surfaces other
tooling consumes — `SimResult.stats`, `SimResult.per_workload`, and
each sweep's row dump.  A new key is a deliberate schema change: update
the golden set here in the same PR that adds it.  Conditional keys
(overload ``classN_*`` / ``shed_requests``, fault accounting, telemetry
columns) are asserted ABSENT when their feature is off — that absence
is the byte-identity story (docs/observability.md).
"""
import os
import sys

import pytest

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.core.types import PlannerConfig
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, synthetic_workloads

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

STATS_KEYS = {
    "n_requests", "n_passes", "n_events", "wall_s", "events_per_s",
    "peak_window", "n_reconfigs", "reconfig_latency_ms",
    "e2e_p50_ms", "e2e_p99_ms", "wait_mean_ms", "wait_p99_ms",
}
PER_WORKLOAD_KEYS = {
    "p99_ms", "p50_ms", "avg_ms", "wait_avg_ms", "wait_p99_ms", "rps",
    "r_final", "batch_final", "shadow_used", "n_replicas",
}
# conditional stats: only under overload admission activity / faults
OVERLOAD_STATS = {"shed_requests", "class0_violation_rate",
                  "class0_shed_rate", "class0_workloads",
                  "brownout_depth_max", "brownout_ticks"}
FAULT_STATS = {"n_failures", "downtime_ms", "lost_requests",
               "n_recoveries", "recovery_mean_ms"}

DYNAMIC_ROW_KEYS = {
    "bench", "m", "scenario", "backend", "hardware", "n_devices",
    "provision_wall_s", "static_violations", "controlled_violations",
    "static_violation_rate", "controlled_violation_rate", "n_reconfigs",
    "n_edits", "n_splits", "n_merges", "split_workloads", "n_replicas",
    "reconfig_latency_ms", "probe_hits", "probe_misses",
    "plan_identical", "static_cost_per_hour", "final_cost_per_hour",
    "mean_cost_per_hour", "static_sim_wall_s", "controlled_sim_wall_s",
    "sim_events_per_s", "sim_duration_s",
}
DYNAMIC_OVERLOAD_KEYS = {
    "max_devices", "hi_workloads", "lo_workloads", "hi_violations",
    "lo_violations", "shed_requests", "lo_shed_rate", "hi_shed_rate",
    "hi_violation_rate", "brownout_depth_max", "brownout_ticks",
    "admission_preemptions", "admission_shed_workloads",
    "admission_readmits",
}
DYNAMIC_TELEMETRY_KEYS = {
    "telemetry_wall_s", "telemetry_overhead", "telemetry_events",
    "telemetry_reconfig_ok", "telemetry_log",
}
# predictive-tier columns: only on the scenarios that run the
# forecast-on third simulation (no_drift's silence gate, spike's
# strictly-better gate)
DYNAMIC_FORECAST_KEYS = {
    "forecast_violations", "forecast_violation_rate",
    "forecast_n_reconfigs", "n_forecast_events", "n_shadow_arms",
    "forecast_plan_identical", "forecast_sim_wall_s",
}
AVAILABILITY_ROW_KEYS = {
    "bench", "m", "scenario", "backend", "hardware", "n_devices",
    "n_failures", "off_violation_rate", "on_violation_rate", "off", "on",
    "n_reconfigs", "n_migrations", "n_readmits", "n_edits",
    "plan_identical", "off_sim_wall_s", "on_sim_wall_s",
    "sim_duration_s",
}
AVAILABILITY_STRAGGLER_KEYS = {
    "n_stragglers", "victim_tail_ok", "victim_tail_worst",
}
AVAILABILITY_TELEMETRY_KEYS = {
    "telemetry_events", "telemetry_drift_rows", "telemetry_reconfig_ok",
    "telemetry_log",
}
SCALE_ROW_KEYS = {
    "bench", "m", "budget", "backend", "wall_s", "target_s",
    "n_devices", "hardware", "cost_per_hour", "predicted_violations",
    "scalar_wall_s", "matches_scalar_oracle", "sim_devices",
    "sim_workloads", "sim_duration_s", "sim_target_s", "sim_wall_s",
    "sim_violations", "sim_requests", "sim_passes", "sim_events_per_s",
    "sim_wait_mean_ms", "sim_wait_p99_ms", "gap",
    "half_n_devices", "half_cost_per_hour", "half_predicted_violations",
    "half_sim_violations", "half_gap",
    "repl_n_devices", "repl_cost_per_hour", "repl_predicted_violations",
    "repl_sim_violations", "repl_split_workloads", "repl_n_replicas",
    "repl_gap",
    "half_repl_n_devices", "half_repl_cost_per_hour",
    "half_repl_predicted_violations", "half_repl_sim_violations",
    "half_repl_split_workloads", "half_repl_n_replicas",
    "half_repl_gap",
}


@pytest.fixture(scope="module")
def sim_result():
    ctx = fitted_context("tpu-v5e")
    specs = synthetic_workloads(6, seed=0)
    cfg = PlannerConfig()
    plan, hw = prov.provision_cheapest(
        specs, {ctx.hw.name: ctx.profiles}, [ctx.hw], config=cfg)
    return simulate_plan(plan, models(), hw, duration_s=2.0, seed=0)


def test_sim_stats_schema(sim_result):
    assert set(sim_result.stats) == STATS_KEYS
    # feature-gated keys absent on a plain (no-fault, no-overload) run
    assert not (set(sim_result.stats) & OVERLOAD_STATS)
    assert not (set(sim_result.stats) & FAULT_STATS)


def test_per_workload_schema(sim_result):
    assert sim_result.per_workload
    for name, rec in sim_result.per_workload.items():
        assert set(rec) == PER_WORKLOAD_KEYS, name
        assert "shed_requests" not in rec


def test_dynamic_sweep_row_schema(tmp_path):
    from benchmarks import dynamic_sweep
    rows = dynamic_sweep.sweep((10,), ("no_drift", "overload"),
                               sim_duration_s=3.0, telemetry=True,
                               artifact_dir=str(tmp_path))
    by_scenario = {r["scenario"]: r for r in rows}
    assert set(by_scenario["no_drift"]) \
        == DYNAMIC_ROW_KEYS | DYNAMIC_TELEMETRY_KEYS \
        | DYNAMIC_FORECAST_KEYS
    assert set(by_scenario["overload"]) \
        == DYNAMIC_ROW_KEYS | DYNAMIC_OVERLOAD_KEYS \
        | DYNAMIC_TELEMETRY_KEYS
    assert not (set(by_scenario["overload"]) & DYNAMIC_FORECAST_KEYS)
    assert os.path.exists(by_scenario["no_drift"]["telemetry_log"])
    assert os.path.exists(
        str(tmp_path / "telemetry_m10_overload.html"))


def test_dynamic_sweep_row_schema_telemetry_off():
    from benchmarks import dynamic_sweep
    rows = dynamic_sweep.sweep((10,), ("no_drift",), sim_duration_s=3.0)
    assert set(rows[0]) == DYNAMIC_ROW_KEYS | DYNAMIC_FORECAST_KEYS
    assert not (set(rows[0]) & DYNAMIC_TELEMETRY_KEYS)
    assert not (set(rows[0]) & DYNAMIC_OVERLOAD_KEYS)


def test_availability_sweep_row_schema():
    from benchmarks import availability_sweep
    rows = availability_sweep.sweep((10,), rates=(), sim_duration_s=3.0)
    by_scenario = {r["scenario"]: r for r in rows}
    assert set(by_scenario) == {"clean", "straggler"}
    assert set(by_scenario["clean"]) == AVAILABILITY_ROW_KEYS
    assert set(by_scenario["straggler"]) \
        == AVAILABILITY_ROW_KEYS | AVAILABILITY_STRAGGLER_KEYS
    assert not (set(by_scenario["clean"]) & AVAILABILITY_TELEMETRY_KEYS)
    for r in rows:
        assert set(r["off"]) == FAULT_STATS
        assert set(r["on"]) == FAULT_STATS


def test_scale_sweep_row_schema():
    from benchmarks import scale_sweep
    rows = scale_sweep.sweep((10,), sim_duration_s=1.0)
    assert set(rows[0]) == SCALE_ROW_KEYS
