"""Coefficient acquisition: the 11-config least-squares fit must recover a
known Eq.-11 surface, and the full pipeline must fit the simulator within
paper-like error."""
import numpy as np
import pytest

from repro.core import coefficients as C
from repro.core import perf_model as pm
from repro.core.types import V5E
from repro.serving.simulator import SimTestbed
from repro.serving.workload import models


def test_fit_k_act_recovers_known_surface():
    k1, k2, k3, k4, k5 = 0.02, 1.5, 4.0, 0.05, 0.2
    samples = []
    for (b, r) in C.ELEVEN_CONFIGS:
        t = (k1 * b * b + k2 * b + k3) / (r + k4) + k5
        samples.append(C.ProfileSample(
            model="m", batch=b, r=r, t_load=0, t_sched=0, t_act=t,
            t_feedback=0, power=0, cache_util=0, n_kernels=100,
            d_load=0.1 * b, d_feedback=0.01 * b))
    f1, f2, f3, f4, f5 = C.fit_k_act(samples)
    # the surface must be recovered pointwise (k-params can trade off)
    for (b, r) in [(3, 0.33), (12, 0.77), (24, 0.15)]:
        truth = (k1 * b * b + k2 * b + k3) / (r + k4) + k5
        fit = (f1 * b * b + f2 * b + f3) / (r + f4) + f5
        assert abs(fit - truth) / truth < 0.02


@pytest.fixture(scope="module")
def fitted():
    mods = models()
    tb = SimTestbed(mods, V5E)
    hw = C.fit_hardware("qwen2-vl-7b", V5E, tb)
    profiles = {m: C.fit_workload(m, hw, tb) for m in mods}
    return tb, hw, profiles


def test_solo_prediction_error_paper_range(fitted):
    """Held-out solo configs: avg error must be in the paper's range
    (their Figs. 11-12: ~0.04-9.3%)."""
    tb, hw, profiles = fitted
    for name, c in profiles.items():
        errs = []
        for (b, r) in [(2, 0.25), (6, 0.45), (12, 0.7), (24, 0.9), (3, 0.15)]:
            s = tb.run_solo(name, b, r)
            obs = s.t_load + s.t_sched + s.t_act + s.t_feedback
            pred = pm.predict_device(
                [pm.PlacedWorkload(c, b, r)], hw).per_workload[0].t_inf
            errs.append(abs(pred - obs) / obs)
        assert np.mean(errs) < 0.10, (name, errs)


def test_colocated_prediction_error(fitted):
    """4-way co-location (paper Fig. 13): error within ~12%."""
    tb, hw, profiles = fitted
    entries = [("rwkv6-1.6b", 4, 0.25), ("qwen1.5-4b", 4, 0.25),
               ("qwen2-vl-7b", 3, 0.25), ("whisper-large-v3", 2, 0.2)]
    obs = tb.run_colocated(entries)
    placed = [pm.PlacedWorkload(profiles[m], b, r) for (m, b, r) in entries]
    pred = pm.predict_device(placed, hw)
    for (m, b, r), o, p in zip(entries, obs, pred.per_workload):
        observed = o.t_load + (o.t_sched + o.t_act) * (hw.max_freq / o.device_freq) + o.t_feedback
        err = abs(p.t_inf - observed) / observed
        assert err < 0.15, (m, err, p.t_inf, observed)


def test_fit_hardware_recovers_sched_slope(fitted):
    tb, hw, profiles = fitted
    assert hw.alpha_sch > 0          # co-location slows dispatch
    assert abs(hw.beta_sch) < 0.05
