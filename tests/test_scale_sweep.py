"""Large-cluster scale sweep: synthetic workload generation, sampled
device simulation, and the benchmarks.scale_sweep entry point."""
import os
import sys

import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.serving.simulator import simulate_device_sample, subplan
from repro.serving.workload import models, synthetic_workloads

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _hetero():
    ctx5 = fitted_context("tpu-v5e")
    ctx4 = fitted_context("tpu-v4")
    return ({ctx5.hw.name: ctx5.profiles, ctx4.hw.name: ctx4.profiles},
            [ctx5.hw, ctx4.hw])


def test_synthetic_workloads_deterministic_and_valid():
    a = synthetic_workloads(50, seed=7)
    b = synthetic_workloads(50, seed=7)
    assert [(w.name, w.model, w.slo_ms, w.rate_rps) for w in a] \
        == [(w.name, w.model, w.slo_ms, w.rate_rps) for w in b]
    assert len({w.name for w in a}) == 50
    mods = models()
    for w in a:
        assert w.model in mods
        assert w.slo_ms > 0 and w.rate_rps > 0
    # a different seed gives a different mix
    c = synthetic_workloads(50, seed=8)
    assert [(w.model, w.slo_ms) for w in a] != [(w.model, w.slo_ms) for w in c]


def test_provision_cheapest_synthetic_scale():
    profiles_by_hw, hardware = _hetero()
    specs = synthetic_workloads(40, seed=0)
    plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware)
    assert len(plan.placements) == 40
    assert plan.n_gpus >= 1
    for g in {p.gpu for p in plan.placements}:
        assert plan.total_allocated(g) <= 1.0 + 1e-9
    # vec and scalar engines agree end-to-end through the hetero selector
    oracle, hw_o = prov.provision_cheapest(specs, profiles_by_hw, hardware,
                                           engine="scalar")
    assert hw_o.name == hw.name
    assert [(p.workload.name, p.gpu, round(p.r, 9)) for p in oracle.placements] \
        == [(p.workload.name, p.gpu, round(p.r, 9)) for p in plan.placements]


def test_subplan_and_device_sample():
    profiles_by_hw, hardware = _hetero()
    specs = synthetic_workloads(25, seed=1)
    plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware)
    gpus = sorted({p.gpu for p in plan.placements})
    sub = subplan(plan, gpus[:2])
    assert {p.gpu for p in sub.placements} <= set(gpus[:2])
    assert sub.n_gpus == len({p.gpu for p in sub.placements})

    res, sampled = simulate_device_sample(plan, models(), hw,
                                          max_devices=3, duration_s=2.0)
    assert len(sampled) <= 3
    hosted = {p.workload.name for p in plan.placements if p.gpu in set(sampled)}
    assert set(res.per_workload) == hosted
    for m in res.per_workload.values():
        assert m["rps"] > 0
        assert np.isfinite(m["p99_ms"])


def test_scale_sweep_quick_rows(tmp_path):
    from benchmarks import scale_sweep
    rows = scale_sweep.sweep((10,), sim_duration_s=1.0)
    assert len(rows) == 1
    row = rows[0]
    assert row["m"] == 10
    assert row["wall_s"] >= 0
    assert row["n_devices"] >= 1
    assert row["matches_scalar_oracle"] is True
    assert "predicted_violations" in row and "sim_violations" in row
    # full-cluster simulation: every device, closed loop vs ground truth
    assert row["sim_devices"] == row["n_devices"]
    assert row["sim_workloads"] == row["m"]
    assert row["sim_requests"] > 0 and row["sim_passes"] > 0
    assert row["sim_events_per_s"] > 0
    assert row["sim_wall_s"] >= 0

    out = tmp_path / "results.json"
    status = scale_sweep.main(["--sizes", "10", "--out", str(out)])
    assert status == 0
    assert out.exists()


def test_scale_sweep_sim_floor_enforced(tmp_path):
    from benchmarks import scale_sweep
    out = tmp_path / "results.json"
    # an absurd floor must fail the run; a tiny one must pass
    assert scale_sweep.main(["--sizes", "10", "--sim-duration", "1",
                             "--sim-floor", "1e15",
                             "--out", str(out)]) == 1
    assert scale_sweep.main(["--sizes", "10", "--sim-duration", "1",
                             "--sim-floor", "1",
                             "--out", str(out)]) == 0


def test_full_simulation_reports_violations_for_hosted_specs():
    """`simulate_full` + `SimResult.violations` close the loop the
    predicted_violations count used to stand in for."""
    from repro.serving.simulator import simulate_full
    profiles_by_hw, hardware = _hetero()
    specs = synthetic_workloads(25, seed=1)
    plan, hw = prov.provision_cheapest(specs, profiles_by_hw, hardware)
    res = simulate_full(plan, models(), hw, duration_s=2.0)
    assert set(res.per_workload) == {s.name for s in specs}
    sb = {s.name: s for s in specs}
    viols = res.violations(sb)
    assert set(viols) <= set(sb)
