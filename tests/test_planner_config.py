"""The unified `PlannerConfig` surface: legacy-shim equivalence,
validation, knob precedence, structured infeasibility diagnostics, and
the reconciler's Theorem-1 probe cache.

The compatibility contract is bit-level: a legacy keyword call and its
``config=`` spelling must produce IDENTICAL plans (`experiments`-style
placements, batches, grid allocations), not merely equivalent ones.
"""
import dataclasses

import pytest

from repro.core import baselines as B
from repro.core import provisioner as prov
from repro.core.queueing import resolve
from repro.core.types import (PlannerConfig, V5E, WorkloadSpec,
                              planner_config)
from repro.serving.controller import ControllerConfig, Reconciler
from tests.test_perf_model_vec import _profiles, plan_key


def _specs():
    return [WorkloadSpec("W0", "mid", 150.0, 40.0),
            WorkloadSpec("W1", "light", 200.0, 30.0),
            WorkloadSpec("W2", "heavy", 300.0, 10.0)]


# ---------------------------------------------------------------------------
# Resolution rules
# ---------------------------------------------------------------------------

def test_defaults_reproduce_historical_knobs():
    cfg = PlannerConfig()
    assert (cfg.backend, cfg.engine, cfg.budget, cfg.batch,
            cfg.replicate, cfg.k_max) == \
        ("numpy", "vec", "queueing", "eq17", False, prov.K_MAX)


def test_config_plus_legacy_keyword_is_type_error():
    with pytest.raises(TypeError, match="not both"):
        planner_config(PlannerConfig(), budget="half")
    with pytest.raises(TypeError):
        prov.provision(_specs(), _profiles(), V5E,
                       config=PlannerConfig(), budget="half")
    # None-valued legacy keywords are sentinels, not conflicts
    assert planner_config(PlannerConfig(budget="half"),
                          budget=None).budget == "half"


def test_base_carries_call_site_defaults():
    base = PlannerConfig(batch="joint", k_max=3)
    assert planner_config(None, base=base) is base
    # legacy keywords override the base, not the global defaults
    got = planner_config(None, base=base, budget="half")
    assert (got.batch, got.k_max, got.budget) == ("joint", 3, "half")
    # an explicit config replaces the base outright
    assert planner_config(PlannerConfig(), base=base).batch == "eq17"


def test_validation_rejects_unknown_knobs():
    for bad in (dict(backend="tensorflow"), dict(engine="gpu"),
                dict(batch="auto"), dict(budget="thirds"),
                dict(k_max=0), dict(backend="jax", engine="scalar")):
        with pytest.raises(ValueError):
            PlannerConfig(**bad)


def test_frozen_and_hashable():
    cfg = PlannerConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.budget = "half"
    assert cfg.replace(budget="half") == PlannerConfig(budget="half")
    assert cfg == PlannerConfig() and hash(cfg) == hash(PlannerConfig())


# ---------------------------------------------------------------------------
# Legacy shims: bit-identical plans
# ---------------------------------------------------------------------------

def test_legacy_keywords_and_config_identical_plans():
    specs, profiles = _specs(), _profiles()
    for budget in ("half", "queueing"):
        legacy = prov.provision(specs, profiles, V5E, budget=budget)
        cfg = prov.provision(specs, profiles, V5E,
                             config=PlannerConfig(budget=budget))
        assert plan_key(cfg) == plan_key(legacy)
    a = B.provision_ffd(specs, profiles, V5E, budget="half")
    b = B.provision_ffd(specs, profiles, V5E,
                        config=PlannerConfig(budget="half"))
    assert plan_key(a) == plan_key(b)


def test_plan_edits_accept_config():
    specs, profiles = _specs(), _profiles()
    plan = prov.provision(specs, profiles, V5E)
    extra = WorkloadSpec("EXTRA", "mid", 250.0, 25.0)
    pa = prov.add_workload(plan, extra, profiles, V5E, budget="queueing")
    pb = prov.add_workload(plan, extra, profiles, V5E,
                           config=PlannerConfig())
    assert sorted(plan_key(pa)[0]) == sorted(plan_key(pb)[0])
    assert prov.predicted_violations(pb, profiles, V5E,
                                     config=PlannerConfig()) == []


# ---------------------------------------------------------------------------
# Controller knob precedence: config= > cfg.planner > legacy cfg.k_max
# ---------------------------------------------------------------------------

def test_reconciler_planner_precedence():
    specs, profiles = _specs(), _profiles()
    plan = prov.provision(specs, profiles, V5E)
    r = Reconciler(plan, profiles, V5E)
    assert r.planner.batch == "joint"         # historical default kept
    assert r.k_max == ControllerConfig().k_max

    r = Reconciler(plan, profiles, V5E, cfg=ControllerConfig(k_max=3))
    assert r.k_max == 3
    r = Reconciler(plan, profiles, V5E, cfg=ControllerConfig(
        k_max=3, planner=PlannerConfig(batch="joint", k_max=5)))
    assert r.k_max == 5                       # cfg.planner beats cfg.k_max
    r = Reconciler(plan, profiles, V5E, config=PlannerConfig(k_max=7),
                   cfg=ControllerConfig(
                       planner=PlannerConfig(batch="joint", k_max=5)))
    assert r.k_max == 7                       # config= beats both
    with pytest.raises(TypeError):
        Reconciler(plan, profiles, V5E, config=PlannerConfig(),
                   budget="half")


# ---------------------------------------------------------------------------
# Structured infeasibility diagnostics
# ---------------------------------------------------------------------------

def test_provision_cheapest_per_hw_diagnostics():
    profiles = _profiles()
    impossible = [WorkloadSpec("DOOM", "heavy", 0.05, 5000.0)]
    with pytest.raises(prov.InfeasibleError) as ei:
        prov.provision_cheapest(impossible, {V5E.name: profiles}, [V5E])
    assert set(ei.value.per_hw) == {V5E.name}
    assert "DOOM" in ei.value.per_hw[V5E.name]


# ---------------------------------------------------------------------------
# Theorem-1 probe cache
# ---------------------------------------------------------------------------

def test_probe_cache_hits_and_misses():
    profiles = _profiles()
    cache = prov.ProbeCache()
    bm = resolve("queueing")
    s = WorkloadSpec("W0", "mid", 150.0, 40.0)
    ref = (prov.appropriate_batch(s, profiles["mid"], V5E),)
    ref += (prov.resource_lower_bound(s, profiles["mid"], V5E, ref[0]),)
    assert cache.theorem1(s, profiles["mid"], V5E, bm, "eq17") == ref
    assert (cache.hits, cache.misses) == (0, 1)
    assert cache.theorem1(s, profiles["mid"], V5E, bm, "eq17") == ref
    assert (cache.hits, cache.misses) == (1, 1)
    # a rename with identical (slo, rate, model) still hits: the key is
    # the probe's actual inputs, not the workload identity
    s2 = dataclasses.replace(s, name="RENAMED")
    assert cache.theorem1(s2, profiles["mid"], V5E, bm, "eq17") == ref
    assert cache.hits == 2


def test_probe_cache_reraises_cached_infeasible():
    profiles = _profiles()
    cache = prov.ProbeCache()
    bm = resolve("queueing")
    doom = WorkloadSpec("DOOM", "heavy", 0.05, 5000.0)
    for _ in range(2):           # miss, then cached sentinel
        with pytest.raises(prov.InfeasibleError):
            cache.theorem1(doom, profiles["heavy"], V5E, bm, "eq17")
    assert (cache.hits, cache.misses) == (1, 1)


def test_probe_cache_required_replicas_warms_solo_probes():
    profiles = _profiles()
    cache = prov.ProbeCache()
    bm = resolve("queueing")
    hot = WorkloadSpec("HOT", "heavy", 120.0, 400.0)
    k = cache.required_replicas(hot, profiles["heavy"], V5E, bm, "eq17")
    assert k == prov.required_replicas(hot, profiles["heavy"], V5E,
                                       budget=bm, batch="eq17")
    misses = cache.misses
    # the second ask is a pure hit, and the per-k solo probes are warm
    assert cache.required_replicas(hot, profiles["heavy"], V5E, bm,
                                   "eq17") == k
    assert cache.misses == misses
    if k and k > 1:
        from repro.core import replication
        probe = replication.make_replicas(hot, k)[0]
        assert cache.solo_feasible(probe, profiles["heavy"], V5E, bm,
                                   "eq17")
        assert cache.misses == misses
