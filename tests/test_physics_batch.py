"""Batched ground-truth physics: `device_state_batch` vs the scalar
`device_state` wrapper (which is a thin shim over it — agreement must be
bitwise, well inside the <= 1e-9 contract)."""
import numpy as np
import pytest

from repro.core.types import V4, V5E
from repro.serving import physics
from repro.serving.workload import models


@pytest.fixture(scope="module")
def descs():
    return list(models().values())


def test_solo_terms_returns_seven(descs):
    out = physics.solo_terms(descs[0], 8, 0.4, V5E)
    assert len(out) == 7
    t_load, k_disp, t_c, t_m, p, cache, t_fb = out
    assert all(isinstance(v, float) for v in out)
    assert t_load > 0 and t_fb > 0 and p > 0


@pytest.mark.parametrize("hw", [V5E, V4], ids=lambda h: h.name)
def test_batch_matches_wrapper_randomized(descs, hw):
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(1, 6))
        entries = [(descs[int(rng.integers(len(descs)))],
                    int(rng.integers(1, 33)),
                    float(rng.uniform(0.05, 0.8))) for _ in range(n)]
        b = np.array([float(e[1]) for e in entries])
        r = np.array([e[2] for e in entries])
        st = physics.device_state_batch([e[0] for e in entries], b, r, hw)
        scalars = physics.device_state(entries, hw)
        for i, s in enumerate(scalars):
            assert s.t_load == float(st.t_load[i])
            assert s.t_sched == float(st.t_sched[i])
            assert s.t_act == float(st.t_act[i])
            assert s.t_feedback == float(st.t_feedback[i])
            assert s.power == float(st.power[i])
            assert s.cache_util == float(st.cache_util[i])
            assert s.freq == float(st.freq)
            assert s.device_power == float(st.device_power)
            assert abs(s.t_inf - float(st.t_inf[i])) <= 1e-12 * abs(s.t_inf)


def test_batch_grid_rows_match_per_call(descs):
    """The simulator's use case: one (K, n) grid varying the focal batch
    must equal K independent `device_state` calls bitwise — including in
    the throttling regime where SIMD pow rounding used to diverge."""
    focal, peer = descs[0], descs[1]
    bmax = 64
    b = np.empty((bmax, 2))
    r = np.empty((bmax, 2))
    b[:, 0] = np.arange(1, bmax + 1)
    b[:, 1] = 16.0
    r[:, 0] = 0.45
    r[:, 1] = 0.55
    st = physics.device_state_batch([focal, peer], b, r, V5E)
    throttled = 0
    for k in range(bmax):
        s = physics.device_state([(focal, k + 1, 0.45), (peer, 16, 0.55)],
                                 V5E)[0]
        assert s.t_sched == float(st.t_sched[k, 0])
        assert s.t_act == float(st.t_act[k, 0])
        assert s.t_inf == float(st.t_inf[k, 0])
        assert s.freq == float(st.freq[k])
        throttled += s.freq < V5E.max_freq
    assert throttled > 0              # the grid must cross the power knee


def test_oversubscription_in_batch(descs):
    """Sum r > 1: time-slice shrink + thrash must match the scalar path."""
    d = descs[1]
    entries = [(d, 8, 0.8), (d, 8, 0.8)]
    st = physics.device_state_batch([d, d], np.array([8.0, 8.0]),
                                    np.array([0.8, 0.8]), V5E)
    sc = physics.device_state(entries, V5E)
    assert sc[0].t_inf == float(st.t_inf[0])
    ok = physics.device_state([(d, 8, 0.5), (d, 8, 0.5)], V5E)[0]
    assert sc[0].t_inf > ok.t_inf


def test_noise_path_deterministic_and_distinct(descs):
    d = descs[0]
    entries = [(d, 8, 0.3), (d, 4, 0.3)]
    a = physics.device_state(entries, V5E, np.random.default_rng(7))
    b = physics.device_state(entries, V5E, np.random.default_rng(7))
    base = physics.device_state(entries, V5E)
    assert [s.t_inf for s in a] == [s.t_inf for s in b]
    assert all(s.t_inf != n.t_inf for s, n in zip(base, a))
    # noise perturbs t_act/t_sched only, never the IO terms
    assert all(s.t_load == n.t_load and s.t_feedback == n.t_feedback
               for s, n in zip(base, a))


def test_broadcasting_shapes(descs):
    d = descs[0]
    st = physics.device_state_batch([d], np.arange(1.0, 9.0)[:, None],
                                    np.full((8, 1), 0.5), V5E)
    assert st.t_inf.shape == (8, 1)
    assert st.freq.shape == (8,)
    # latency grows with batch
    assert np.all(np.diff(st.t_inf[:, 0]) > 0)
