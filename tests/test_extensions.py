"""Beyond-paper extensions: 8-bit Adam, Poisson-arrival robustness,
serving engine integration, launchers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.optimizer import (AdamW, QuantState, _dequantize,
                                      _quantize, choose_block, quantizable)

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only


def test_quant_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 3.0
    qs = _quantize(x)
    back = _dequantize(qs, x.shape)
    # blockwise absmax int8: error <= scale/2 = max|block|/254
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 100


def test_choose_block_alignment():
    assert choose_block((8, 16384)) == 256
    # dbrx F=10752: 672 per 16-way shard -> block must divide 672
    b = choose_block((16, 6144, 10752))
    assert b is not None and 10752 % b == 0 and (10752 // 16) % b == 0
    assert choose_block((100,)) is None          # vectors never quantized


def test_quantized_adam_converges_like_f32():
    def run(quant):
        opt = AdamW(lr=0.05, warmup_steps=1, total_steps=400,
                    weight_decay=0.0, grad_clip=None,
                    quant_min_size=16 if quant else None)
        params = {"w": jnp.ones((4, 512)) * 2.0}
        st = opt.init(params)
        for _ in range(100):
            g = {"w": 2 * params["w"]}
            params, st = opt.update(g, st, params)
        return float(jnp.abs(params["w"]).max())
    f32 = run(False)
    q8 = run(True)
    assert q8 < 0.2 and abs(q8 - f32) < 0.15


def test_quant_state_is_pytree_and_checkpointable():
    import tempfile
    from repro.training import checkpoint as ckpt
    opt = AdamW(quant_min_size=16)
    params = {"w": jnp.ones((4, 512))}
    st = opt.init(params)
    assert isinstance(st.mu["w"], QuantState)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, st)
        restored, _ = ckpt.restore_latest(d, st)
        np.testing.assert_array_equal(np.asarray(restored.mu["w"].q),
                                      np.asarray(st.mu["w"].q))


def test_poisson_fragility_documented():
    """Beyond-paper FINDING (EXPERIMENTS.md §Repro-validation notes):
    iGniter sizes b_appr to *just meet* the mean arrival rate and spends
    the full T/2 latency budget on the batch pass — utilization -> 1 and
    zero tail slack.  Under Poisson arrivals the M/D/1-style queue pushes
    essentially every workload over its P99 SLO, so the paper's
    constant-rate client (Sec. 5.1) is a load-bearing assumption.

    The principled fix is the queueing-delay term in the Eq. 14 budget
    split (`core/queueing.py`, the provisioner-wide default since PR 3):
    the half split's fragility stays reproducible via ``budget="half"``,
    and the queueing-aware split resolves it on the same seed."""
    from repro.core import provisioner as prov
    from repro.core.experiments import fitted_context
    from repro.serving.simulator import simulate_plan
    from repro.serving.workload import models, specs_by_name, twelve_workloads
    ctx = fitted_context()
    specs = twelve_workloads()
    sb = specs_by_name()

    plan = prov.provision(specs, ctx.profiles, ctx.hw, budget="half")
    res = simulate_plan(plan, models(), ctx.hw, duration_s=20.0,
                        poisson=True, shadow=False, seed=3)
    naive = res.violations(sb)
    assert len(naive) >= 8              # the fragility is real and large

    plan2 = prov.provision(specs, ctx.profiles, ctx.hw)   # queueing split
    res2 = simulate_plan(plan2, models(), ctx.hw, duration_s=20.0,
                         poisson=True, shadow=False, seed=3)
    fixed = res2.violations(sb)
    assert len(fixed) <= 2              # tails tamed on the same seed
    assert len(fixed) < len(naive)


def test_serving_engine_batched():
    import time
    from repro.configs import REGISTRY, reduced
    from repro.serving.engine import Request, ServingEngine
    cfg = reduced(REGISTRY["qwen3-4b"], layers=2, d_model=128)
    eng = ServingEngine(cfg, batch_size=2, prompt_len=16, decode_tokens=2)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(rid=i, tokens=rng.integers(
            3, cfg.vocab_size, size=16).astype(np.int32),
            arrival_s=time.time()))
    out = eng.pump() + eng.pump()
    assert len(out) == 4
    assert all(c.tokens.shape == (2,) for c in out)
    assert eng.p99_ms() > 0


def test_gslice_reactive_oscillation_visible():
    """Fig. 15/16: GSLICE+'s threshold tuning must actually move r/b."""
    import functools
    from repro.core import baselines as B
    from repro.core.experiments import fitted_context
    from repro.serving.simulator import measure_steady
    from repro.serving.workload import models, twelve_workloads
    ctx = fitted_context()
    mfn = functools.partial(measure_steady, models=models(), hw=ctx.hw)
    plan = B.provision_gslice(twelve_workloads(), ctx.profiles, ctx.hw, mfn)
    # batches were reactively grown from 1
    assert any(p.batch > 1 for p in plan.placements)


def test_expert_parallel_matches_dense_dispatch():
    """apply_moe_ep (shard_map all-to-all EP) must equal apply_moe exactly
    in the dropless regime.  Runs in a subprocess with 8 host devices so
    the 4-way data (EP) x 2-way model (TP) mesh is real."""
    import subprocess
    import sys
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import REGISTRY, reduced
from repro.models import moe as M
cfg = reduced(REGISTRY["dbrx-132b"]).replace(n_experts=4, top_k=2,
                                             capacity_factor=8.0)
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
p = M.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model), jnp.float32)
with mesh:
    y_ref, _ = jax.jit(lambda p, x: M.apply_moe(p, x, cfg, chunk=32))(p, x)
    y_ep, _ = jax.jit(lambda p, x: M.apply_moe_ep(p, x, cfg, mesh=mesh,
                                                  chunk=32))(p, x)
err = float(jnp.max(jnp.abs(y_ref - y_ep)))
assert err < 1e-5, err
print("OK", err)
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
