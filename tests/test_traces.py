"""Dynamic rate traces: piecewise-constant schedules and the arrival
generators both simulator engines consume.

Invariants: generated arrivals live inside the trace's support (zero-
rate segments produce nothing), deterministic counts follow the rate
integral exactly, Poisson thinning matches the expected count to
statistical tolerance, and the flat-trace special case reduces to the
static evenly-spaced process.
"""
import math

import numpy as np
import pytest

from repro.serving import traces


def _names():
    return ["A", "B"]


# ---------------------------------------------------------------------------
# Trace construction and lookups
# ---------------------------------------------------------------------------

def test_trace_validation():
    with pytest.raises(ValueError):        # edges not starting at 0
        traces.Trace(edges=np.array([1.0, 2.0]), scales={"A": np.array([1.0])})
    with pytest.raises(ValueError):        # non-increasing edges
        traces.Trace(edges=np.array([0.0, 5.0, 5.0]),
                     scales={"A": np.array([1.0, 2.0])})
    with pytest.raises(ValueError):        # wrong segment count
        traces.Trace(edges=np.array([0.0, 5.0]),
                     scales={"A": np.array([1.0, 2.0])})
    with pytest.raises(ValueError):        # negative rate
        traces.Trace(edges=np.array([0.0, 5.0]),
                     scales={"A": np.array([-1.0])})


def test_scale_lookups_step():
    tr = traces.step_spike(_names(), 10_000.0, at_ms=4000.0,
                           duration_ms=2000.0, scale=2.5)
    assert tr.scale_at("A", 0.0) == 1.0
    assert tr.scale_at("A", 4000.0) == 2.5
    assert tr.scale_at("A", 5999.9) == 2.5
    assert tr.scale_at("A", 6000.0) == 1.0
    assert tr.scale_at("missing", 5000.0) == 1.0    # absent: static rate
    # time-weighted mean: 8s at 1.0 + 2s at 2.5
    assert tr.mean_scale("A", 10_000.0) == pytest.approx(1.3)
    assert tr.max_scale("A", 10_000.0) == 2.5
    assert tr.max_scale("A", 3000.0) == 1.0         # clipped before spike


def test_segments_clip_and_extend():
    tr = traces.step_spike(_names(), 10_000.0, at_ms=4000.0,
                           duration_ms=2000.0, scale=2.0)
    e, s = tr.segments("A", 5000.0)                 # clip mid-spike
    assert e[0] == 0.0 and e[-1] == 5000.0
    assert s.tolist() == [1.0, 2.0]
    e, s = tr.segments("A", 20_000.0)               # extend last segment
    assert e[-1] == 20_000.0 and s[-1] == 1.0
    assert (np.diff(e) > 0).all()


def test_diurnal_shape():
    tr = traces.diurnal(_names(), 10_000.0, peak=2.0)
    s = tr.scales["A"]
    assert s.min() >= 1.0 - 1e-9
    assert s.max() <= 2.0 + 1e-9
    assert s.max() > 1.95                    # reaches (nearly) the peak
    assert abs(s[0] - 1.0) < 0.05 and abs(s[-1] - 1.0) < 0.05
    assert tr.mean_scale("A", 10_000.0) == pytest.approx(1.5, abs=0.02)


def test_churn_support():
    tr = traces.churn(_names(), 10_000.0, departures={"A": 3000.0},
                      arrivals={"B": 4000.0})
    assert tr.scale_at("A", 2999.0) == 1.0 and tr.scale_at("A", 3001.0) == 0.0
    assert tr.scale_at("B", 3999.0) == 0.0 and tr.scale_at("B", 4001.0) == 1.0


def test_random_churn_seeded():
    names = [f"S{i}" for i in range(20)]
    a = traces.random_churn(names, 10_000.0, seed=3)
    b = traces.random_churn(names, 10_000.0, seed=3)
    assert all(np.array_equal(a.scales[n], b.scales[n]) for n in names)
    n_touched = sum(1 for n in names if (a.scales[n] == 0.0).any())
    assert n_touched == 4                    # 10% depart + 10% arrive


# ---------------------------------------------------------------------------
# Arrival generation
# ---------------------------------------------------------------------------

def _gen(tr, name, rate, horizon, poisson, seed=0):
    e, s = tr.segments(name, horizon)
    return traces.gen_arrivals(rate, e, s, horizon, poisson,
                               np.random.default_rng(seed))


def test_deterministic_flat_trace_is_evenly_spaced():
    h, rate = 10_000.0, 80.0
    tr = traces.constant(["A"], h)
    arr = _gen(tr, "A", rate, h, poisson=False)
    assert abs(arr.size - rate * h / 1000.0) <= 1
    gaps = np.diff(arr)
    np.testing.assert_allclose(gaps, 1000.0 / rate, rtol=1e-9)
    assert (arr >= 0).all() and (arr < h).all()


def test_deterministic_counts_follow_rate_integral():
    h, rate = 10_000.0, 120.0
    for tr in (traces.diurnal(["A"], h, peak=2.0),
               traces.step_spike(["A"], h, at_ms=2000.0, duration_ms=3000.0,
                                 scale=3.0)):
        arr = _gen(tr, "A", rate, h, poisson=False)
        expected = rate * tr.mean_scale("A", h) * h / 1000.0
        assert abs(arr.size - expected) <= 1.5
        assert (np.diff(arr) > 0).all()


def test_zero_rate_segments_produce_no_arrivals():
    h = 10_000.0
    tr = traces.churn(["A", "B"], h, departures={"A": 3000.0},
                      arrivals={"B": 4000.0})
    for poisson in (False, True):
        a = _gen(tr, "A", 100.0, h, poisson)
        b = _gen(tr, "B", 100.0, h, poisson)
        assert a.size > 0 and (a < 3000.0).all()
        assert b.size > 0 and (b >= 4000.0).all()
    # fully-zero trace
    tr0 = traces.constant(["A"], h, scale=0.0)
    assert _gen(tr0, "A", 100.0, h, False).size == 0
    assert _gen(tr0, "A", 100.0, h, True).size == 0


def test_poisson_thinning_matches_expectation():
    h, rate = 20_000.0, 150.0
    tr = traces.diurnal(["A"], h, peak=2.0)
    lam = rate * tr.mean_scale("A", h) * h / 1000.0
    counts = [_gen(tr, "A", rate, h, True, seed=s).size for s in range(6)]
    for c in counts:
        assert abs(c - lam) < 5.0 * math.sqrt(lam)
    assert len(set(counts)) > 1              # seeds actually differ
    # per-segment intensity tracks the scale: spike window ~2x the base
    tr2 = traces.step_spike(["A"], h, at_ms=5000.0, duration_ms=5000.0,
                            scale=2.0)
    arr = _gen(tr2, "A", rate, h, True, seed=1)
    n_spike = ((arr >= 5000.0) & (arr < 10_000.0)).sum()
    n_base = (arr < 5000.0).sum()
    assert 1.5 < n_spike / max(n_base, 1) < 2.6


def test_gen_arrivals_deterministic_per_seed():
    h = 5000.0
    tr = traces.diurnal(["A"], h, peak=1.8)
    for poisson in (False, True):
        a = _gen(tr, "A", 90.0, h, poisson, seed=7)
        b = _gen(tr, "A", 90.0, h, poisson, seed=7)
        assert np.array_equal(a, b)
