"""Queueing-aware SLO budget split: t_queue invariants, budget solver
properties, and the never-looser-than-half-split guarantee.

Property tests run under hypothesis when available and skip cleanly on
bare environments (`tests._hypothesis_stub`); the unit tests alongside
them always run and cover the same invariants on fixed grids.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # bare env: property tests skip, unit tests run
    from tests._hypothesis_stub import given, settings, st

from repro.core import provisioner as prov
from repro.core.queueing import (BudgetModel, HALF, QUEUEING, QueueingDelay,
                                 t_queue, resolve)
from repro.core.types import V5E, WorkloadSpec
from tests.test_perf_model import make_coeffs


def _profiles():
    return {
        "light": make_coeffs(k1=0.002, k2=0.4, k3=0.8, k5=0.05),
        "mid": make_coeffs(k1=0.01, k2=2.0, k3=3.0),
        "heavy": make_coeffs(k1=0.02, k2=5.0, k3=8.0, k5=0.3),
    }


# ---------------------------------------------------------------------------
# t_queue invariants
# ---------------------------------------------------------------------------

def test_t_queue_zero_at_b1_zero_burst():
    """A non-batching server under deterministic (zero-burst) arrivals
    queues not at all while stable."""
    qd = t_queue(1, 100.0, 5.0, burstiness=0.0)
    assert qd.expected == 0.0
    assert qd.tail == 0.0
    assert qd.t_acc_mean == 0.0 and qd.t_acc_tail == 0.0


def test_t_queue_monotone_in_batch_at_fixed_utilization():
    """For fixed arrival rate and utilization (service time scaling with
    the batch, as the physical t_inf(b) does), a larger configured batch
    never shortens the wait: accumulation grows linearly while the
    utilization term stays constant."""
    for rate in (30.0, 120.0, 400.0):
        for rho in (0.2, 0.5, 0.9):
            r_ms = rate / 1000.0
            prev = None
            for b in range(1, 65):
                qd = t_queue(b, rate, rho * b / r_ms)
                assert abs(qd.rho - rho) < 1e-9
                if prev is not None:
                    assert qd.expected >= prev.expected - 1e-12, (rate, rho, b)
                    assert qd.tail >= prev.tail - 1e-12, (rate, rho, b)
                prev = qd


def test_t_queue_monotone_in_utilization():
    """For fixed (b, R), longer service (higher utilization) never
    shortens the wait; the wait diverges as rho -> 1."""
    b, rate = 8, 200.0
    prev = 0.0
    for frac in np.linspace(0.05, 0.95, 19):
        t_inf = frac * b / (rate / 1000.0)     # rho == frac
        qd = t_queue(b, rate, t_inf)
        assert abs(qd.rho - frac) < 1e-9
        assert qd.expected >= prev - 1e-12
        prev = qd.expected
    assert math.isinf(t_queue(b, rate, 1.01 * b / (rate / 1000.0)).tail)


def test_t_queue_zero_rate_never_queues():
    """rate_rps=0 (no arrivals) must yield zero delay — not a division
    error — in the scalar model, the scalar solver, and the batched
    solver alike, and a zero-rate workload must provision end-to-end."""
    for b in (1, 4, 64):
        qd = t_queue(b, 0.0, 50.0)
        assert qd.expected == 0.0 and qd.tail == 0.0 and qd.rho == 0.0
    assert QUEUEING.budget_ms(100.0, 0.0, 8) == 50.0    # cap at T_slo/2
    vec = QUEUEING.budget_ms_vec(np.array([100.0]), np.array([0.0]),
                                 np.array([8.0]))
    assert vec[0] == QUEUEING.budget_ms(100.0, 0.0, 8)
    plan = prov.provision([WorkloadSpec("Z", "mid", 150.0, 0.0)],
                          _profiles(), V5E)
    assert len(plan.placements) == 1 and plan.placements[0].r > 0


def test_t_queue_tail_dominates_mean():
    for b in (1, 4, 16, 64):
        qd = t_queue(b, 150.0, 10.0, quantile=0.99)
        assert qd.tail >= qd.expected - 1e-12
        assert qd.t_util_tail >= qd.t_util_mean - 1e-12


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 64), rate=st.floats(1.0, 500.0),
       rho=st.floats(0.01, 0.95))
def test_t_queue_properties_randomized(b, rate, rho):
    r_ms = rate / 1000.0
    qd = t_queue(b, rate, rho * b / r_ms)
    assert qd.t_acc_mean >= 0 and qd.t_util_mean >= 0
    assert qd.tail >= qd.expected - 1e-12
    # one extra unit of batch never helps (fixed R and utilization)
    qd2 = t_queue(b + 1, rate, rho * (b + 1) / r_ms)
    assert qd2.tail >= qd.tail - 1e-9
    assert qd2.expected >= qd.expected - 1e-9


# ---------------------------------------------------------------------------
# Budget solver
# ---------------------------------------------------------------------------

def test_budget_half_mode_is_exact_half():
    for slo in (60.0, 100.0, 237.5):
        assert HALF.budget_ms(slo, 123.0, 7) == slo / 2.0


def test_budget_never_exceeds_half_split():
    """The queueing-aware budget is capped at T_slo/2: allocations are
    never looser than the paper's split."""
    for slo in (60.0, 120.0, 240.0):
        for rate in (10.0, 60.0, 250.0):
            for b in (1, 4, 16, 64):
                B = QUEUEING.budget_ms(slo, rate, b)
                assert 0.0 <= B <= slo / 2.0 + 1e-12


def test_budget_solution_satisfies_slo_equation():
    """B + t_queue_tail(b, R, B) + slack <= T_slo at the solution (when
    the T_slo/2 cap is not binding)."""
    bm = QUEUEING
    for slo, rate, b in [(90.0, 250.0, 16), (240.0, 60.0, 7),
                         (60.0, 120.0, 3), (150.0, 300.0, 20)]:
        B = bm.budget_ms(slo, rate, b)
        assert B > 0
        tail = t_queue(b, rate, B, quantile=bm.quantile,
                       burstiness=bm.burstiness).tail
        assert B + tail <= slo * (1.0 - bm.slack_frac) + 1e-6
        if B < slo / 2.0 - 1e-9:       # cap not binding: solution is tight
            B2 = min(B * 1.05, slo)
            tail2 = t_queue(b, rate, B2, quantile=bm.quantile,
                            burstiness=bm.burstiness).tail
            assert B2 + tail2 > slo * (1.0 - bm.slack_frac)


def test_budget_vec_matches_scalar_oracle():
    """Batched budget evaluation pinned to the scalar bisection <= 1e-9
    across a randomized (slo, rate, batch) grid."""
    rng = np.random.default_rng(0)
    slo = rng.uniform(40.0, 400.0, size=200)
    rate = rng.uniform(5.0, 500.0, size=200)
    b = rng.integers(1, 65, size=200).astype(float)
    for bm in (QUEUEING, HALF,
               BudgetModel(mode="queueing", quantile=0.9, slack_frac=0.1)):
        vec = bm.budget_ms_vec(slo, rate, b)
        ref = np.array([bm.budget_ms(s, r, int(k))
                        for s, r, k in zip(slo, rate, b)])
        np.testing.assert_allclose(vec, ref, rtol=1e-9, atol=1e-9)


def test_resolve_api():
    assert resolve("half") is HALF
    assert resolve("queueing") is QUEUEING
    bm = BudgetModel(quantile=0.9)
    assert resolve(bm) is bm
    with pytest.raises(ValueError):
        resolve("thirds")
    with pytest.raises(ValueError):
        BudgetModel(mode="quarters")


# ---------------------------------------------------------------------------
# Theorem 1 under the queueing budget: never looser than the half split
# ---------------------------------------------------------------------------

def test_theorem1_never_looser_than_half_split():
    """For every spec where both modes are feasible, the queueing-aware
    (b, r_lower) allocates at least as much as the half split: the batch
    matches Eq. 17 and r_lower never shrinks."""
    profiles = _profiles()
    rng = np.random.default_rng(1)
    checked = 0
    for _ in range(200):
        m = str(rng.choice(["light", "mid", "heavy"]))
        s = WorkloadSpec("W", m, float(rng.uniform(60.0, 400.0)),
                         float(rng.uniform(5.0, 300.0)))
        c = profiles[m]
        try:
            b_h = prov.appropriate_batch(s, c, V5E, budget="half")
            r_h = prov.resource_lower_bound(s, c, V5E, b_h, budget="half")
        except prov.InfeasibleError:
            continue
        b_q = prov.appropriate_batch(s, c, V5E, budget="queueing")
        r_q = prov.resource_lower_bound(s, c, V5E, b_q, budget="queueing")
        assert b_q <= b_h               # only the degenerate-budget shrink
        if b_q == b_h:
            assert r_q >= r_h - 1e-12, (s.slo_ms, s.rate_rps, b_q)
        checked += 1
    assert checked > 50


def test_queueing_infeasible_clamps_to_full_device():
    """A spec whose TIGHTENED budget is unreachable on a full device is
    clamped to R_MAX (honest residual) instead of raising, as long as
    the half split is feasible; a spec infeasible even at T_slo/2 still
    raises in both modes."""
    profiles = _profiles()
    c = profiles["heavy"]
    clamped = None
    for rate in np.arange(20.0, 400.0, 5.0):
        s = WorkloadSpec("W", "heavy", 80.0, float(rate))
        try:
            b = prov.appropriate_batch(s, c, V5E, budget="half")
            r_h = prov.resource_lower_bound(s, c, V5E, b, budget="half")
        except prov.InfeasibleError:
            continue
        r_q = prov.resource_lower_bound(s, c, V5E, b, budget="queueing")
        if r_q == prov.R_MAX and r_h < prov.R_MAX:
            clamped = (s, b)
            break
    assert clamped is not None, "expected a clamped spec in the sweep"
    # infeasible even at T_slo/2 raises identically in both modes
    s_bad = WorkloadSpec("X", "heavy", 1.0, 10.0)
    for budget in ("half", "queueing"):
        with pytest.raises(prov.InfeasibleError):
            prov.resource_lower_bound(s_bad, c, V5E, 8, budget=budget)


@settings(max_examples=40, deadline=None)
@given(slo=st.floats(60.0, 400.0), rate=st.floats(5.0, 300.0),
       model=st.sampled_from(["light", "mid", "heavy"]))
def test_never_looser_randomized(slo, rate, model):
    profiles = _profiles()
    s = WorkloadSpec("W", model, slo, rate)
    c = profiles[model]
    try:
        b = prov.appropriate_batch(s, c, V5E, budget="half")
        r_h = prov.resource_lower_bound(s, c, V5E, b, budget="half")
    except prov.InfeasibleError:
        return
    b_q = prov.appropriate_batch(s, c, V5E, budget="queueing")
    if b_q == b:
        r_q = prov.resource_lower_bound(s, c, V5E, b_q, budget="queueing")
        assert r_q >= r_h - 1e-12
