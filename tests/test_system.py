"""End-to-end behaviour tests for the paper's system (replaces scaffold)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.experiments import fitted_context
from repro.core import provisioner as prov
from repro.core.types import WorkloadSpec
from repro.profiling.metrics import forward_flops, kernel_count, serving_models
from repro.configs import ASSIGNED, REGISTRY, get_config
from repro.launch.shapes import SHAPES, applicable, effective_config


def test_paper_table1_analogue():
    """Sec. 2.3 illustrative example: iGniter hosts the 3-workload set on
    few devices with all SLOs predicted met."""
    from repro.serving.workload import three_workloads
    ctx = fitted_context()
    plan = prov.provision(three_workloads(), ctx.profiles, ctx.hw)
    assert plan.n_gpus <= 3
    metrics = prov.predicted_plan_metrics(plan, ctx.profiles, ctx.hw)
    for p in plan.placements:
        assert metrics[p.workload.name].t_inf <= p.workload.slo_ms / 2 + 1e-6


def test_runtime_overhead_paper_claim():
    """Sec. 5.4: Alg. 1 runs in seconds even for hundreds of workloads."""
    import time
    ctx = fitted_context()
    rng = np.random.default_rng(0)
    mods = list(ctx.profiles)
    specs = [WorkloadSpec(f"W{i}", mods[i % len(mods)],
                          float(rng.uniform(120, 400)),
                          float(rng.uniform(5, 40)))
             for i in range(100)]
    t0 = time.time()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    dt = time.time() - t0
    assert dt < 30.0                    # paper: 4.61 s at m=1000 (C++ server)
    assert plan.n_gpus >= 1


def test_workload_metrics_sane():
    """Analytic FLOPs/bytes against configuration arithmetic."""
    for name, d in serving_models().items():
        cfg = get_config(d.arch)
        # flops within sane multiple of 2*N*prompt
        lo = 1.5 * cfg.n_active_params() * d.prompt_len
        hi = 40 * cfg.n_active_params() * d.prompt_len
        assert lo <= d.flops_per_item <= hi, name
        assert d.n_kernels == kernel_count(cfg)
        assert d.weight_bytes == 2.0 * cfg.n_active_params()


def test_all_arch_shape_applicability_table():
    """DESIGN.md skip table: exactly the subquadratic archs run long_500k."""
    runs = {a for a in ASSIGNED if applicable(a, "long_500k")}
    assert runs == {"rwkv6-1.6b", "zamba2-2.7b", "qwen3-4b", "mixtral-8x22b"}
    for a in ASSIGNED:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(a, s)


def test_effective_config_long_context():
    cfg = effective_config("qwen3-4b", "long_500k")
    assert cfg.sliding_window == 4096          # beyond-paper SWA variant
    cfg = effective_config("zamba2-2.7b", "long_500k")
    assert cfg.sliding_window == 4096          # shared-attn block windowed
    cfg = effective_config("mixtral-8x22b", "decode_32k")
    assert cfg.sliding_window == 4096          # native


def test_n_params_analytic_matches_init():
    """Config-level parameter arithmetic vs actual initialized trees."""
    import jax
    from repro.configs import reduced
    from repro.models.zoo import build_model
    for arch in ("yi-6b", "mixtral-8x22b", "rwkv6-1.6b", "zamba2-2.7b"):
        cfg = reduced(REGISTRY[arch])
        model = build_model(cfg)
        n_actual = sum(x.size for x in jax.tree.leaves(
            model.abstract_params()))
        n_analytic = cfg.n_params()
        assert abs(n_actual - n_analytic) / n_actual < 0.25, (
            arch, n_actual, n_analytic)
