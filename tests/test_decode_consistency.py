"""Decode path correctness: prefill(S) + decode(token S) must equal the
teacher-forced forward over S+1 tokens — per architecture, including the
SWA rolling-buffer cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, reduced
from repro.models.zoo import build_model

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only


def _full_logits(model, cfg, params, batch, pos):
    hidden, _ = model.forward(params, batch)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["w"].T)
    return hidden[:, pos, :].astype(jnp.float32) @ table.T.astype(jnp.float32)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = reduced(REGISTRY[arch])
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=8.0)   # dropless regime
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    S = 12
    batch = model.make_train_batch(key, 2, S + 1)
    full = _full_logits(model, cfg, params, batch, S)
    pb = {k: (v[:, :S] if k in ("tokens", "labels") else v)
          for k, v in batch.items()}
    cache = model.init_cache(2, 64, dtype=jnp.float32)
    _, cache = model.prefill(params, pb, cache)
    lg, _ = model.decode_step(params, batch["tokens"][:, S:S + 1], cache)
    err = float(jnp.max(jnp.abs(lg[:, 0, :] - full)))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert err / scale < 1e-4, (arch, err)


def test_rolling_swa_cache_long_decode():
    """Rolling SWA buffer: decode far past the window must equal the
    teacher-forced forward with windowed attention."""
    cfg = reduced(REGISTRY["mixtral-8x22b"]).replace(
        capacity_factor=8.0, sliding_window=8)
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    S = 24                                          # 3x the window
    batch = model.make_train_batch(key, 2, S + 1)
    full = _full_logits(model, cfg, params, batch, S)
    pb = {k: v[:, :S] for k, v in batch.items()}
    cache = model.init_cache(2, 64, dtype=jnp.float32)
    # buffer is capped at the window
    assert cache["layers"].k.shape[3] == 8   # (L,B,KV,S,hd) heads-major
    _, cache = model.prefill(params, pb, cache)
    lg, _ = model.decode_step(params, batch["tokens"][:, S:S + 1], cache)
    err = float(jnp.max(jnp.abs(lg[:, 0, :] - full)))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert err / scale < 1e-4, err


def test_greedy_generation_consistency():
    """Multi-step greedy decode == repeated teacher-forced forward."""
    cfg = reduced(REGISTRY["qwen3-4b"])
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    S, n_gen = 8, 4
    batch = model.make_train_batch(key, 1, S)
    pb = {"tokens": batch["tokens"]}
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    logits, cache = model.prefill(params, pb, cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n_gen - 1):
        lg, cache = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0, -1])))

    # teacher-forced reference
    ref_tokens = batch["tokens"]
    ref = []
    for i in range(n_gen):
        hidden, _ = model.forward(params, {"tokens": ref_tokens})
        table = params["head"]["w"].T
        nxt = int(jnp.argmax(
            hidden[0, -1].astype(jnp.float32) @ table.T.astype(jnp.float32)))
        ref.append(nxt)
        ref_tokens = jnp.concatenate(
            [ref_tokens, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    assert toks == ref
