"""Predictive tier (docs/control-plane.md): the trend/seasonal rate
forecaster, forecast-armed Sec. 4.2 shadows, and the transactional
arming paths.

Property tests run under hypothesis when available and skip cleanly on
bare environments (`tests._hypothesis_stub`); every property also has a
plain seed-loop twin alongside so the invariants stay pinned without
hypothesis installed:

  * constant-rate input — deterministic or Poisson, any seed — NEVER
    breaches the forecast band (the no-false-positive contract the
    dynamic_sweep no-drift gate rides on);
  * a linear ramp's forecast is monotone and LEADS the smoothed rate;
  * a periodic series recovers its period within one monitor tick;
  * armed reservations never overcommit a device past r = 1.0;
  * a placement failure mid-edit restores the plan, the vec mirror,
    and the armed shadow book bit-identically (PR 8's checkpoint).
"""
import dataclasses
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # bare env: property tests skip, unit tests run
    from tests._hypothesis_stub import given, settings, st

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.serving.controller import (ArrivalEstimator, ControllerConfig,
                                      PlanState, Reconciler)
from repro.serving.workload import twelve_workloads

WINDOW_MS = 1000.0
FC = ControllerConfig(forecast=True)


def _poisson_window(rng, rate_rps, window_ms=WINDOW_MS, t0=0.0):
    n = rng.poisson(rate_rps * window_ms / 1000.0)
    return t0 + np.sort(rng.uniform(0.0, window_ms, size=n))


def _det_window(rate_rps, window_ms=WINDOW_MS, t0=0.0):
    period = 1000.0 / max(rate_rps, 1e-9)
    return t0 + np.arange(period / 2.0, window_ms, period)


def _breach(est, plan_rate, cfg=FC):
    """The exact trigger `Reconciler._forecast_pass` evaluates."""
    f = est.forecast_rps(cfg.forecast_horizon)
    band = max(cfg.forecast_band,
               cfg.forecast_sigmas * est.rate_sigma() / plan_rate)
    return f / plan_rate > 1.0 + band


@pytest.fixture(scope="module")
def ctx12():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    return ctx, plan


def _estimators(plan, cfg=None):
    return {p.workload.name: ArrivalEstimator(p.workload.rate_rps, cfg)
            for p in plan.placements}


# ---------------------------------------------------------------------------
# Never-fires: constant-rate input stays forecast-silent
# ---------------------------------------------------------------------------

def test_constant_deterministic_never_breaches():
    for rate in (8.0, 30.0, 60.0, 250.0):
        est = ArrivalEstimator(rate, FC)
        for k in range(40):
            est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
            assert not _breach(est, rate), (rate, k)


def test_constant_poisson_never_breaches_seeds():
    """Seed-loop twin of the property below: 5 rates x 20 seeds x 50
    ticks of pure counting noise, not one band breach."""
    for rate in (5.0, 20.0, 60.0, 120.0, 300.0):
        for seed in range(20):
            rng = np.random.default_rng(seed)
            est = ArrivalEstimator(rate, FC)
            for k in range(50):
                est.observe(_poisson_window(rng, rate, t0=k * WINDOW_MS),
                            WINDOW_MS)
                assert not _breach(est, rate), (rate, seed, k)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(3.0, 400.0))
def test_constant_poisson_never_breaches_property(seed, rate):
    rng = np.random.default_rng(seed)
    est = ArrivalEstimator(rate, FC)
    for k in range(40):
        est.observe(_poisson_window(rng, rate, t0=k * WINDOW_MS),
                    WINDOW_MS)
        assert not _breach(est, rate), (seed, rate, k)


def test_forecast_reconciler_noop_on_poisson(ctx12):
    """Closed over the real reconciler: forecast=True + noise-only input
    never reconfigures, never arms, and leaves the plan object itself
    untouched (the dynamic_sweep forecast no-drift gate)."""
    ctx, plan = ctx12
    for seed in range(3):
        rng = np.random.default_rng(seed)
        rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=FC)
        ests = _estimators(plan, FC)
        for k in range(25):
            for name, est in ests.items():
                rate = rec.targets[name].rate_rps
                est.observe(_poisson_window(rng, rate, t0=k * WINDOW_MS),
                            WINDOW_MS)
            assert not rec.reconcile(k + 1.0, ests)
        assert rec.edits == [] and rec.armed == {} and rec.plan is plan


# ---------------------------------------------------------------------------
# Ramp: monotone extrapolation that leads the smoothed rate
# ---------------------------------------------------------------------------

def _ramp_forecasts(rate0, slope_frac, n=20):
    est = ArrivalEstimator(rate0, FC)
    out = []
    for k in range(n):
        rate = rate0 * (1.0 + slope_frac * k)
        est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
        out.append((est.forecast_rps(FC.forecast_horizon), est.rate_rps))
    return out


def test_linear_ramp_forecast_monotone_and_leads():
    for slope in (0.02, 0.05, 0.10):
        hist = _ramp_forecasts(60.0, slope)
        f = [x[0] for x in hist]
        # monotone after the EWMA warm-up, and always >= smoothed rate
        assert all(b >= a - 1e-9 for a, b in zip(f[3:], f[4:])), slope
        assert all(fk >= rk for fk, rk in hist), slope
        # the horizon extrapolation actually LEADS: by mid-ramp the
        # forecast exceeds the current true rate
        assert f[10] > 60.0 * (1.0 + slope * 10)


@settings(max_examples=40, deadline=None)
@given(st.floats(0.01, 0.15), st.floats(20.0, 200.0))
def test_linear_ramp_forecast_monotone_property(slope, rate0):
    hist = _ramp_forecasts(rate0, slope)
    f = [x[0] for x in hist]
    assert all(b >= a - 1e-9 for a, b in zip(f[3:], f[4:]))
    assert all(fk >= rk - 1e-9 for fk, rk in hist)


# ---------------------------------------------------------------------------
# Periodicity: autocorrelation period scan
# ---------------------------------------------------------------------------

def _periodic_estimator(period, n=64, base=100.0, amp=0.8, noise_seed=None):
    est = ArrivalEstimator(base, FC)
    rng = (np.random.default_rng(noise_seed)
           if noise_seed is not None else None)
    for k in range(n):
        rate = base * (1.0 + amp * math.sin(2.0 * math.pi * k / period))
        w = (_poisson_window(rng, rate, t0=k * WINDOW_MS) if rng is not None
             else _det_window(rate, t0=k * WINDOW_MS))
        est.observe(w, WINDOW_MS)
    return est


def test_periodic_series_recovers_period_within_one_tick():
    for period in (6, 10, 16):
        est = _periodic_estimator(period)
        got = est.detect_period()
        assert got is not None and abs(got - period) <= 1, (period, got)


def test_periodic_series_recovers_period_under_noise():
    for period in (8, 12):
        est = _periodic_estimator(period, noise_seed=0)
        got = est.detect_period()
        assert got is not None and abs(got - period) <= 1, (period, got)


def test_constant_poisson_detects_no_period():
    for seed in range(10):
        rng = np.random.default_rng(seed)
        est = ArrivalEstimator(80.0, FC)
        for k in range(64):
            est.observe(_poisson_window(rng, 80.0, t0=k * WINDOW_MS),
                        WINDOW_MS)
        assert est.detect_period() is None, seed


@settings(max_examples=25, deadline=None)
@given(st.integers(5, 20))
def test_periodic_recovery_property(period):
    est = _periodic_estimator(period)
    got = est.detect_period()
    assert got is not None and abs(got - period) <= 1


def test_seasonal_lookup_raises_forecast_before_peak():
    """One period of history behind the horizon: the forecast at the
    trough's leading edge must already see next cycle's peak."""
    period = 10
    est = _periodic_estimator(period, n=35)
    # history ends at k=34 (sin phase 0.4 of cycle); the seasonal lookup
    # one period back at t+horizon covers the coming rise
    f = est.forecast_rps(FC.forecast_horizon)
    assert est.detect_period() is not None
    assert f >= est.rate_rps


# ---------------------------------------------------------------------------
# Spike: the reconciler fires, arms shadows, and never overcommits
# ---------------------------------------------------------------------------

def _drive_spike(rec, ests, scale=2.5, warm=4, hot=3):
    k = 0
    for _ in range(warm):
        for name, est in ests.items():
            est.observe(_det_window(rec.targets[name].rate_rps,
                                    t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
        k += 1
    for _ in range(hot):
        for name, est in ests.items():
            est.observe(_det_window(rec.targets[name].rate_rps * scale,
                                    t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
        k += 1
    return k


def _assert_no_overcommit(rec):
    """Plan r + armed reservations <= 1.0 on every device, exactly."""
    by_gpu = {}
    gpu_of = {}
    for p in rec.plan.placements:
        by_gpu.setdefault(p.gpu, []).append(p.r)
        gpu_of[p.workload.name] = p.gpu
    for name, sr in rec.armed.items():
        assert name in gpu_of, f"armed orphan {name}"
        by_gpu[gpu_of[name]].append(sr)
    for gpu, rs in by_gpu.items():
        assert math.fsum(rs) <= 1.0 + 1e-9, (gpu, rs)


def test_spike_fires_forecast_and_arms_shadows(ctx12):
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=FC)
    ests = _estimators(plan, FC)
    _drive_spike(rec, ests)
    actions = {e.action for e in rec.edits}
    assert "forecast" in actions
    assert "shadow_arm" in actions
    assert rec.armed
    _assert_no_overcommit(rec)
    # the reservation book and the vec mirror share one dict BY
    # REFERENCE — placement feasibility sees every armed share
    assert rec._state is None or rec._state.shadow is rec.armed


def test_shadow_reservation_capped_by_free_share(ctx12):
    """Every granted reservation is at most shadow_extra and at most
    the device's free share at grant time."""
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=FC)
    ests = _estimators(plan, FC)
    _drive_spike(rec, ests)
    assert rec.armed
    for name, sr in rec.armed.items():
        assert 0.0 < sr <= FC.shadow_extra + 1e-12, (name, sr)


def test_disarm_after_hold_releases_reservations(ctx12):
    """Breach-free for forecast_hold ticks with no ACTIVE shadow: the
    book empties and a shadow_disarm edit records the release."""
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=FC)
    ests = _estimators(plan, FC)
    k = _drive_spike(rec, ests)
    assert rec.armed
    # back inside the (raised) targets: hold ticks of in-band traffic
    for _ in range(FC.forecast_hold + 2):
        for name, est in ests.items():
            est.observe(_det_window(rec.targets[name].rate_rps,
                                    t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
        k += 1
    assert rec.armed == {}
    assert any(e.action == "shadow_disarm" for e in rec.edits)


# ---------------------------------------------------------------------------
# Transactional arming (satellite: PR 8 checkpoint covers the armed book)
# ---------------------------------------------------------------------------

def _plan_key(plan):
    return sorted((p.workload.name, p.gpu, p.r, p.batch)
                  for p in plan.placements)


def test_failed_edit_restores_plan_mirror_and_armed(ctx12, monkeypatch):
    """Inject a placement failure MID-edit, after `_resize_spec` has
    already dropped the workload's reservation: the checkpoint must
    hand back the plan, the rebuilt vec mirror, AND the armed book
    bit-identically (same dict object, same contents)."""
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=FC)
    ests = _estimators(plan, FC)
    _drive_spike(rec, ests)
    assert rec.armed
    from repro.core import replication
    base = sorted(rec.armed)[0].split(replication.SEP)[0]
    est = ests[base]

    plan_before = _plan_key(rec.plan)
    armed_before = dict(rec.armed)
    armed_dict = rec.armed

    calls = {"n": 0}
    real_resize, real_remove = PlanState.resize, PlanState.remove

    # fail whichever op the edit takes first — a same-membership edit
    # goes through resize, a re-split through remove; both fire AFTER
    # `_resize_spec` / `_remove_name` dropped the armed reservation
    def failing_resize(self, spec, **kw):
        calls["n"] += 1
        raise prov.DeviceCapError(spec.name)

    def failing_remove(self, name, **kw):
        calls["n"] += 1
        raise prov.DeviceCapError(name)

    monkeypatch.setattr(PlanState, "resize", failing_resize)
    monkeypatch.setattr(PlanState, "remove", failing_remove)
    changed = rec._forecast_act(99.0, base, est,
                                est.rate_rps * 1.2,
                                backlog=0.0)
    monkeypatch.setattr(PlanState, "resize", real_resize)
    monkeypatch.setattr(PlanState, "remove", real_remove)

    assert calls["n"] >= 1, "injection never reached the edit path"
    # the pre-size failed; re-arming the unchanged group is a no-op, so
    # nothing changed at all
    assert changed is False
    assert _plan_key(rec.plan) == plan_before
    assert rec.armed == armed_before
    assert rec.armed is armed_dict          # identity preserved
    if rec._state is not None:
        assert _plan_key(rec._state.to_plan()) == plan_before
        assert rec._state.shadow is rec.armed
    assert not any(e.action == "forecast" and e.t_s == 99.0
                   for e in rec.edits)
