"""Shadow-instance failover as a pinned tier-1 behavior (paper Sec. 4.2,
Fig. 17) — promoted from examples/shadow_failover.py.

Deliberately under-provision one workload (a simulated performance-
prediction error), simulate with ``shadow=True``, and require the
monitor to activate the pre-launched shadow process: the victim's
shadow flag flips in the timeline and the post-activation tail comes
back down from the un-provisioned peak.
"""
import pytest

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.serving.simulator import simulate_plan
from repro.serving.workload import models, specs_by_name, twelve_workloads


@pytest.fixture(scope="module")
def setup():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    # inject a prediction error: shave half of W1's resource grant
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    return ctx, plan


def test_shadow_failover_engages_and_recovers(setup):
    ctx, plan = setup
    res = simulate_plan(plan, models(), ctx.hw, duration_s=20.0,
                        shadow=True, record_timeline=True)
    m = res.per_workload["W1"]
    assert m["shadow_used"], "shadow failover should have triggered"

    tl = [t for t in res.timeline if t["workload"] == "W1"]
    flips = [t["t_s"] for t in tl if t["shadow"]]
    assert flips, "timeline never shows the shadow active"
    t_on = flips[0]
    # activation is monitor-driven: within a few 1 s windows of start
    assert t_on <= 5.0

    # post-activation recovery: the worst 1 s window p99 after the
    # shadow engages (plus a settle window) is far below the worst
    # window of the violating ramp before it
    pre = max(t["p99_1s"] for t in tl if t["t_s"] <= t_on)
    post = [t["p99_1s"] for t in tl if t["t_s"] >= t_on + 2.0]
    assert post and max(post) < pre

    # the tail end meets the SLO again
    slo = specs_by_name()["W1"].slo_ms
    tail = [t["p99_1s"] for t in tl if t["t_s"] >= 15.0]
    assert tail and max(tail) <= slo


def test_shadow_off_keeps_violating(setup):
    """Control: without shadow=True the same under-provisioned plan
    stays in violation — the recovery above is the shadow's doing."""
    ctx, plan = setup
    res = simulate_plan(plan, models(), ctx.hw, duration_s=20.0,
                        record_timeline=True)
    m = res.per_workload["W1"]
    slo = specs_by_name()["W1"].slo_ms
    assert not m.get("shadow_used", False)
    tl = [t for t in res.timeline if t["workload"] == "W1"
          and t["t_s"] >= 15.0]
    assert tl and min(t["p99_1s"] for t in tl) > slo
