"""Data pipeline, optimizer, checkpointing and a short real training run."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, get_config, reduced
from repro.data.pipeline import DocumentSource, PackedBatcher, make_pipeline
from repro.training import checkpoint as ckpt
from repro.training.loop import train
from repro.training.optimizer import AdamW
import pytest

pytestmark = [pytest.mark.jax, pytest.mark.slow]  # full CI tier only


def test_packing_shapes_and_labels():
    src = DocumentSource(vocab_size=512, seed=0)
    b = next(iter(PackedBatcher(iter(src), batch=4, seq=64)))
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    # next-token alignment within the packed stream
    flat_t = b["tokens"].reshape(-1)
    flat_l = b["labels"].reshape(-1)
    assert (flat_t[1:65 - 1] == flat_l[0:63]).mean() > 0.9


def test_pipeline_modality_stubs():
    cfg = reduced(REGISTRY["qwen2-vl-7b"])
    b = next(make_pipeline(cfg, 2, 32))
    assert "patches" in b and b["patches"].shape[0] == 2
    cfg = reduced(REGISTRY["whisper-large-v3"])
    b = next(make_pipeline(cfg, 2, 32))
    assert "frames" in b and b["frames"].shape[1] == cfg.encoder_seq_len


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0,
                grad_clip=None)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 10, tree)
        ckpt.save(d, 20, jax.tree.map(lambda a: a * 2, tree))
        restored, step = ckpt.restore_latest(d, tree)
        assert step == 20
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   2 * np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_short_training_loss_decreases():
    cfg = get_config("qwen3-4b").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, dtype="float32")
    report = train(cfg, steps=60, batch=8, seq=64, log_every=1000,
                   log_fn=lambda s: None)
    first = np.mean(report.losses[:10])
    last = np.mean(report.losses[-10:])
    assert last < first - 0.3, (first, last)
