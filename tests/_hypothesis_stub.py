"""Fallback shim for environments without `hypothesis` installed.

`hypothesis` is declared in requirements.txt / pyproject.toml, but bare
environments (minimal CI images, the accelerator containers) may lack
it.  Importing this module's `given` turns every property test into a
clean `pytest.importorskip`-style skip instead of a collection error,
while the plain unit tests in the same modules keep running.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def wrapper(*args, **kwargs):   # noqa: ARG001 - strategy kwargs
            pytest.importorskip("hypothesis")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategies:
    """Stands in for `hypothesis.strategies`: any strategy constructor
    returns an inert placeholder (the stubbed @given never draws)."""

    def __getattr__(self, name):
        def strategy(*_args, **_kwargs):
            return None
        return strategy


st = _Strategies()
