"""Online control plane: estimators, hysteresis, reconciliation, and the
closed loop against the simulator.

Property tests run under hypothesis when available and skip cleanly on
bare environments (`tests._hypothesis_stub`), mirroring
`test_queueing.py`; the unit tests alongside always run.

The closed-loop tier pins the PR's acceptance behavior at m=100:

  * no-drift runs (deterministic AND Poisson noise-only) perform ZERO
    reconfigurations and leave the plan bit-identical — the controlled
    simulation's latency streams equal the uncontrolled run's exactly;
  * under a 2x diurnal ramp the controlled plan's simulated violations
    come in strictly below the static queueing plan's;
  * a reconfiguring controlled run is byte-identical across simulator
    engines (fresh controllers per engine), including `n_reconfigs`.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # bare env: property tests skip, unit tests run
    from tests._hypothesis_stub import given, settings, st

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.core.queueing import QUEUEING
from repro.serving import traces
from repro.serving.controller import (ArrivalEstimator, Controller,
                                      ControllerConfig, Reconciler)
from repro.serving.simulator import simulate_full, simulate_plan
from repro.serving.workload import models, synthetic_workloads, \
    twelve_workloads

WINDOW_MS = 1000.0


def _poisson_window(rng, rate_rps, window_ms=WINDOW_MS, t0=0.0):
    n = rng.poisson(rate_rps * window_ms / 1000.0)
    return t0 + np.sort(rng.uniform(0.0, window_ms, size=n))


def _det_window(rate_rps, window_ms=WINDOW_MS, t0=0.0):
    period = 1000.0 / rate_rps
    return t0 + np.arange(period / 2.0, window_ms, period)


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------

def test_ewma_rate_converges_on_constant_trace():
    est = ArrivalEstimator(50.0)           # prior far from truth
    for k in range(20):
        est.observe(_det_window(120.0, t0=k * WINDOW_MS), WINDOW_MS)
    assert est.rate_rps == pytest.approx(120.0, rel=0.02)
    assert abs(est.trend_rps) < 2.0
    # burstiness of an evenly spaced stream ~ 0
    assert est.cv2 < 0.05


def test_burstiness_poisson_near_one():
    rng = np.random.default_rng(0)
    est = ArrivalEstimator(200.0)
    for k in range(30):
        est.observe(_poisson_window(rng, 200.0, t0=k * WINDOW_MS),
                    WINDOW_MS)
    assert 0.5 < est.cv2 < 1.8
    assert est.rate_rps == pytest.approx(200.0, rel=0.15)


def test_burstiness_spike_train_much_greater_than_one():
    """Bursts of back-to-back arrivals separated by long silences: the
    CV^2 estimator must see the inter-burst gaps (chained across
    windows) and report >> 1."""
    est = ArrivalEstimator(40.0)
    for k in range(12):
        t0 = k * WINDOW_MS
        burst = t0 + 100.0 + np.arange(40) * 1.0      # 40 reqs in 40 ms
        est.observe(burst, WINDOW_MS)
    assert est.cv2 > 4.0


def test_burstiness_accumulates_for_low_rate_workloads():
    """A 3 rps workload yields fewer than min_gap_obs gaps per window;
    gaps must buffer across windows so cv2 still updates eventually."""
    est = ArrivalEstimator(3.0)
    for k in range(10):
        est.observe(_det_window(3.0, t0=k * WINDOW_MS), WINDOW_MS)
    assert est.n_gaps > 0
    assert est.cv2 < 0.1          # evenly spaced: near-deterministic


def test_estimator_empty_windows_accumulate():
    est = ArrivalEstimator(80.0)
    est.observe(_det_window(80.0), WINDOW_MS)
    assert est.empty_ms == 0.0
    for _ in range(3):
        est.observe(np.empty(0), WINDOW_MS)
    assert est.empty_ms == pytest.approx(3 * WINDOW_MS)
    est.observe(_det_window(80.0, t0=4 * WINDOW_MS), WINDOW_MS)
    assert est.empty_ms == 0.0


@settings(max_examples=30, deadline=None)
@given(rate=st.floats(10.0, 400.0), prior=st.floats(5.0, 500.0))
def test_ewma_convergence_randomized(rate, prior):
    est = ArrivalEstimator(prior)
    for k in range(25):
        est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
    assert est.rate_rps == pytest.approx(rate, rel=0.05)
    assert est.cv2 < 0.1


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(30.0, 300.0), seed=st.integers(0, 50))
def test_burstiness_poisson_randomized(rate, seed):
    rng = np.random.default_rng(seed)
    est = ArrivalEstimator(rate)
    for k in range(30):
        est.observe(_poisson_window(rng, rate, t0=k * WINDOW_MS), WINDOW_MS)
    assert 0.3 < est.cv2 < 2.5


# ---------------------------------------------------------------------------
# Hysteresis / reconciler (no simulator involved)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ctx12():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    return ctx, plan


def _estimators(plan, cfg=None):
    return {p.workload.name: ArrivalEstimator(p.workload.rate_rps, cfg)
            for p in plan.placements}


def test_hysteresis_quiet_on_noise_only_input(ctx12):
    """Poisson windows at the provisioned rates, many ticks, several
    seeds: the reconciler must never fire (oscillation prevention)."""
    ctx, plan = ctx12
    for seed in range(3):
        rng = np.random.default_rng(seed)
        rec = Reconciler(plan, ctx.profiles, ctx.hw)
        ests = _estimators(plan)
        for k in range(25):
            for name, est in ests.items():
                rate = rec.targets[name].rate_rps
                est.observe(_poisson_window(rng, rate, t0=k * WINDOW_MS),
                            WINDOW_MS)
            assert not rec.reconcile((k + 1.0), ests)
        assert rec.edits == [] and rec.plan is plan


def test_reconciler_fires_on_sustained_updrift(ctx12):
    """A sustained up-drift reconfigures: either a same-count resize or
    — when the drifted rate is infeasible even solo on a full device —
    a replica split (the allocated rate shares then sum to the new
    target instead of clamping at r = 1.0)."""
    from repro.core import replication
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw)
    ests = _estimators(plan)
    name = plan.placements[0].workload.name
    base = rec.targets[name].rate_rps
    changed = False
    for k in range(6):
        for n, est in ests.items():
            rate = rec.targets[n].rate_rps * (1.6 if n == name else 1.0)
            est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
        changed |= rec.reconcile(k + 1.0, ests)
    assert changed
    acts = [e for e in rec.edits if e.workload == name]
    assert acts and acts[0].action in ("resize", "split")
    assert rec.targets[name].rate_rps > base * 1.3
    group = replication.group_placements(rec.plan.placements)[name]
    assert len(group) == acts[-1].replicas
    assert sum(p.workload.rate_rps for p in group) == \
        pytest.approx(rec.targets[name].rate_rps)


def test_reconciler_departure_and_rearrival(ctx12):
    ctx, plan = ctx12
    cfg = ControllerConfig()
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=cfg)
    ests = _estimators(plan, cfg)
    name = plan.placements[0].workload.name
    # one active window first (a NEVER-active workload is "not started
    # yet", not departed), then silence long enough to miss >=
    # depart_missed expected arrivals
    for k in range(7):
        for n, est in ests.items():
            if n == name and k > 0:
                est.observe(np.empty(0), WINDOW_MS)
            else:
                est.observe(_det_window(rec.targets[n].rate_rps,
                                        t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
    assert name in rec.departed
    assert all(p.workload.name != name for p in rec.plan.placements)
    assert any(e.action == "remove" and e.workload == name
               for e in rec.edits)
    # traffic resumes: the workload is re-added
    orig_rate = rec.departed[name].rate_rps
    for k in range(7, 13):
        for n, est in ests.items():
            rate = orig_rate if n == name else rec.targets[n].rate_rps
            est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
    assert name not in rec.departed
    # re-added possibly as a replica group (w#0..w#k-1) when the
    # recovered rate + headroom is infeasible for a single instance
    from repro.core import replication
    group = replication.group_placements(rec.plan.placements).get(name)
    assert group, f"{name} not re-added"
    assert sum(p.workload.rate_rps for p in group) == \
        pytest.approx(rec.targets[name].rate_rps)
    assert any(e.action == "add" and e.workload == name for e in rec.edits)


def test_never_active_workload_left_alone(ctx12):
    """A workload with zero traffic FROM THE START keeps its provisioned
    allocation (reclaiming it would manufacture a cold start when the
    traffic begins); silence only counts as departure after activity."""
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw)
    ests = _estimators(plan)
    name = plan.placements[0].workload.name
    for k in range(10):
        for n, est in ests.items():
            if n == name:
                est.observe(np.empty(0), WINDOW_MS)
            else:
                est.observe(_det_window(rec.targets[n].rate_rps,
                                        t0=k * WINDOW_MS), WINDOW_MS)
        assert not rec.reconcile(k + 1.0, ests)
    assert name not in rec.departed
    assert rec.plan is plan


def test_planstate_matches_sequential_provisioner_ops(ctx12):
    """The persistent VecCluster hot path (PlanState) produces the same
    per-workload allocations as applying the plan-in/plan-out
    provisioner ops one by one (entry order inside a device differs —
    irrelevant to the model's symmetric sums — and PlanState may reuse
    an emptied device where the ops would open a fresh one)."""
    import dataclasses
    from repro.serving.controller import PlanState
    ctx, plan = ctx12
    state = PlanState(plan, ctx.profiles, ctx.hw)
    seq = plan
    specs = {p.workload.name: p.workload for p in plan.placements}
    edits = [("resize", "W5", 1.3), ("remove", "W2", None),
             ("resize", "W9", 0.6), ("resize", "W5", 1.1),
             ("add", "W2", 1.2), ("resize", "W11", 1.4)]
    for action, name, factor in edits:
        if action == "remove":
            state.remove(name)
            seq = prov.remove_workload(seq, name)
            continue
        new = dataclasses.replace(specs[name],
                                  rate_rps=specs[name].rate_rps * factor)
        specs[name] = new
        if action == "resize":
            state.resize(new, batch="eq17")
            seq = prov.resize_workload(seq, new, ctx.profiles, ctx.hw)
        else:
            state.add(new, batch="eq17")
            seq = prov.add_workload(seq, new, ctx.profiles, ctx.hw)
    got = {p.workload.name: (round(p.r, 9), p.batch)
           for p in state.to_plan().placements}
    want = {p.workload.name: (round(p.r, 9), p.batch)
            for p in seq.placements}
    assert got == want
    assert state.to_plan().n_gpus <= seq.n_gpus


def test_online_burstiness_floored_at_base(ctx12):
    """A deterministic trace's cv2 ~ 0 must not loosen the budget below
    the provisioned model; a bursty trace tightens it."""
    ctx, plan = ctx12
    rec = Reconciler(plan, ctx.profiles, ctx.hw)
    ests = _estimators(plan)
    name = plan.placements[0].workload.name
    for k in range(6):
        for n, est in ests.items():
            rate = rec.targets[n].rate_rps * (1.6 if n == name else 1.0)
            est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
    assert rec.edits                      # it did reconfigure
    assert rec.bm.burstiness >= QUEUEING.burstiness - 1e-12
    # synthetic bursty estimates push it up, clamped at the ceiling
    for est in ests.values():
        est.cv2 = 6.0
        est.n_gaps = 1000
    for k in range(6, 12):
        for n, est in ests.items():
            rate = rec.targets[n].rate_rps * (1.6 if n == name else 1.0)
            est.observe(_det_window(rate, t0=k * WINDOW_MS), WINDOW_MS)
        rec.reconcile(k + 1.0, ests)
    assert rec.bm.burstiness > 2.0


# ---------------------------------------------------------------------------
# Closed loop against the simulator (m=100 acceptance tier)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def m100():
    ctx = fitted_context()
    specs = synthetic_workloads(100, 0)
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    return ctx, specs, plan, models()


def _violations(res, specs, tr, horizon_ms):
    """SimResult.violations with each spec's rate target replaced by its
    trace-mean expectation (reuses the one violation definition)."""
    import dataclasses
    scaled = {s.name: dataclasses.replace(
        s, rate_rps=s.rate_rps * tr.mean_scale(s.name, horizon_ms))
        for s in specs}
    return res.violations(scaled)


@pytest.mark.parametrize("poisson", [False, True], ids=["det", "poisson"])
def test_no_drift_closed_loop_is_a_noop(m100, poisson):
    """Zero reconfigurations, bit-identical plan, and latency streams
    equal to the uncontrolled run — under both arrival processes."""
    ctx, specs, plan, mods = m100
    tr = traces.constant([s.name for s in specs], 10_000.0)
    ctl = Controller(plan, ctx.profiles, ctx.hw)
    res_c = simulate_full(plan, mods, ctx.hw, duration_s=10.0, trace=tr,
                          poisson=poisson, adjust_fn=ctl,
                          adjust_scope="cluster", adjust_period_s=1.0)
    assert res_c.stats["n_reconfigs"] == 0
    assert ctl.edits == []
    assert ctl.plan is plan               # bit-identical: never replaced
    res_0 = simulate_full(plan, mods, ctx.hw, duration_s=10.0, trace=tr,
                          poisson=poisson)
    for w in res_0.request_latencies:
        assert np.array_equal(res_c.request_latencies[w],
                              res_0.request_latencies[w]), w


def test_diurnal_controlled_beats_static(m100):
    """The PR's headline acceptance: under a 2x diurnal ramp the
    controlled plan's simulated violations come in strictly below the
    static queueing plan's (which degrades badly)."""
    ctx, specs, plan, mods = m100
    H = 10_000.0
    tr = traces.diurnal([s.name for s in specs], H, peak=2.0)
    res_s = simulate_full(plan, mods, ctx.hw, duration_s=10.0, trace=tr)
    ctl = Controller(plan, ctx.profiles, ctx.hw)
    res_c = simulate_full(plan, mods, ctx.hw, duration_s=10.0, trace=tr,
                          adjust_fn=ctl, adjust_scope="cluster",
                          adjust_period_s=1.0)
    v_s = _violations(res_s, specs, tr, H)
    v_c = _violations(res_c, specs, tr, H)
    assert len(v_s) >= 60                 # the static plan degrades
    assert len(v_c) < len(v_s) * 0.75     # the controller recovers most
    assert res_c.stats["n_reconfigs"] > 0
    assert res_c.stats["reconfig_latency_ms"] > 0.0
    # the controller buys capacity: more devices at peak, tracked cost
    assert ctl.plan.n_gpus >= plan.n_gpus


def test_controlled_run_engine_identical(ctx12):
    """A RECONFIGURING controlled run is byte-identical across engines
    (fresh controller per engine; wall-clock stat excluded)."""
    ctx, plan = ctx12
    mods = models()
    names = [s.name for s in twelve_workloads()]
    tr = traces.diurnal(names, 6000.0, peak=2.0)
    results = {}
    for engine in ("scalar", "vec"):
        ctl = Controller(plan, ctx.profiles, ctx.hw)
        results[engine] = (ctl, simulate_plan(
            plan, mods, ctx.hw, duration_s=6.0, trace=tr, adjust_fn=ctl,
            adjust_scope="cluster", adjust_period_s=1.0, engine=engine))
    (ctl_a, a), (ctl_b, b) = results["scalar"], results["vec"]
    assert a.stats["n_reconfigs"] == b.stats["n_reconfigs"] > 0
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload
    assert len(ctl_a.edits) == len(ctl_b.edits)
    for ea, eb in zip(ctl_a.edits, ctl_b.edits):
        assert (ea.t_s, ea.action, ea.workload, ea.rate_to) == \
            (eb.t_s, eb.action, eb.workload, eb.rate_to)


def test_adjust_scope_device_vs_cluster_instance_local(ctx12):
    """An instance-local callback produces identical results under both
    scopes and both engines (the unified contract)."""
    ctx, plan = ctx12
    mods = models()

    def bump(now, insts):
        for inst in insts:
            if inst.completed > 300 and inst.batch < 32:
                inst.batch += 1

    base = None
    for engine in ("scalar", "vec"):
        for scope in ("device", "cluster"):
            res = simulate_plan(plan, mods, ctx.hw, duration_s=4.0,
                                adjust_fn=bump, adjust_period_s=0.7,
                                adjust_scope=scope, engine=engine)
            sig = (res.stats["n_reconfigs"],
                   {w: res.request_latencies[w].tobytes()
                    for w in res.request_latencies})
            if base is None:
                base = sig
            else:
                assert sig == base, (engine, scope)


def test_simulate_rejects_bad_scope(ctx12):
    ctx, plan = ctx12
    with pytest.raises(ValueError):
        simulate_plan(plan, models(), ctx.hw, duration_s=1.0,
                      adjust_scope="rack")


def test_controller_rejects_device_scope(ctx12):
    """Driving the Controller under the default per-device scope would
    corrupt its estimators (zero-width windows); it must fail loudly."""
    ctx, plan = ctx12
    ctl = Controller(plan, ctx.profiles, ctx.hw)
    with pytest.raises(RuntimeError, match="cluster"):
        simulate_plan(plan, models(), ctx.hw, duration_s=3.0,
                      adjust_fn=ctl, adjust_period_s=1.0,
                      adjust_scope="device")


def test_controller_composes_with_shadow_mode(ctx12):
    """The historical Controller <-> shadow=True refusal is gone: the
    controller ADOPTS simulator-armed shadow_r reservations into its
    armed book at the first tick, so every plan edit accounts for them
    and an activation can never overcommit a device."""
    ctx, plan = ctx12
    ctl = Controller(plan, ctx.profiles, ctx.hw)
    res = simulate_plan(plan, models(), ctx.hw, duration_s=3.0,
                        shadow=True, adjust_fn=ctl, adjust_period_s=1.0,
                        adjust_scope="cluster")
    assert res.stats["n_requests"] > 0
    # every _setup-armed reservation is in the book after tick 1
    assert ctl.reconciler.armed  # twelve_workloads leaves free capacity


def test_migration_via_gpu_mutation(ctx12):
    """The adjust hook's gpu mutation (migration) is honored by both
    engines: the instance serves from the new device's co-location
    state and the streams stay engine-identical."""
    ctx, plan = ctx12
    mods = models()
    free_gpu = max(p.gpu for p in plan.placements) + 1
    moved = set()

    def make_fn():
        moved.clear()

        def fn(now, insts):
            for inst in insts:
                if inst.spec.name == "W1" and inst.spec.name not in moved:
                    inst.gpu = free_gpu
                    inst.r = 1.0
                    moved.add(inst.spec.name)
        return fn

    a = simulate_plan(plan, mods, ctx.hw, duration_s=4.0,
                      adjust_fn=make_fn(), adjust_scope="cluster",
                      adjust_period_s=1.0, engine="scalar")
    b = simulate_plan(plan, mods, ctx.hw, duration_s=4.0,
                      adjust_fn=make_fn(), adjust_scope="cluster",
                      adjust_period_s=1.0, engine="vec")
    assert a.stats["n_reconfigs"] == b.stats["n_reconfigs"] == 1
    assert a.per_workload["W1"]["r_final"] == 1.0
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w


def test_recent_arrivals_synced_to_adjust_window(ctx12):
    ctx, plan = ctx12
    mods = models()
    seen = []

    def probe(now, insts):
        for inst in insts:
            if inst.spec.name == "W1":
                seen.append((now, np.array(inst.recent_arrivals)))

    simulate_plan(plan, mods, ctx.hw, duration_s=3.0, adjust_fn=probe,
                  adjust_scope="cluster", adjust_period_s=1.0)
    w1 = next(s for s in twelve_workloads() if s.name == "W1")
    assert len(seen) >= 2
    for now, arr in seen:
        assert arr.size == pytest.approx(w1.rate_rps, rel=0.05)
        assert (arr > (now - 1.0) * 1000.0).all()
        assert (arr <= now * 1000.0).all()
