"""Golden spike fixture: the predictive tier's control-plane event
sequence, pinned byte-for-byte.

`tests/data/telemetry_spike_fixture.jsonl` is a committed
`Telemetry.to_jsonl` log of the canonical forecast-on flash-crowd run —
m=100 synthetic workloads (seed 0), the dynamic_sweep `spike` trace
shape (2.5x step at 40% of a 6 s horizon for 20% of it), Poisson
arrivals, a `ControllerConfig(forecast=True)` controller on the numpy
backend, `Telemetry(retention=600)`.  Regenerate (only on a deliberate
predictive-tier behavior change) by re-running exactly that and
refreshing the pinned constants below:

    from repro.serving.telemetry import Telemetry
    tel = Telemetry(retention=600)
    ctl = Controller(plan, profiles, hw, config=cfg.replace(batch="joint"),
                     cfg=ControllerConfig(forecast=True), telemetry=tel)
    simulate_full(plan, models(), hw, duration_s=6.0, seed=0,
                  poisson=True, trace=step_spike(names, 6000.0,
                  at_ms=2400.0, duration_ms=1200.0, scale=2.5),
                  adjust_fn=ctl, adjust_scope="cluster",
                  adjust_period_s=1.0, telemetry=tel)
    tel.to_jsonl("tests/data/telemetry_spike_fixture.jsonl")

This module is stdlib-only ON PURPOSE (no numpy, no repro import): it
replays the log through `benchmarks.telemetry_report` the way the docs
CI tier does, so the fixture doubles as the renderer's regression input.
A digest mismatch here means the forecast trigger, the arming order, or
the event schema changed — update the fixture AND the constants in the
same PR, deliberately.
"""
import hashlib
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import telemetry_report

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "telemetry_spike_fixture.jsonl")

# the exact (t_s, kind, workload, replicas) sequence of every
# forecast / shadow_arm / shadow_disarm event, in log order
SEQUENCE_SHA256 = \
    "e0ebfdbb91e2e76627ddf5c73c99c9946142243f460dbbb1ee5a076d476bd0e7"
N_FORECAST = 62
N_SHADOW_ARM = 58
N_SHADOW_DISARM = 0
N_RECONFIGS = 182
FORECAST_TICKS = {3.0, 4.0}   # the spike lands at 2.4 s; the monitor
                              # window ending t=3 is the FIRST tick the
                              # rate signal is visible, and the
                              # forecaster acts on it immediately


def _load():
    data = telemetry_report.load(FIXTURE)
    pred = [e for e in data["events"]
            if e["kind"] in ("forecast", "shadow_arm", "shadow_disarm")]
    return data, pred


def test_fixture_is_clean_and_renders():
    data, pred = _load()
    assert telemetry_report.check(data) == []
    assert data["events"] and data["workloads"] and data["drift"]
    html = telemetry_report.render_html(data)
    assert "<svg" in html and "forecast" in html


def test_forecast_event_sequence_pinned():
    _, pred = _load()
    sig = "|".join(f"{e['t_s']}:{e['kind']}:{e['workload']}"
                   f":{e['replicas']}" for e in pred)
    assert hashlib.sha256(sig.encode()).hexdigest() == SEQUENCE_SHA256
    kinds = {}
    for e in pred:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    assert kinds.get("forecast", 0) == N_FORECAST
    assert kinds.get("shadow_arm", 0) == N_SHADOW_ARM
    assert kinds.get("shadow_disarm", 0) == N_SHADOW_DISARM


def test_forecast_events_structurally_sound():
    """Schema-level contracts every predictive event must satisfy,
    independent of the pinned digest."""
    data, pred = _load()
    assert {e["t_s"] for e in pred} == FORECAST_TICKS
    assert all(e["cause"] == "forecast" for e in pred)
    # a pre-size always RAISES the target, and every arm covers >= 1
    # replica
    for e in pred:
        if e["kind"] == "forecast":
            assert e["rate_to"] > e["rate_from"]
        elif e["kind"] == "shadow_arm":
            assert e["replicas"] >= 1
    # arming rides a successful pre-size in this run: every shadow_arm
    # has a same-tick forecast edit for its base
    fc = {(e["t_s"], e["workload"]) for e in pred
          if e["kind"] == "forecast"}
    assert all((e["t_s"], e["workload"]) in fc
               for e in pred if e["kind"] == "shadow_arm")


def test_reconfig_counter_reconciles():
    """The overflow-immune counter in the summary trailer equals the
    ring's reconfig event count — the same reconciliation the sweep's
    --check gate enforces, replayed from the committed artifact."""
    data, _ = _load()
    counters = data["summary"]["counters"]
    assert counters["reconfig_events"] == N_RECONFIGS
    assert counters["events_forecast"] == N_FORECAST
    assert counters["events_shadow_arm"] == N_SHADOW_ARM
