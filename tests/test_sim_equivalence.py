"""Scalar-oracle vs vectorized simulator equivalence.

The vec engine (per-device pass recurrence over cached latency tables)
must reproduce the scalar global-heap event loop EXACTLY: same seed =>
byte-identical per-request latency streams, SimResult metrics, and
monitor timelines — across constant-rate, Poisson, shadow-failover and
adjust_fn (GSLICE-style reactive controller) scenarios.  Per-instance
RNG streams (`default_rng([seed, i, k])`) are what make this possible.
"""
import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core.experiments import fitted_context
from repro.serving import traces
from repro.serving.simulator import (simulate_full, simulate_plan,
                                     simulate_device_sample)
from repro.serving.workload import models, specs_by_name, twelve_workloads


@pytest.fixture(scope="module")
def setup():
    ctx = fitted_context()
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    return ctx, plan, models()


def _adjust(now, insts):
    """Instance-local reactive controller (the contract the vec engine
    documents): grows batch under backlog, nudges r with progress."""
    for inst in insts:
        if len(inst.queue) > 2 * inst.batch and inst.batch < 32:
            inst.batch += 1
        if inst.completed > 400:
            inst.r = min(1.0, round(inst.r + 0.025, 10))


_NAMES = [s.name for s in twelve_workloads()]

SCENARIOS = {
    "constant": {},
    "poisson": {"poisson": True, "seed": 3},
    "shadow": {"shadow": True},
    "adjust": {"adjust_fn": _adjust, "adjust_period_s": 0.7},
    "adjust_cluster": {"adjust_fn": _adjust, "adjust_period_s": 0.7,
                       "adjust_scope": "cluster"},
    "shadow_poisson": {"shadow": True, "poisson": True, "seed": 7},
    "trace_diurnal": {"trace": traces.diurnal(_NAMES, 4000.0, peak=1.8)},
    "trace_spike_poisson": {
        "trace": traces.step_spike(_NAMES, 4000.0, at_ms=1500.0,
                                   duration_ms=1000.0, scale=2.0),
        "poisson": True, "seed": 5},
    "trace_churn_adjust": {
        "trace": traces.churn(_NAMES, 4000.0,
                              departures={"W2": 1800.0},
                              arrivals={"W7": 2200.0}),
        "adjust_fn": _adjust, "adjust_period_s": 0.9,
        "adjust_scope": "cluster"},
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=str)
def test_engines_byte_identical(setup, scenario):
    ctx, plan, mods = setup
    kw = dict(SCENARIOS[scenario])
    if scenario == "shadow":
        # inject a prediction error so the shadow actually flips
        plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
        victim = next(p for p in plan.placements if p.workload.name == "W1")
        victim.r = max(ctx.hw.r_unit,
                       round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    a = simulate_plan(plan, mods, ctx.hw, duration_s=4.0, engine="scalar",
                      record_timeline=True, **kw)
    b = simulate_plan(plan, mods, ctx.hw, duration_s=4.0, engine="vec",
                      record_timeline=True, **kw)
    assert set(a.request_latencies) == set(b.request_latencies)
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload
    assert a.timeline == b.timeline
    assert a.stats["n_passes"] == b.stats["n_passes"]
    assert a.stats["n_requests"] == b.stats["n_requests"]
    assert a.stats["peak_window"] == b.stats["peak_window"]
    assert a.stats["n_reconfigs"] == b.stats["n_reconfigs"]
    for key in ("e2e_p50_ms", "e2e_p99_ms", "wait_mean_ms", "wait_p99_ms"):
        assert a.stats[key] == b.stats[key], key


def test_unknown_engine_rejected(setup):
    ctx, plan, mods = setup
    with pytest.raises(ValueError):
        simulate_plan(plan, mods, ctx.hw, duration_s=1.0, engine="cuda")


@pytest.mark.parametrize("budget", ["half", "queueing"])
def test_engines_byte_identical_per_budget(setup, budget):
    """Plans from BOTH budget splits simulate byte-identically across
    engines (the queueing-aware plan has different allocations/devices,
    so this exercises fresh co-location states)."""
    ctx, _, mods = setup
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw,
                          budget=budget)
    a = simulate_plan(plan, mods, ctx.hw, duration_s=4.0, engine="scalar",
                      poisson=True, seed=11)
    b = simulate_plan(plan, mods, ctx.hw, duration_s=4.0, engine="vec",
                      poisson=True, seed=11)
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload


@pytest.mark.parametrize("engine", ["scalar", "vec"])
def test_monitor_window_bounded(setup, engine):
    """Regression for the unbounded `recent` list: the monitor window
    must stay O(rate x 1s lookback), NOT O(total completed requests)."""
    ctx, plan, mods = setup
    res = simulate_plan(plan, mods, ctx.hw, duration_s=12.0, engine=engine)
    peak = res.stats["peak_window"]
    total = res.stats["n_requests"]
    max_rate = max(s.rate_rps for s in twelve_workloads())
    assert 0 < peak <= 3 * max_rate      # ~1s of the fastest workload
    assert peak < total / 10             # nowhere near the full history


def test_stats_accounting(setup):
    ctx, plan, mods = setup
    res = simulate_plan(plan, mods, ctx.hw, duration_s=3.0)
    st = res.stats
    assert st["n_events"] == st["n_requests"] + st["n_passes"]
    assert st["n_passes"] > 0 and st["events_per_s"] > 0
    served = sum(len(v) for v in res.request_latencies.values())
    assert served == st["n_requests"]    # every arrival eventually served


def test_simulate_full_runs_every_device(setup):
    ctx, plan, mods = setup
    res = simulate_full(plan, mods, ctx.hw, duration_s=2.0)
    assert set(res.per_workload) == {s.name for s in twelve_workloads()}
    assert res.stats["events_per_s"] > 0


def test_device_sample_consistent_with_full(setup):
    """A sampled sub-simulation hosts exactly the sampled devices'
    workloads and produces finite metrics (API kept for spot checks)."""
    ctx, plan, mods = setup
    res, gpus = simulate_device_sample(plan, mods, ctx.hw, max_devices=2,
                                       duration_s=2.0)
    hosted = {p.workload.name for p in plan.placements if p.gpu in set(gpus)}
    assert set(res.per_workload) == hosted
    for m in res.per_workload.values():
        assert np.isfinite(m["p99_ms"])


def _assert_streams_identical(a, b):
    assert set(a.request_latencies) == set(b.request_latencies)
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload
    assert a.stats["n_requests"] == b.stats["n_requests"]
    assert a.stats["n_reconfigs"] == b.stats["n_reconfigs"]


def test_engines_identical_controller_owned_shadows(setup):
    """Uncovered matrix cell: spike trace x Poisson x CONTROLLER-owned
    shadows — the predictive tier arms `inst.shadow_r` itself (no
    `shadow=True` simulator flag), and both engines must honor the
    armed reservation and its monitor-tick activation identically.
    Controllers are stateful: each engine gets a fresh one."""
    from repro.serving.controller import Controller, ControllerConfig
    ctx, plan, mods = setup
    tr = traces.step_spike(_NAMES, 6000.0, at_ms=2400.0,
                           duration_ms=1200.0, scale=2.5)
    res, ctls = {}, {}
    for engine in ("scalar", "vec"):
        ctl = Controller(plan, ctx.profiles, ctx.hw,
                         cfg=ControllerConfig(forecast=True))
        res[engine] = simulate_plan(plan, mods, ctx.hw, duration_s=6.0,
                                    engine=engine, poisson=True, seed=5,
                                    trace=tr, adjust_fn=ctl,
                                    adjust_scope="cluster",
                                    adjust_period_s=1.0)
        ctls[engine] = ctl
    _assert_streams_identical(res["scalar"], res["vec"])
    # the predictive tier actually acted, identically in both runs
    for ctl in ctls.values():
        acts = {e.action for e in ctl.edits}
        assert "forecast" in acts and "shadow_arm" in acts
    assert [(e.t_s, e.action, e.workload, e.replicas)
            for e in ctls["scalar"].edits] \
        == [(e.t_s, e.action, e.workload, e.replicas)
            for e in ctls["vec"].edits]
    assert ctls["scalar"].reconciler.armed == ctls["vec"].reconciler.armed


def test_engines_identical_faults_trace_telemetry(setup):
    """Uncovered matrix cell: device faults x diurnal trace x telemetry
    recorder — byte-identical result streams, fault accounting, and
    telemetry CONTENT (wall-clock fields excepted, engine-tagged
    dispatch counters excepted by design)."""
    from repro.serving import faults
    from repro.serving.telemetry import Telemetry
    ctx, plan, mods = setup
    fs = faults.random_failures(plan.n_gpus, 6000.0, rate_per_min=6.0,
                                mttr_ms=600.0, seed=3)
    tr = traces.diurnal(_NAMES, 6000.0, peak=1.8)
    res, tels = {}, {}
    for engine in ("scalar", "vec"):
        tel = Telemetry()
        res[engine] = simulate_plan(plan, mods, ctx.hw, duration_s=6.0,
                                    engine=engine, poisson=True, seed=9,
                                    trace=tr, faults=fs, telemetry=tel)
        tels[engine] = tel
    _assert_streams_identical(res["scalar"], res["vec"])
    assert res["scalar"].stats["n_failures"] > 0
    for key in ("n_failures", "downtime_ms", "lost_requests"):
        assert res["scalar"].stats[key] == res["vec"].stats[key], key
    ev_s = [dict(e.to_dict(), wall_ms=0.0) for e in tels["scalar"].events]
    ev_v = [dict(e.to_dict(), wall_ms=0.0) for e in tels["vec"].events]
    assert ev_s == ev_v
    assert tels["scalar"].workloads.list() == tels["vec"].workloads.list()
    assert tels["scalar"].devices.list() == tels["vec"].devices.list()
    assert tels["scalar"].drift.list() == tels["vec"].drift.list()


def test_shadow_equivalent_and_recovers(setup):
    """The 12-workload shadow scenario both flips the shadow (Sec. 4.2)
    and stays engine-identical after the table invalidation."""
    ctx, _, mods = setup
    plan = prov.provision(twelve_workloads(), ctx.profiles, ctx.hw)
    victim = next(p for p in plan.placements if p.workload.name == "W1")
    victim.r = max(ctx.hw.r_unit,
                   round(victim.r * 0.5 / ctx.hw.r_unit) * ctx.hw.r_unit)
    a = simulate_plan(plan, mods, ctx.hw, duration_s=8.0, shadow=True,
                      engine="scalar")
    b = simulate_plan(plan, mods, ctx.hw, duration_s=8.0, shadow=True,
                      engine="vec")
    assert a.per_workload["W1"]["shadow_used"]
    assert b.per_workload["W1"]["shadow_used"]
    assert a.per_workload == b.per_workload
