"""Replica groups end to end: naming/share conventions, the plan-layer
split fallback, arrival-stream splitting, replica-merged accounting,
and runtime scale-out — with the scalar engines pinned as oracles.

The satellite guarantees pinned here (docs/provisioning.md,
docs/simulator.md):

  * k=1 replica plans are byte-identical to pre-replication plans, in
    provisioning output AND in both simulator engines' latency streams;
  * rate shares renormalize on replica removal (merge_workload) — the
    survivors' shares always sum to the base workload's rate;
  * merged per-workload p99 equals the percentile of the POOLED request
    stream across replicas, and replica arrival slices exactly
    partition the pooled base stream;
  * a split plan and a runtime-splitting controlled run stay
    byte-identical across the vec engine and the scalar oracle.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import provisioner as prov
from repro.core import replication as repl
from repro.core.experiments import fitted_context
from repro.core.types import WorkloadSpec
from repro.serving import traces
from repro.serving.controller import Controller
from repro.serving.simulator import (_ReplicaRouter, _setup, _split_stream,
                                     simulate_full, simulate_plan)
from repro.serving.workload import models, synthetic_workloads, \
    twelve_workloads


@pytest.fixture(scope="module")
def ctx():
    return fitted_context()


@pytest.fixture(scope="module")
def m100(ctx):
    specs = synthetic_workloads(100, 0)
    return specs, prov.provision(specs, ctx.profiles, ctx.hw,
                                 replicate=True)


# ---------------------------------------------------------------------------
# Conventions: names, shares, grouping
# ---------------------------------------------------------------------------

def test_replica_naming_roundtrip():
    assert repl.base_name("w#3") == "w"
    assert repl.base_name("w") == "w"
    assert repl.replica_index("w#3") == 3
    assert repl.replica_index("w") is None
    assert repl.replica_name("w", 2) == "w#2"
    assert repl.is_replica("w#0") and not repl.is_replica("w")


def test_make_replicas_shares_sum_to_rate():
    s = WorkloadSpec("w", "m", 100.0, 90.0)
    assert repl.make_replicas(s, 1) == [s]       # k=1: the plain spec
    reps = repl.make_replicas(s, 3)
    assert [r.name for r in reps] == ["w#0", "w#1", "w#2"]
    assert sum(r.rate_rps for r in reps) == pytest.approx(90.0)
    with pytest.raises(ValueError):
        repl.make_replicas(reps[0], 2)           # split from the base only
    with pytest.raises(ValueError):
        repl.make_replicas(s, 0)


# ---------------------------------------------------------------------------
# Plan layer: split fallback, edits, renormalization
# ---------------------------------------------------------------------------

def test_k1_plans_byte_identical_to_prereplication(ctx):
    """A workload mix where nothing needs splitting: replicate=True must
    be a no-op bit for bit (plans AND both engines' latency streams)."""
    specs = [s for s in twelve_workloads()
             if prov.required_replicas(s, ctx.profiles[s.model],
                                       ctx.hw) == 1]
    assert len(specs) >= 8               # the mix is mostly feasible
    base = prov.provision(specs, ctx.profiles, ctx.hw)
    for engine in ("vec", "scalar"):
        p = prov.provision(specs, ctx.profiles, ctx.hw, engine=engine,
                           replicate=True)
        assert [(x.workload, x.gpu, x.r, x.batch) for x in p.placements] \
            == [(x.workload, x.gpu, x.r, x.batch) for x in base.placements]
    mods = models()
    a = simulate_plan(base, mods, ctx.hw, duration_s=3.0, poisson=True,
                      engine="scalar")
    b = simulate_plan(prov.provision(specs, ctx.profiles, ctx.hw,
                                     replicate=True),
                      mods, ctx.hw, duration_s=3.0, poisson=True,
                      engine="vec")
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w


def test_replicated_provision_clears_honest_residuals(ctx, m100):
    """m=100 pin: the residual workloads that clamp at r=1.0 under the
    queueing budget split into replicas and the model predicts clean —
    and the scalar engine emits the identical replicated plan."""
    specs, plan_r = m100
    plan_0 = prov.provision(specs, ctx.profiles, ctx.hw)
    v0 = prov.predicted_violations(plan_0, ctx.profiles, ctx.hw)
    vr = prov.predicted_violations(plan_r, ctx.profiles, ctx.hw)
    assert len(v0) > 0                   # the ceiling is real pre-split
    assert vr == []                      # ...and split away
    groups = repl.group_placements(plan_r.placements)
    split = {b: g for b, g in groups.items() if len(g) > 1}
    assert set(split) >= set(v0)         # every residual got replicas
    for b, g in split.items():
        base_rate = next(s.rate_rps for s in specs if s.name == b)
        assert sum(p.workload.rate_rps for p in g) == \
            pytest.approx(base_rate)
    oracle = prov.provision(specs, ctx.profiles, ctx.hw, engine="scalar",
                            replicate=True)
    assert [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
            for p in oracle.placements] == \
        [(p.workload.name, p.gpu, round(p.r, 9), p.batch)
         for p in plan_r.placements]


def test_merge_renormalizes_shares(ctx):
    """Shares always sum to the base rate: after split 3 -> merge 2 ->
    merge 1, each intermediate group renormalizes and k=1 restores the
    plain name."""
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    w = specs[4]
    plan3 = prov.split_workload(plan, w, 3, ctx.profiles, ctx.hw)
    g3 = repl.group_placements(plan3.placements)[w.name]
    assert [p.workload.name for p in g3] == [f"{w.name}#{j}"
                                            for j in range(3)]
    assert sum(p.workload.rate_rps for p in g3) == pytest.approx(w.rate_rps)
    plan2 = prov.merge_workload(plan3, w, 2, ctx.profiles, ctx.hw)
    g2 = repl.group_placements(plan2.placements)[w.name]
    assert len(g2) == 2
    assert sum(p.workload.rate_rps for p in g2) == pytest.approx(w.rate_rps)
    assert all(p.workload.rate_rps == pytest.approx(w.rate_rps / 2)
               for p in g2)              # equal shares, renormalized
    plan1 = prov.merge_workload(plan2, w, 1, ctx.profiles, ctx.hw)
    g1 = repl.group_placements(plan1.placements)[w.name]
    assert [p.workload.name for p in g1] == [w.name]
    assert g1[0].workload.rate_rps == pytest.approx(w.rate_rps)
    with pytest.raises(ValueError):
        prov.split_workload(plan3, w, 2, ctx.profiles, ctx.hw)
    with pytest.raises(ValueError):
        prov.merge_workload(plan1, w, 1, ctx.profiles, ctx.hw)


# ---------------------------------------------------------------------------
# Arrival-stream splitting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("poisson", [False, True], ids=["rr", "thin"])
def test_split_stream_partitions_exactly(poisson):
    rng = np.random.default_rng(7)
    arr = np.sort(rng.uniform(0.0, 10_000.0, size=5000))
    fracs = [0.5, 0.3, 0.2]
    parts = _split_stream(arr, fracs, poisson,
                          np.random.default_rng([0, 1, 3, 0]))
    merged = np.sort(np.concatenate(parts))
    assert np.array_equal(merged, arr)   # exact partition, nothing lost
    counts = np.array([p.size for p in parts]) / arr.size
    tol = 0.001 if not poisson else 0.05
    assert np.allclose(counts, fracs, atol=tol)


def test_split_stream_round_robin_interleaves():
    """Equal shares reduce to strict round-robin (i mod k)."""
    arr = np.arange(12, dtype=np.float64)
    parts = _split_stream(arr, [0.5, 0.5], False,
                          np.random.default_rng(0))
    assert np.array_equal(parts[0], arr[0::2])
    assert np.array_equal(parts[1], arr[1::2])


def test_split_stream_zero_share_and_all_zero():
    arr = np.arange(10, dtype=np.float64)
    parts = _split_stream(arr, [1.0, 0.0], False, np.random.default_rng(0))
    assert np.array_equal(parts[0], arr) and parts[1].size == 0
    parts = _split_stream(arr, [0.0, 0.0], True, np.random.default_rng(0))
    assert np.array_equal(parts[0], arr) and parts[1].size == 0


@pytest.mark.parametrize("poisson", [False, True], ids=["det", "poisson"])
def test_setup_pools_replica_group_arrivals(ctx, m100, poisson):
    """Replica slices exactly partition the pooled base stream, and the
    pooled stream is the one the base workload would have drawn."""
    specs, plan_r = m100
    instances, _, arrivals, _, _, router = _setup(
        plan_r, models(), False, 0.0, 4000.0, poisson, 0)
    groups = {}
    for i, inst in enumerate(instances):
        groups.setdefault(repl.base_name(inst.spec.name), []).append(i)
    n_split = 0
    for base, idxs in groups.items():
        if len(idxs) == 1:
            assert arrivals[idxs[0]] is router.base[base]
            continue
        n_split += 1
        merged = np.sort(np.concatenate([arrivals[i] for i in idxs]))
        assert np.array_equal(merged, router.base[base])
    assert n_split >= 5                  # the m=100 mix really splits


# ---------------------------------------------------------------------------
# Simulation: merged accounting + engine equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("poisson", [False, True], ids=["det", "poisson"])
def test_split_plan_engines_byte_identical(ctx, m100, poisson):
    specs, plan_r = m100
    mods = models()
    a = simulate_full(plan_r, mods, ctx.hw, duration_s=3.0, seed=2,
                      poisson=poisson, engine="scalar")
    b = simulate_full(plan_r, mods, ctx.hw, duration_s=3.0, seed=2,
                      poisson=poisson, engine="vec")
    assert set(a.request_latencies) == {s.name for s in specs}
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload
    assert a.per_replica == b.per_replica
    assert a.stats["n_requests"] == b.stats["n_requests"]


def test_merged_p99_matches_pooled_stream(ctx, m100):
    """per_workload percentiles are computed over the POOLED request
    stream, whose size is the sum of the replica streams (nothing
    dropped, nothing double-counted)."""
    specs, plan_r = m100
    res = simulate_full(plan_r, models(), ctx.hw, duration_s=3.0, seed=2)
    groups = repl.group_placements(plan_r.placements)
    checked = 0
    for base, g in groups.items():
        if len(g) == 1:
            continue
        pooled = res.request_latencies[base]
        names = [p.workload.name for p in g]
        assert set(names) <= set(res.per_replica)
        assert res.per_workload[base]["n_replicas"] == len(g)
        assert res.per_workload[base]["p99_ms"] == \
            pytest.approx(float(np.percentile(pooled, 99)))
        assert res.per_workload[base]["rps"] == pytest.approx(
            sum(res.per_replica[n]["rps"] for n in names))
        checked += 1
    assert checked >= 5


def test_violations_accept_base_specs(ctx, m100):
    specs, plan_r = m100
    res = simulate_full(plan_r, models(), ctx.hw, duration_s=3.0, seed=0)
    viols = res.violations({s.name: s for s in specs})
    assert set(viols) <= {s.name for s in specs}


# ---------------------------------------------------------------------------
# Runtime scale-out (controller-driven splits)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ramped(ctx):
    """A 12-workload diurnal ramp hot enough to force runtime splits."""
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    tr = traces.diurnal([s.name for s in specs], 8000.0, peak=2.2)
    mods = models()
    out = {}
    for engine in ("scalar", "vec"):
        ctl = Controller(plan, ctx.profiles, ctx.hw)
        out[engine] = (ctl, simulate_plan(
            plan, mods, ctx.hw, duration_s=8.0, trace=tr, adjust_fn=ctl,
            adjust_scope="cluster", adjust_period_s=1.0, engine=engine))
    return specs, plan, tr, out


def test_runtime_split_occurs_and_appends_instances(ramped):
    specs, plan, tr, out = ramped
    ctl, res = out["vec"]
    splits = [e for e in ctl.edits if e.action == "split"]
    assert splits, "the 2.2x ramp must force at least one split"
    assert all(e.replicas > 1 for e in splits)
    split_bases = {e.workload for e in splits}
    # the plan now carries replica placements with renormalized shares
    groups = repl.group_placements(ctl.plan.placements)
    for base in split_bases:
        g = groups[base]
        assert len(g) > 1
        assert sum(p.workload.rate_rps for p in g) == pytest.approx(
            ctl.reconciler.targets[base].rate_rps)
    # and the simulation served them: merged accounting + per_replica
    assert set(res.per_workload) == {s.name for s in specs}
    assert any(res.per_workload[b]["n_replicas"] > 1 for b in split_bases)
    assert res.stats["n_reconfigs"] > 0


def test_runtime_split_engine_identical(ramped):
    """Scale-out mid-run (appended instances, re-split arrival tails)
    stays byte-identical across engines."""
    specs, plan, tr, out = ramped
    (ctl_a, a), (ctl_b, b) = out["scalar"], out["vec"]
    assert [dataclasses.astuple(e) for e in ctl_a.edits] == \
        [dataclasses.astuple(e) for e in ctl_b.edits]
    assert a.stats["n_reconfigs"] == b.stats["n_reconfigs"]
    assert a.stats["n_requests"] == b.stats["n_requests"]
    for w in a.request_latencies:
        assert np.array_equal(a.request_latencies[w],
                              b.request_latencies[w]), w
        assert np.array_equal(a.request_waits[w], b.request_waits[w]), w
    assert a.per_workload == b.per_workload
    assert a.per_replica == b.per_replica


def test_runtime_split_improves_ramped_violations(ctx, ramped):
    specs, plan, tr, out = ramped
    ctl, res_c = out["vec"]
    res_s = simulate_plan(plan, models(), ctx.hw, duration_s=8.0,
                          trace=tr)
    scaled = {s.name: dataclasses.replace(
        s, rate_rps=s.rate_rps * tr.mean_scale(s.name, 8000.0))
        for s in specs}
    assert len(res_c.violations(scaled)) <= len(res_s.violations(scaled))


def test_required_replicas_none_when_hopeless(ctx):
    """'Feasible as one instance' (1) and 'hopeless at any split'
    (None) must stay distinguishable — the controller keeps hopeless
    workloads at their CURRENT replica count instead of merging a
    working group into one guaranteed-violating instance."""
    impossible = WorkloadSpec("X", "qwen2-vl-7b", slo_ms=1.0,
                              rate_rps=10.0)
    c = ctx.profiles[impossible.model]
    assert prov.required_replicas(impossible, c, ctx.hw) is None
    feasible = twelve_workloads()[0]
    assert prov.required_replicas(feasible,
                                  ctx.profiles[feasible.model],
                                  ctx.hw) == 1


def test_hopeless_drift_keeps_group_membership(ctx, monkeypatch):
    """A drift tick on a split group whose new rate is infeasible at
    EVERY k must resize the existing replicas in place — never remove
    the group (the atomicity hole: removals before a raising add would
    silently drop the workload from the plan)."""
    from repro.serving.controller import (ArrivalEstimator,
                                          ControllerConfig, Reconciler)
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    w = specs[4]
    plan = prov.split_workload(plan, w, 2, ctx.profiles, ctx.hw)
    cfg = ControllerConfig()
    rec = Reconciler(plan, ctx.profiles, ctx.hw, cfg=cfg)
    monkeypatch.setattr(prov, "required_replicas",
                        lambda *a, **k: None)
    ests = {}
    for base, spec in rec.targets.items():
        est = ArrivalEstimator(spec.rate_rps, cfg)
        rate = spec.rate_rps * (1.5 if base == w.name else 1.0)
        for k in range(4):
            est.observe(np.arange(0.5, 1000.0, 1000.0 / rate)
                        + k * 1000.0, 1000.0)
        ests[base] = est
    rec.reconcile(4.0, ests)
    group = repl.group_placements(rec.plan.placements)[w.name]
    assert [p.workload.name for p in group] == \
        [f"{w.name}#0", f"{w.name}#1"]   # membership preserved
    acts = [e for e in rec.edits if e.workload == w.name]
    assert acts and acts[-1].action in ("resize", "infeasible")
    assert not any(e.action in ("merge", "split") for e in acts)


def test_scale_out_requires_cluster_scope(ctx):
    """Appending instances under the per-device scope is rejected
    loudly instead of silently dropping the new replica."""
    specs = twelve_workloads()
    plan = prov.provision(specs, ctx.profiles, ctx.hw)
    mods = models()

    def rogue(now, insts):
        from repro.serving.simulator import ServedInstance
        insts.append(ServedInstance(
            spec=dataclasses.replace(insts[0].spec, name="X#1",
                                     rate_rps=1.0),
            desc=insts[0].desc, r=0.05, batch=1, gpu=insts[0].gpu))

    with pytest.raises(RuntimeError, match="cluster"):
        simulate_plan(plan, mods, ctx.hw, duration_s=2.0,
                      adjust_fn=rogue, adjust_period_s=1.0,
                      adjust_scope="device")


# ---------------------------------------------------------------------------
# Capacity-proportional share rebalance (provision-time, unequal devices)
# ---------------------------------------------------------------------------

def test_proportional_shares_unit():
    assert repl.proportional_shares(100.0, []) is None
    assert repl.proportional_shares(100.0, [3.0, 3.0, 3.0]) is None
    with pytest.raises(ValueError):
        repl.proportional_shares(100.0, [3.0, 0.0])
    shares = repl.proportional_shares(90.0, [2.0, 1.0])
    assert shares == [60.0, 30.0]
    assert sum(shares) == 90.0


def test_rebalance_preserves_group_rates(ctx, m100):
    """Every replica group's shares still sum to its base rate after
    the capacity-proportional rewrite, and unreplicated plans are
    untouched (replicate=False goes nowhere near the rebalance)."""
    specs, plan = m100
    by_base = {s.name: s.rate_rps for s in specs}
    for base, group in repl.group_placements(plan.placements).items():
        total = repl.group_rate([p.workload for p in group])
        assert total == pytest.approx(by_base[base], rel=1e-9)


def test_rebalance_skips_equal_device_groups(ctx, m100):
    """Groups whose replicas sit on identical device compositions keep
    the bitwise-equal-share split (proportional_shares returns None for
    bitwise-identical capacities)."""
    specs, plan = m100
    metrics = prov.predicted_plan_metrics(plan, ctx.profiles, ctx.hw)
    for base, group in repl.group_placements(plan.placements).items():
        if len(group) < 2:
            continue
        caps = [1000.0 * p.batch / metrics[p.workload.name].t_inf
                for p in group]
        shares = [p.workload.rate_rps for p in group]
        if all(c == caps[0] for c in caps):
            assert all(s == shares[0] for s in shares)
        else:
            total = sum(shares)
            want = repl.proportional_shares(total, caps)
            for s, w in zip(shares, want):
                assert s == pytest.approx(w, rel=1e-9)


def test_rebalanced_provision_engine_identical(ctx):
    """The scalar and vec provision engines emit the same rebalanced
    replicated plan."""
    specs = synthetic_workloads(60, 2)
    a = prov.provision(specs, ctx.profiles, ctx.hw, replicate=True,
                       engine="scalar")
    b = prov.provision(specs, ctx.profiles, ctx.hw, replicate=True,
                       engine="vec")
    pa = sorted(((p.workload.name, p.workload.rate_rps, p.gpu, p.batch,
                  p.r) for p in a.placements))
    pb = sorted(((p.workload.name, p.workload.rate_rps, p.gpu, p.batch,
                  p.r) for p in b.placements))
    assert pa == pb
