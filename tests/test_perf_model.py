"""iGniter performance model (Eqs. 1-11) + Theorem 1 — unit and
hypothesis property tests on the system's invariants."""
import math

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # bare env: property tests skip, unit tests run
    from tests._hypothesis_stub import given, settings, st

from repro.core import perf_model as pm
from repro.core import provisioner as prov
from repro.core.types import V5E, WorkloadCoefficients, WorkloadSpec


def make_coeffs(k1=0.01, k2=2.0, k3=3.0, k4=0.02, k5=0.1, alpha_cache=0.1):
    return WorkloadCoefficients(
        model="m", hardware="hw", d_load=0.5, d_feedback=0.01,
        n_kernels=400, k_sch=0.005,
        k1=k1, k2=k2, k3=k3, k4=k4, k5=k5,
        alpha_power=500.0, beta_power=5.0,
        alpha_cacheutil=1.2, beta_cacheutil=0.02, alpha_cache=alpha_cache)


# ---------------------------------------------------------------------------
# Eq.-level unit tests
# ---------------------------------------------------------------------------

def test_eq11_monotonicity():
    c = make_coeffs()
    # more resources -> faster; bigger batch -> slower
    assert c.k_act(8, 0.8) < c.k_act(8, 0.4)
    assert c.k_act(16, 0.5) > c.k_act(4, 0.5)


def test_eq6_scheduling_delay():
    assert pm.delta_sch(V5E, 1) == 0.0
    d2, d5 = pm.delta_sch(V5E, 2), pm.delta_sch(V5E, 5)
    assert d5 > d2 > 0.0


def test_eq9_frequency_throttling():
    assert pm.gpu_frequency(V5E, V5E.power_cap - 1) == V5E.max_freq
    f = pm.gpu_frequency(V5E, V5E.power_cap + 50)
    assert f < V5E.max_freq
    assert f >= 0.3 * V5E.max_freq


def test_interference_increases_latency():
    """Fig. 3 property: co-location strictly increases predicted latency."""
    c = make_coeffs()
    solo = pm.predict_device([pm.PlacedWorkload(c, 8, 0.2)], V5E)
    prev = solo.per_workload[0].t_inf
    for n in (2, 3, 4, 5):
        multi = pm.predict_device([pm.PlacedWorkload(c, 8, 0.2)] * n, V5E)
        cur = multi.per_workload[0].t_inf
        assert cur > prev - 1e-12
        prev = cur


def test_eq8_neighbor_cache_sensitivity():
    c = make_coeffs(alpha_cache=0.5)
    light = pm.PlacedWorkload(make_coeffs(), 1, 0.1)
    heavy = pm.PlacedWorkload(make_coeffs(), 8, 0.8)
    me = pm.PlacedWorkload(c, 4, 0.2)
    t_light = pm.predict_workload(me, [light], V5E).t_act
    t_heavy = pm.predict_workload(me, [heavy], V5E).t_act
    assert t_heavy > t_light


# ---------------------------------------------------------------------------
# Theorem 1 properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(slo=st.floats(20.0, 400.0), rate=st.floats(5.0, 400.0))
def test_theorem1_batch_meets_rate(slo, rate):
    """b_appr is the SMALLEST batch whose throughput can cover the rate
    within T_slo/2 (Eq. 17 derivation property)."""
    c = make_coeffs()
    spec = WorkloadSpec("w", "m", slo, rate)
    b = prov.appropriate_batch(spec, c, V5E, b_max=10_000)
    r_ms = rate / 1000.0
    # with t_gpu = T/2 - t_load - t_feedback, throughput b / (t_gpu + t_fb) >= R
    t_budget = slo / 2.0 - c.t_load(b, V5E.pcie_bw)
    assert b >= r_ms * t_budget - 1.0 - 1e-6   # ceil within 1
    if b > 1:
        t_budget_prev = slo / 2.0 - c.t_load(b - 1, V5E.pcie_bw)
        assert (b - 1) < r_ms * t_budget_prev + 1e-9


@settings(max_examples=100, deadline=None)
@given(slo=st.floats(30.0, 400.0), rate=st.floats(5.0, 200.0))
def test_theorem1_r_lower_meets_slo(slo, rate):
    """Running alone with r_lower, predicted latency fits T_slo/2; with one
    r_unit less it would not (minimality), modulo the k4 offset.

    NOTE (paper fidelity): the Appendix-A proof of Eq. 18 drops the f/F
    frequency factor, i.e. Theorem 1 only guarantees the bound when the
    solo power demand stays under the cap.  We test exactly that regime
    (Alg. 2 re-checks the full model with throttling at placement time,
    which covers the residual) — see EXPERIMENTS.md notes.
    """
    c = make_coeffs()
    spec = WorkloadSpec("w", "m", slo, rate)
    try:
        b = prov.appropriate_batch(spec, c, V5E)
        rl = prov.resource_lower_bound(spec, c, V5E, b)
    except prov.InfeasibleError:
        return
    pred = pm.predict_device([pm.PlacedWorkload(c, b, rl)], V5E)
    if pred.p_demand > V5E.power_cap:
        return   # outside Theorem 1's assumption (see docstring)
    assert pred.per_workload[0].t_inf <= slo / 2.0 + 1e-6
    if rl > V5E.r_unit + 1e-9:
        pred2 = pm.predict_device(
            [pm.PlacedWorkload(c, b, rl - V5E.r_unit)], V5E)
        assert pred2.per_workload[0].t_inf > slo / 2.0 - 1e-6


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 64), r=st.floats(0.05, 1.0))
def test_solo_characteristics_positive(b, r):
    c = make_coeffs()
    assert c.k_act(b, r) > 0
    assert c.power(b, r) > 0
    assert 0 <= c.cache_util(b, r) <= 10.0


def test_throughput_eq2():
    c = make_coeffs()
    pred = pm.predict_device([pm.PlacedWorkload(c, 8, 0.5)], V5E)
    w = pred.per_workload[0]
    assert w.throughput == pytest.approx(
        1000.0 * 8 / (w.t_gpu + w.t_feedback))
